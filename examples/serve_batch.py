"""Batched serving example: continuous batching over a bursty request
stream, mixed prompt lengths and temperatures, with norm-fold compile.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import numpy as np

import repro
from repro.configs import get_config
from repro.serve import Request


def main():
    cfg = get_config("mixtral-8x22b", smoke=True)   # MoE serving

    t0 = time.perf_counter()
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    sched = repro.serve(exe, repro.SchedulerOptions(slots=4, max_len=96))
    print(f"scheduler compiled in {time.perf_counter() - t0:.1f}s "
          f"(folds={sched.fold_report['folds']})")

    rng = np.random.default_rng(1)
    # burst 1
    for i in range(6):
        sched.submit(Request(uid=i,
                             prompt=rng.integers(0, cfg.vocab,
                                                 int(rng.integers(4, 20))),
                             max_new_tokens=int(rng.integers(8, 20)),
                             temperature=0.8 if i % 2 else 0.0))
    # drain some, then burst 2 arrives mid-flight — the scheduler
    # rebatches every decode step, so the new burst fills freed slots
    for _ in range(10):
        sched.step()
    for c in sched.pop_completions():
        print(f"  early finish: uid={c.uid} ({c.finish_reason})")
    for i in range(6, 10):
        sched.submit(Request(uid=i,
                             prompt=rng.integers(0, cfg.vocab, 8),
                             max_new_tokens=10))
    done = sched.run()
    s = sched.summary()
    print(f"{s['completed']} completions / {s['total_new_tokens']} tokens "
          f"({(s['tokens_per_s'] or 0):.1f} tok/s, "
          f"occupancy {(s['mean_batch_occupancy'] or 0):.2f}/4, "
          f"peak queue {s['peak_queue_depth']})")
    for c in sorted(done, key=lambda c: c.uid):
        m = sched.request_metrics[c.uid]
        print(f"  uid={c.uid:<2} n={len(c.tokens):<3} "
              f"ttft={(m.ttft or 0) * 1e3:6.0f}ms first={c.tokens[:6]}")


if __name__ == "__main__":
    main()
