"""Batched serving example: continuous batching over a bursty request
stream, mixed prompt lengths and temperatures, with norm-fold compile.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import numpy as np

import repro
from repro.configs import get_config
from repro.inference import Request


def main():
    cfg = get_config("mixtral-8x22b", smoke=True)   # MoE serving

    t0 = time.perf_counter()
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    eng = exe.serve(slots=4, max_len=96)
    print(f"engine compiled in {time.perf_counter() - t0:.1f}s "
          f"(folds={eng.fold_report['folds']})")

    rng = np.random.default_rng(1)
    # burst 1
    for i in range(6):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab,
                                               int(rng.integers(4, 20))),
                           max_new_tokens=int(rng.integers(8, 20)),
                           temperature=0.8 if i % 2 else 0.0))
    # drain some, then burst 2 arrives mid-flight
    for _ in range(10):
        eng.step()
    for i in range(6, 10):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, 8),
                           max_new_tokens=10))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(c.tokens) for c in done)
    print(f"{len(done)} completions / {toks} tokens "
          f"({toks / dt:.1f} tok/s steady-state)")
    for c in sorted(done, key=lambda c: c.uid):
        print(f"  uid={c.uid:<2} n={len(c.tokens):<3} "
              f"first={c.tokens[:6]}")


if __name__ == "__main__":
    main()
