"""Quickstart: the paper's complete flow in 40 lines.

Build a CNN (the front end), compile it at load time (the paper's
contribution), validate against the SimpleNN oracle, and time
compiled-vs-interpreted — then do the same flow for an LLM: compile a
decode step and generate tokens.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

import jax

from repro.core import CompiledModel, ModelBuilder, SimpleNN


def cnn_flow():
    print("== CNN flow (the paper's own domain) ==")
    mb = ModelBuilder()
    x = mb.input((32, 32, 3))
    h = mb.conv2d(x, 16, (3, 3), activation="relu")
    h = mb.batchnorm(h)
    h = mb.maxpool(h)
    h = mb.conv2d(h, 32, (3, 3), activation="relu")
    h = mb.global_avg_pool(h)
    h = mb.dense(h, 10)
    out = mb.softmax(h)
    graph = mb.build([out])

    model = CompiledModel(graph)          # optimize + jit at load time
    img = np.random.default_rng(0).standard_normal(
        (1, 32, 32, 3)).astype(np.float32)

    got = model.apply(input=img)[out]
    want = SimpleNN(graph)(input=img)[out]
    print(f"  compiled == oracle: max|Δ| = "
          f"{float(abs(np.asarray(got) - np.asarray(want)).max()):.2e}")
    print(f"  compile time: {model.compile_time * 1e3:.1f} ms")
    print(f"  passes: " + ", ".join(
        f"{p['pass']}({p['nodes_before']}→{p['nodes_after']})"
        for p in model.report["passes"]))
    print(f"  memory plan: {model.report['memory_plan']}")


def llm_flow():
    print("== LLM flow (the same idea at framework scale) ==")
    from repro.configs import get_config
    from repro.inference import Engine, Request
    from repro.models import get_model

    cfg = get_config("qwen2.5-14b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    eng = Engine(m, params, slots=2, max_len=64)
    eng.submit(Request(uid=0, prompt=np.arange(8) % cfg.vocab,
                       max_new_tokens=12))
    out = eng.run()[0]
    print(f"  {len(out.tokens)} tokens in "
          f"{time.perf_counter() - t0:.1f}s (incl. compile); "
          f"norm folds applied: {eng.fold_report['folds']}")
    print(f"  tokens: {out.tokens}")


if __name__ == "__main__":
    cnn_flow()
    print()
    llm_flow()
