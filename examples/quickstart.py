"""Quickstart: the paper's complete flow through the unified API.

Build a CNN (the front end), then ``repro.compile`` it — one entry
point, explicit options, named targets.  Validate the "jit" target
against the "interpret" oracle, then run the same funnel for an LLM:
the "engine" target wraps the framework-scale model + serving engine
behind the identical Executable protocol.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

import repro
from repro.core import ModelBuilder


def cnn_flow():
    print("== CNN flow (the paper's own domain) ==")
    mb = ModelBuilder()
    x = mb.input((32, 32, 3))
    h = mb.conv2d(x, 16, (3, 3), activation="relu")
    h = mb.batchnorm(h)
    h = mb.maxpool(h)
    h = mb.conv2d(h, 32, (3, 3), activation="relu")
    h = mb.global_avg_pool(h)
    h = mb.dense(h, 10)
    out = mb.softmax(h)
    graph = mb.build([out])

    exe = repro.compile(graph, repro.CompileOptions(target="jit"))
    oracle = repro.compile(graph, repro.CompileOptions(target="interpret"))
    img = np.random.default_rng(0).standard_normal(
        (1, 32, 32, 3)).astype(np.float32)

    got = exe(input=img)[out]
    want = oracle(input=img)[out]
    print(f"  compiled == oracle: max|Δ| = "
          f"{float(abs(np.asarray(got) - np.asarray(want)).max()):.2e}")
    print(f"  compile time: {exe.compile_time * 1e3:.1f} ms")
    cost = exe.cost_summary()
    print(f"  passes: " + ", ".join(
        f"{p['pass']}({p['nodes_before']}→{p['nodes_after']})"
        for p in cost["passes"]))
    print(f"  memory plan: {cost['memory_plan']}")

    # The artifact is portable: serialize, ship, deserialize, run.
    blob = exe.serialize()
    again = repro.deserialize(blob)
    print(f"  serialized executable: {len(blob)} bytes; "
          f"round-trip max|Δ| = "
          f"{float(abs(np.asarray(again(input=img)[out]) - np.asarray(got)).max()):.2e}")


def trace_flow():
    print("== Trace flow (a plain function through the same funnel) ==")
    from repro.frontends import ops as F

    rng = np.random.default_rng(0)
    k = rng.standard_normal((3, 3, 3, 16)).astype(np.float32)
    w_cls = rng.standard_normal((16, 10)).astype(np.float32)
    w_emb = rng.standard_normal((16, 4)).astype(np.float32)

    def model(image):
        h = F.global_avg_pool(F.conv2d(image, k, activation="relu"))
        return {"probs": F.softmax(F.dense(h, w_cls)),
                "embed": F.dense(h, w_emb)}

    graph = repro.trace(model, (32, 32, 3))      # specs exclude batch
    exe = repro.compile(graph, repro.CompileOptions(target="jit"))
    sig = exe.signature
    print(f"  signature: ({', '.join(sig.input_names)}) -> "
          f"{dict((n, s.shape) for n, s in sig.outputs)}")

    img = np.random.default_rng(1).standard_normal(
        (4, 32, 32, 3)).astype(np.float32)
    out = exe(img)                               # positional binding
    print(f"  outputs: " + ", ".join(f"{n}{tuple(v.shape)}"
                                     for n, v in out.items()))

    # Bare callables also go straight into compile (trace frontend):
    exe2 = repro.compile(model, example_inputs=(img,), target="jit")
    same = np.array_equal(np.asarray(exe2(img)["probs"]),
                          np.asarray(out["probs"]))
    print(f"  compile(fn, example_inputs=...) == compile(trace(fn)): {same}")


def llm_flow():
    print("== LLM flow (the same funnel at framework scale) ==")
    from repro.configs import get_config
    from repro.serve import Request

    cfg = get_config("qwen2.5-14b", smoke=True)
    t0 = time.perf_counter()
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    sched = repro.serve(exe, repro.SchedulerOptions(slots=2, max_len=64))
    sched.submit(Request(uid=0, prompt=np.arange(8) % cfg.vocab,
                         max_new_tokens=12))
    out = sched.run()[0]
    print(f"  {len(out.tokens)} tokens in "
          f"{time.perf_counter() - t0:.1f}s (incl. compile); "
          f"norm folds applied: {sched.fold_report['folds']}")
    print(f"  tokens: {out.tokens}")
    print(f"  cost: {exe.cost_summary()}")


if __name__ == "__main__":
    cnn_flow()
    print()
    trace_flow()
    print()
    llm_flow()
