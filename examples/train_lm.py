"""End-to-end training driver example: a ~100M-param llama-family model
for a few hundred steps on the synthetic pipeline, with checkpointing
and the straggler watchdog — the full production loop at CPU scale.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: 12 layers × d_model 512 × vocab 50k ≈ 90M.)
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import ArchConfig
from repro.data import DataConfig, SyntheticTokens
from repro.distributed import StragglerWatchdog
from repro.models import get_model
from repro.training import (OptConfig, TrainConfig, init_state,
                            make_jitted_train_step)

CFG_100M = ArchConfig(
    name="llama-100m", family="dense",
    num_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
    d_ff=1536, vocab=50_304, head_dim=64,
    rope_theta=1e4, mlp_act="silu", tie_embeddings=True,
    q_chunk=128, kv_chunk=256, remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ck")
    args = ap.parse_args()

    model = get_model(CFG_100M)
    n_params = CFG_100M.param_count()
    print(f"model: {n_params/1e6:.0f}M params")

    tc = TrainConfig(opt=OptConfig(
        lr=6e-4, total_steps=args.steps, warmup_steps=args.steps // 20,
        schedule="cosine"), microbatches=2)
    step_fn = make_jitted_train_step(model, tc, mesh=None)
    data = SyntheticTokens(DataConfig(vocab=CFG_100M.vocab,
                                      global_batch=args.batch,
                                      seq_len=args.seq))
    ck = Checkpointer(args.ckpt, keep=2)
    state = init_state(model, jax.random.PRNGKey(0))
    start = (ck.latest_step() + 1) if ck.latest_step() is not None else 0
    if start:
        state = ck.restore(ck.latest_step(), state)
        print(f"resumed from step {start - 1}")

    wd = StragglerWatchdog(120.0, on_timeout=lambda s, el: print(
        f"[watchdog] step {s}: {el:.0f}s"))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        with wd.step(i):
            state, metrics = step_fn(state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(json.dumps({"step": i,
                              "loss": round(float(metrics["loss"]), 4),
                              "elapsed": round(time.time() - t0, 1)}),
                  flush=True)
        if i and i % 100 == 0:
            ck.save(i, state)
    ck.save(args.steps - 1, state, blocking=True)
    print("done; checkpoints:", ck.all_steps())


if __name__ == "__main__":
    main()
