"""The "engine" target: framework-scale models behind the same API.

``repro.compile(cfg_or_model, CompileOptions(target="engine"))`` wraps
``models.api.Model`` + ``inference.Engine`` in the Executable protocol,
so the LLM stack and the paper's CNN compiler are driven identically:

    exe = repro.compile(get_config("qwen2.5-14b", smoke=True),
                        CompileOptions(target="engine"), params=params)
    exe(tokens=toks)["logits"]          # jitted forward
    sched = exe.serve(slots=4)          # continuous-batching scheduler
"""

from __future__ import annotations

import dataclasses
import io
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Signature
from .executable import Executable, pack
from .options import CompileOptions


class ModelExecutable(Executable):
    """Executable over a registered model architecture (the ``"engine"``
    target): wraps ``model.forward`` in one jitted program with params
    closed over, tracking compile time per unseen input signature."""

    def __init__(self, model_or_cfg, options: CompileOptions, *,
                 params=None, init_seed: int = 0) -> None:
        from ..models.api import Model, get_model
        if isinstance(model_or_cfg, Model):
            self.model = model_or_cfg
            self.cfg = model_or_cfg.cfg
        else:
            self.cfg = model_or_cfg
            self.model = get_model(self.cfg)
        self.options = options
        if params is None:
            params = self.model.init(jax.random.PRNGKey(init_seed))
        # Low-precision serving: the graph quantize pass does not route
        # through framework-scale models, so the engine target supports
        # the storage-level subset — a weight-only bf16 cast (matmuls
        # upcast per JAX promotion, activations and KV stay f32).
        # Calibrated int8 needs the graph pipeline and is rejected here
        # rather than silently served at full precision.
        self.quant_report: Optional[dict] = None
        if options.precision in ("int8", "mixed"):
            raise ValueError(
                f"precision={options.precision!r} is not supported by "
                "the 'engine' target: calibrated int8 routes through "
                "the graph quantize pass, which framework-scale models "
                "bypass — use precision='bf16' (weight-only storage "
                "cast) for served models")
        if options.precision == "bf16":
            leaves, treedef = jax.tree_util.tree_flatten(params)
            cast = [l.astype(jnp.bfloat16)
                    if getattr(l, "dtype", None) == jnp.float32 else l
                    for l in leaves]
            n_bf16 = sum(1 for l in cast
                         if getattr(l, "dtype", None) == jnp.bfloat16)
            params = jax.tree_util.tree_unflatten(treedef, cast)
            self.quant_report = {
                "mode": "bf16",
                "decisions": {"bf16": n_bf16,
                              "f32": len(leaves) - n_bf16}}
        self.params = params
        self.compile_time: Optional[float] = None
        self._fwd = jax.jit(lambda p, b: self.model.forward(p, b)[0])
        self._seen_shapes = set()
        # Shapes are dynamic at this scale (prefill length, batch), so
        # the signature carries names + order but no static specs.
        from ..configs.base import extra_input_specs
        self.signature = Signature(
            inputs=(("tokens", None),) + tuple(
                (n, None) for n in extra_input_specs(self.cfg)),
            outputs=(("logits", None),),
        )

    # ------------------------------------------------------------------
    def __call__(self, *pos, **batch) -> Dict[str, Any]:
        batch = self.signature.bind(pos, batch)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        sig = tuple(sorted((k, v.shape, str(v.dtype))
                           for k, v in batch.items()))
        if sig not in self._seen_shapes:
            t0 = time.perf_counter()
            logits = jax.block_until_ready(self._fwd(self.params, batch))
            self._seen_shapes.add(sig)
            self.compile_time = ((self.compile_time or 0.0)
                                 + time.perf_counter() - t0)
        else:
            logits = self._fwd(self.params, batch)
        return {"logits": logits}

    def serve(self, options=None, **kw):
        """Build the continuous-batching scheduler over this executable
        (shorthand for ``repro.serve(exe, options, **kw)``)."""
        from .serve import serve as api_serve
        return api_serve(self, options, **kw)

    # ------------------------------------------------------------------
    def cost_summary(self):
        """Model-level cost facts: parameter count and byte footprint
        (engine executables have no pass pipeline to report)."""
        leaves = jax.tree_util.tree_leaves(self.params)
        out = {
            "target": "engine",
            "arch": self.cfg.name,
            "family": self.cfg.family,
            "params": int(sum(l.size for l in leaves)),
            "param_bytes": int(sum(l.size * l.dtype.itemsize
                                   for l in leaves)),
        }
        if self.quant_report is not None:
            out["quant"] = dict(self.quant_report)
        return out

    def serialize(self) -> bytes:
        """Pack cfg + param leaves into the portable artifact format."""
        # The param pytree structure is NOT stored: it is rederived from
        # the cfg at load time (no pickle — repro.deserialize must be
        # safe on untrusted bytes).  Only leaves travel, in
        # tree_flatten order.
        leaves, _ = jax.tree_util.tree_flatten(self.params)
        arrays = {}
        dtypes = []
        for i, leaf in enumerate(leaves):
            a = np.asarray(leaf)
            dtypes.append(str(a.dtype))
            if a.dtype not in (np.float32, np.float64, np.int32, np.int64,
                               np.uint8, np.bool_):
                a = a.astype(np.float32)  # bf16 etc: widen losslessly
            arrays[f"leaf::{i}"] = a
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        extra = {"cfg": dataclasses.asdict(self.cfg), "leaf_dtypes": dtypes}
        return pack("engine", self.options, buf.getvalue(), extra=extra)


def deserialize_engine(meta: dict, body: bytes,
                       options: CompileOptions) -> ModelExecutable:
    """Rebuild a ``ModelExecutable`` from a packed artifact: cfg from
    metadata, param leaves from the npz body (no pickle)."""
    from ..configs.base import ArchConfig
    from ..core.keras_like import _tuplify
    from ..models.api import get_model
    data = np.load(io.BytesIO(body), allow_pickle=False)
    cfg_dict = {k: _tuplify(v) if isinstance(v, list) else v
                for k, v in meta["cfg"].items()}
    cfg = ArchConfig(**cfg_dict)
    # Rebuild the pytree structure from the cfg (abstract init — no
    # allocation), then pour the stored leaves back in.
    model = get_model(cfg)
    template = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    treedef = jax.tree_util.tree_structure(template)
    n = treedef.num_leaves
    if len(meta["leaf_dtypes"]) != n:
        raise ValueError(
            f"param leaf count mismatch: container has "
            f"{len(meta['leaf_dtypes'])}, cfg {cfg.name!r} expects {n}")
    leaves = [jnp.asarray(data[f"leaf::{i}"]).astype(dt)
              for i, dt in enumerate(meta["leaf_dtypes"])]
    params = jax.tree_util.tree_unflatten(treedef, leaves)
    return ModelExecutable(cfg, options, params=params)
