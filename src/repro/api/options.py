"""CompileOptions — the one options surface for ``repro.compile``.

A frozen dataclass replaces the old kwargs soup
(``CompiledModel(graph, embed_weights=…, precision=…, use_pallas=…,
passes=…)``).  Options are hashable, comparable and serializable, so
they double as part of the persistent executable-cache key.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

from ..dist.mesh import MeshSpec
from ..runtime.buckets import BucketPolicy

PRECISIONS = ("exact", "fast", "f32", "bf16", "int8", "mixed")
#: Precision names that request the calibration-driven quantize pass
#: (``repro.core.passes.quantize``).  ``"f32"`` is in the family for
#: symmetry but compiles bit-identically to ``"exact"``.
QUANT_PRECISIONS = ("f32", "bf16", "int8", "mixed")
AUTOTUNE_MODES = ("off", "cached", "full")


def _normalize_rules(rules) -> Tuple[Tuple[str, object], ...]:
    """Canonical, hashable form of a sharding-rules override: sorted
    ``(logical, axes-tuple-or-None)`` pairs.  Accepts a mapping or a
    pair sequence (the ``from_dict`` round-trip)."""
    items = rules.items() if hasattr(rules, "items") else rules
    out = []
    for k, v in items:
        if v is None or isinstance(v, str):
            out.append((str(k), v))
        else:
            out.append((str(k), tuple(str(a) for a in v)))
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Every compile-time choice, in one place.

    target:        lowering backend name (see ``repro.available_targets()``):
                   ``"interpret"`` (SimpleNN oracle semantics), ``"jit"``
                   (optimized jaxpr path), ``"pallas"`` (fused kernels),
                   ``"engine"`` (framework-scale Model/Engine adapter).
    precision:     numeric contract of the compiled program.
                   ``"exact"`` (default) and ``"fast"`` (paper §3.4
                   approximate activations) are the f32 pipelines.
                   The low-precision family routes through the
                   calibration-driven quantize pass: ``"f32"``
                   (explicit full precision — bit-identical to
                   ``"exact"``), ``"bf16"`` (operands cast to bfloat16,
                   f32 accumulation), ``"int8"`` (calibrated symmetric
                   int8 compute with f32 dequant epilogues), and
                   ``"mixed"`` (the autotuner picks f32/bf16/int8 per
                   site, measured under the autotune budget and
                   constrained by ``precision_budget``).
    calibrate:     number of seeded sample batches the quantize pass
                   runs through the interpret-target oracle to record
                   per-tensor abs-max activation ranges.  ``None``
                   defaults to 4 when a quantizing precision is
                   selected; ignored otherwise.
    precision_budget: accuracy budget (max_abs_err vs the f32
                   calibration outputs) that ``"mixed"`` tactic
                   selection must hold per site; sites whose int8/bf16
                   candidates exceed it stay f32.  ``None`` = the
                   default budget (0.05).
    embed_weights: close over weights as XLA constants (paper-faithful)
                   vs. pass them as an argument (program reusable across
                   checkpoints).
    passes:        explicit pass pipeline; ``None`` = DEFAULT_PIPELINE.
    batch_buckets: optional ascending batch sizes to specialize for; a
                   call with batch B runs the smallest bucket ≥ B (input
                   padded, output sliced).  Empty = specialize exactly.
                   Compiles lazily and synchronously — the legacy
                   spelling; prefer ``buckets=`` for the runtime engine
                   cache (async warm-up, nearest-warm fallback).
    buckets:       a :class:`repro.runtime.BucketPolicy`; the compile
                   returns a :class:`~repro.runtime.BucketedExecutable`
                   (one warm program per batch bucket, background
                   compilation of cold buckets, pre-warming from the
                   persistent cache).  ``None`` = exact specialization.
    donate_inputs: donate input buffers to the compiled program
                   (in-place memory reuse; callers must not reuse the
                   arrays they pass in).
    cache_dir:     directory for the persistent executable cache.  None
                   falls back to ``$REPRO_CACHE_DIR``; if that is unset
                   the on-disk cache is disabled (in-process caching
                   always applies).
    dump_ir:       dump the IR between compiler passes: a directory
                   (one ``NN-<pass>.txt`` summary per stage) or ``"-"``
                   for stderr.  ``None`` falls back to
                   ``$REPRO_DUMP_IR``; unset disables.
    autotune:      profile-guided kernel selection (``repro.autotune``).
                   ``"off"`` (default): the static heuristic selector,
                   bit-identical to the pre-autotuner compiler.
                   ``"cached"``: use measured tactics from the
                   persistent tactic cache where present; heuristic
                   otherwise — never measures.  ``"full"``: additionally
                   micro-benchmark candidates for uncached shapes and
                   record the winners.
    autotune_budget_ms: wall-clock budget for ``"full"`` measurement per
                   compile (candidate jit compiles included); shapes the
                   budget doesn't reach fall back to the heuristic.
                   ``None`` = unlimited.  Graph-level decision tuning
                   (``repro.autotune.decisions``) takes at most half of
                   it; per-node kernel tactics get the remainder.
    capture:       write a self-contained capture bundle for this
                   compile (``repro.api.capture``): the serialized input
                   graph, the options, per-pass IR dumps, the kernel and
                   graph-decision selection reports with per-candidate
                   µs, recorded input/output tensors per batch, and the
                   environment fingerprint — everything
                   ``python -m repro.replay <bundle>`` needs to re-run
                   the compile offline and diff it against the record.
                   A directory path = the bundle directory itself.
                   ``None`` falls back to ``$REPRO_CAPTURE_DIR`` (a
                   *root*: the bundle lands in a per-compile
                   subdirectory); unset disables capture.
    mesh:          a :class:`repro.dist.MeshSpec` (or any spelling its
                   ``coerce`` accepts: ``"data=4,model=2"``, a dict of
                   sizes) making device placement a compile-time input.
                   The ``"jit"``/``"pallas"`` targets then produce a
                   :class:`repro.dist.ShardedExecutable` whose graph
                   carries per-tensor PartitionSpecs and explicit
                   collective nodes; a single-device mesh stays
                   bit-identical to the unsharded path.  ``None`` =
                   today's unsharded compile.
    sharding_rules: overrides on the logical-axis rules table
                   (``repro.distributed.sharding.DEFAULT_RULES``) the
                   propagation pass consults — a mapping/pairs of
                   ``logical axis -> mesh axis (or axes, or None to
                   force replication)``.  Only meaningful with
                   ``mesh=``.  Both fields are part of the persistent
                   cache key.
    """

    target: str = "jit"
    precision: str = "exact"
    calibrate: Optional[int] = None
    precision_budget: Optional[float] = None
    embed_weights: bool = True
    passes: Optional[Tuple[str, ...]] = None
    batch_buckets: Tuple[int, ...] = ()
    buckets: Optional[BucketPolicy] = None
    donate_inputs: bool = False
    cache_dir: Optional[str] = None
    dump_ir: Optional[str] = None
    autotune: str = "off"
    autotune_budget_ms: Optional[float] = 1000.0
    capture: Optional[str] = None
    mesh: Optional[MeshSpec] = None
    sharding_rules: Optional[Tuple[Tuple[str, object], ...]] = None

    def __post_init__(self) -> None:
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got {self.precision!r}"
            )
        if self.calibrate is not None and int(self.calibrate) <= 0:
            raise ValueError(
                f"calibrate must be a positive batch count or None, "
                f"got {self.calibrate!r}")
        if self.precision_budget is not None and self.precision_budget <= 0:
            raise ValueError(
                f"precision_budget must be a positive max_abs_err or "
                f"None, got {self.precision_budget!r}")
        if self.autotune not in AUTOTUNE_MODES:
            raise ValueError(
                f"autotune must be one of {AUTOTUNE_MODES}, "
                f"got {self.autotune!r}"
            )
        if (self.autotune_budget_ms is not None
                and self.autotune_budget_ms <= 0):
            raise ValueError(
                f"autotune_budget_ms must be positive or None, "
                f"got {self.autotune_budget_ms!r}"
            )
        if self.passes is not None:
            object.__setattr__(self, "passes", tuple(self.passes))
        buckets = tuple(sorted(int(b) for b in self.batch_buckets))
        if any(b <= 0 for b in buckets):
            raise ValueError(f"batch_buckets must be positive: {buckets}")
        object.__setattr__(self, "batch_buckets", buckets)
        if isinstance(self.buckets, dict):      # from_dict round-trip
            object.__setattr__(self, "buckets",
                               BucketPolicy.from_dict(self.buckets))
        if self.buckets is not None and not isinstance(self.buckets,
                                                       BucketPolicy):
            raise ValueError(
                f"buckets must be a repro.runtime.BucketPolicy or None, "
                f"got {type(self.buckets).__name__}")
        if self.buckets is not None and self.batch_buckets:
            raise ValueError(
                "batch_buckets (legacy, lazy) and buckets (runtime "
                "engine cache) are mutually exclusive")
        if self.mesh is not None and not isinstance(self.mesh, MeshSpec):
            object.__setattr__(self, "mesh", MeshSpec.coerce(self.mesh))
        if self.sharding_rules is not None:
            object.__setattr__(
                self, "sharding_rules",
                _normalize_rules(self.sharding_rules))

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "CompileOptions":
        """Copy with the given fields replaced (options are frozen)."""
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        """Plain-dict form for JSON artifacts; invert with ``from_dict``."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CompileOptions":
        """Rebuild options from ``to_dict`` output (re-tuplifying the
        fields JSON round-trips as lists)."""
        d = dict(d)
        if d.get("passes") is not None:
            d["passes"] = tuple(d["passes"])
        d["batch_buckets"] = tuple(d.get("batch_buckets") or ())
        return cls(**d)

    def cache_token(self) -> str:
        """Stable string of every field that affects generated code.

        ``cache_dir`` is excluded (where the cache lives must not change
        what is cached), so are ``batch_buckets`` and ``buckets`` (the
        per-batch program is identical however the caller buckets; the
        batch size itself is a separate key component — which is also
        why bucketed executables share disk entries with exact compiles
        of the same batch), and so is ``dump_ir`` (a debugging side
        channel, not a codegen choice).  The ``autotune`` fields
        are excluded too: what actually changes the generated code is
        the *resolved kernel selection*, which the executable cache key
        mixes in separately — so an autotuned compile whose measurements
        land on the heuristic's choices shares the heuristic's cached
        executable.
        """
        d = self.to_dict()
        d.pop("cache_dir")
        d.pop("batch_buckets")
        d.pop("buckets")
        d.pop("dump_ir")
        d.pop("autotune")
        d.pop("autotune_budget_ms")
        d.pop("capture")   # a recording side channel, like dump_ir
        return json.dumps(d, sort_keys=True, default=str)
