"""Persistent on-disk executable cache.

The paper measures compilation time as part of model load; here we
amortize it *across processes*: the ``"jit"``/``"pallas"`` targets lower
ahead-of-time via ``jax.jit(...).lower(...).compile()`` and the
resulting XLA executable is serialized (``jax.experimental.
serialize_executable``) under a key of ``structure_hash × weights ×
options × batch × jax-version × backend``.  A second process compiling
the same model loads the executable instead of re-running XLA.

Serialization is best-effort: any failure (old jax, cross-platform
blob, corrupt file) degrades to a normal compile — never to an error.
Entries are pickled, so the cache directory is trusted local state
(unlike ``repro.deserialize``, which must be safe on untrusted bytes);
point ``cache_dir``/``$REPRO_CACHE_DIR`` only at directories you own.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
from typing import Optional

import jax

_FORMAT_VERSION = 1

#: Env var capping the on-disk executable cache size in bytes; when set,
#: every ``store`` triggers an LRU sweep back under the cap.  Bucketed
#: executables multiply entries (one per batch bucket), so an unbounded
#: cache directory now grows much faster than it did pre-bucketing.
MAX_BYTES_ENV = "REPRO_CACHE_MAX_BYTES"


def cache_key(*parts: str) -> str:
    """Digest of the given parts plus everything environmental that
    invalidates an executable (jax version, backend platform)."""
    h = hashlib.sha256()
    for p in (f"v{_FORMAT_VERSION}", jax.__version__, jax.default_backend(),
              *parts):
        h.update(p.encode())
        h.update(b"\x00")
    return h.hexdigest()


def resolve_cache_dir(explicit: Optional[str]) -> Optional[str]:
    """Explicit option wins; else ``$REPRO_CACHE_DIR``; else disabled."""
    return explicit if explicit is not None else os.environ.get("REPRO_CACHE_DIR")


class ExecutableCache:
    """Pickle-per-entry directory cache of serialized XLA executables."""

    def __init__(self, root: str) -> None:
        self.root = root
        self.hits = 0
        self.misses = 0
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.xla")

    def load(self, key: str):
        """Return a loaded executable, or None on miss/failure."""
        path = self._path(key)
        if not os.path.exists(path):
            self.misses += 1
            return None
        try:
            from jax.experimental import serialize_executable as se
            with open(path, "rb") as f:
                payload = pickle.load(f)
            exe = se.deserialize_and_load(*payload)
            self.hits += 1
            try:
                # LRU recency: a hit refreshes the entry's mtime so the
                # size-capped sweep evicts cold entries, not hot ones.
                os.utime(path)
            except OSError:
                pass
            return exe
        except Exception:
            # Corrupt/stale entry: drop it and recompile.
            try:
                os.remove(path)
            except OSError:
                pass
            self.misses += 1
            return None

    def store(self, key: str, compiled) -> bool:
        """Serialize ``compiled`` under ``key``; atomic via rename."""
        try:
            from jax.experimental import serialize_executable as se
            payload = se.serialize(compiled)
            blob = pickle.dumps(payload)
        except Exception:
            return False
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass
            return False
        cap = os.environ.get(MAX_BYTES_ENV)
        if cap:
            try:
                prune(int(cap), self.root)
            except (OSError, ValueError):
                pass                       # the sweep is best-effort
        return True

    def stats(self) -> dict:
        """Hit/miss counters for this process plus the cache directory."""
        return {"dir": self.root, "hits": self.hits, "misses": self.misses}


def _load_manifests(root: str):
    """Map manifest path -> member ``.xla`` basenames, for every
    ``*.manifest.json`` a :class:`~repro.dist.ShardedExecutable` wrote
    next to its per-batch entries.  Unreadable manifests count as
    empty (and so get cleaned up as dangling)."""
    import json
    out = {}
    for name in os.listdir(root):
        if not name.endswith(".manifest.json"):
            continue
        path = os.path.join(root, name)
        members = []
        try:
            with open(path) as f:
                members = [f"{k}.xla" for k in json.load(f).get("members", [])]
        except (OSError, ValueError):
            pass
        out[path] = members
    return out


def prune(max_bytes: int, cache_dir: Optional[str] = None) -> dict:
    """Size-capped LRU sweep of the persistent executable cache.

    Deletes least-recently-used entries (mtime order — ``load``
    refreshes it on every hit) until the directory's entry bytes fit in
    ``max_bytes``, and clears out orphaned ``.tmp`` files from
    interrupted writes.  Corruption-safe by construction: entries are
    only ever whole files (writes go through an atomic rename), removal
    is whole-file, and a concurrently-vanishing file is skipped, so a
    reader racing the sweep sees either a valid entry or a clean miss —
    never a truncated one.

    Sharded executables group their per-batch artifacts under a
    ``*.manifest.json``; the sweep treats each group as ONE logical LRU
    entry — recency is the group's hottest member, eviction removes the
    members and the manifest together — so a pruned cache never holds a
    manifest pointing at missing artifacts (nor sharded artifacts with
    a dangling subset).  Manifests whose members are already all gone
    are removed as dangling up front.

    Returns ``{"dir", "before_bytes", "after_bytes", "removed"}``.
    """
    if max_bytes < 0:
        raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
    root = resolve_cache_dir(cache_dir)
    report = {"dir": root, "before_bytes": 0, "after_bytes": 0, "removed": 0}
    if not root or not os.path.isdir(root):
        return report
    manifests = _load_manifests(root)
    grouped = {m for members in manifests.values() for m in members}
    entries = []  # (mtime, size, [paths])  — one tuple per LRU unit
    for name in os.listdir(root):
        path = os.path.join(root, name)
        try:
            if name.endswith(".tmp"):      # orphaned partial write
                os.remove(path)
                report["removed"] += 1
                continue
            if (not name.endswith(".xla") or name in grouped
                    or not os.path.isfile(path)):
                continue
            st = os.stat(path)
        except OSError:
            continue                       # vanished mid-sweep: skip
        entries.append((st.st_mtime, st.st_size, [path]))
    for mpath, members in manifests.items():
        group, mtime, size = [], 0.0, 0
        for member in members:
            path = os.path.join(root, member)
            try:
                st = os.stat(path)
            except OSError:
                continue                   # member already gone
            group.append(path)
            mtime = max(mtime, st.st_mtime)
            size += st.st_size
        if not group:                      # dangling manifest: clean up
            try:
                os.remove(mpath)
                report["removed"] += 1
            except OSError:
                pass
            continue
        try:
            size += os.stat(mpath).st_size
        except OSError:
            pass
        entries.append((mtime, size, group + [mpath]))
    total = sum(size for _, size, _ in entries)
    report["before_bytes"] = total
    entries.sort()                         # oldest (coldest) first
    for _, size, paths in entries:
        if total <= max_bytes:
            break
        removed_any = False
        for path in paths:                 # group eviction is atomic:
            try:                           # members first, manifest last
                os.remove(path)
                removed_any = True
            except OSError:
                continue
        if not removed_any:
            continue
        total -= size
        report["removed"] += 1
    report["after_bytes"] = total
    return report


def open_cache(explicit_dir: Optional[str]) -> Optional[ExecutableCache]:
    """Open the executable cache at ``explicit_dir`` (or the resolved
    default root); returns ``None`` when caching is disabled or the
    directory cannot be created."""
    root = resolve_cache_dir(explicit_dir)
    if not root:
        return None
    try:
        return ExecutableCache(root)
    except OSError:
        return None
