"""Persistent on-disk executable cache.

The paper measures compilation time as part of model load; here we
amortize it *across processes*: the ``"jit"``/``"pallas"`` targets lower
ahead-of-time via ``jax.jit(...).lower(...).compile()`` and the
resulting XLA executable is serialized (``jax.experimental.
serialize_executable``) under a key of ``structure_hash × weights ×
options × batch × jax-version × backend``.  A second process compiling
the same model loads the executable instead of re-running XLA.

Serialization is best-effort: any failure (old jax, cross-platform
blob, corrupt file) degrades to a normal compile — never to an error.
Entries are pickled, so the cache directory is trusted local state
(unlike ``repro.deserialize``, which must be safe on untrusted bytes);
point ``cache_dir``/``$REPRO_CACHE_DIR`` only at directories you own.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
from typing import Optional

import jax

_FORMAT_VERSION = 1

#: Env var capping the on-disk executable cache size in bytes; when set,
#: every ``store`` triggers an LRU sweep back under the cap.  Bucketed
#: executables multiply entries (one per batch bucket), so an unbounded
#: cache directory now grows much faster than it did pre-bucketing.
MAX_BYTES_ENV = "REPRO_CACHE_MAX_BYTES"


def cache_key(*parts: str) -> str:
    """Digest of the given parts plus everything environmental that
    invalidates an executable (jax version, backend platform)."""
    h = hashlib.sha256()
    for p in (f"v{_FORMAT_VERSION}", jax.__version__, jax.default_backend(),
              *parts):
        h.update(p.encode())
        h.update(b"\x00")
    return h.hexdigest()


def resolve_cache_dir(explicit: Optional[str]) -> Optional[str]:
    """Explicit option wins; else ``$REPRO_CACHE_DIR``; else disabled."""
    return explicit if explicit is not None else os.environ.get("REPRO_CACHE_DIR")


class ExecutableCache:
    """Pickle-per-entry directory cache of serialized XLA executables."""

    def __init__(self, root: str) -> None:
        self.root = root
        self.hits = 0
        self.misses = 0
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.xla")

    def load(self, key: str):
        """Return a loaded executable, or None on miss/failure."""
        path = self._path(key)
        if not os.path.exists(path):
            self.misses += 1
            return None
        try:
            from jax.experimental import serialize_executable as se
            with open(path, "rb") as f:
                payload = pickle.load(f)
            exe = se.deserialize_and_load(*payload)
            self.hits += 1
            try:
                # LRU recency: a hit refreshes the entry's mtime so the
                # size-capped sweep evicts cold entries, not hot ones.
                os.utime(path)
            except OSError:
                pass
            return exe
        except Exception:
            # Corrupt/stale entry: drop it and recompile.
            try:
                os.remove(path)
            except OSError:
                pass
            self.misses += 1
            return None

    def store(self, key: str, compiled) -> bool:
        """Serialize ``compiled`` under ``key``; atomic via rename."""
        try:
            from jax.experimental import serialize_executable as se
            payload = se.serialize(compiled)
            blob = pickle.dumps(payload)
        except Exception:
            return False
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass
            return False
        cap = os.environ.get(MAX_BYTES_ENV)
        if cap:
            try:
                prune(int(cap), self.root)
            except (OSError, ValueError):
                pass                       # the sweep is best-effort
        return True

    def stats(self) -> dict:
        """Hit/miss counters for this process plus the cache directory."""
        return {"dir": self.root, "hits": self.hits, "misses": self.misses}


def prune(max_bytes: int, cache_dir: Optional[str] = None) -> dict:
    """Size-capped LRU sweep of the persistent executable cache.

    Deletes least-recently-used ``.xla`` entries (mtime order — ``load``
    refreshes it on every hit) until the directory's entry bytes fit in
    ``max_bytes``, and clears out orphaned ``.tmp`` files from
    interrupted writes.  Corruption-safe by construction: entries are
    only ever whole files (writes go through an atomic rename), removal
    is whole-file, and a concurrently-vanishing file is skipped, so a
    reader racing the sweep sees either a valid entry or a clean miss —
    never a truncated one.

    Returns ``{"dir", "before_bytes", "after_bytes", "removed"}``.
    """
    if max_bytes < 0:
        raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
    root = resolve_cache_dir(cache_dir)
    report = {"dir": root, "before_bytes": 0, "after_bytes": 0, "removed": 0}
    if not root or not os.path.isdir(root):
        return report
    entries = []
    for name in os.listdir(root):
        path = os.path.join(root, name)
        try:
            if name.endswith(".tmp"):      # orphaned partial write
                os.remove(path)
                report["removed"] += 1
                continue
            if not name.endswith(".xla") or not os.path.isfile(path):
                continue
            st = os.stat(path)
        except OSError:
            continue                       # vanished mid-sweep: skip
        entries.append((st.st_mtime, st.st_size, path))
    total = sum(size for _, size, _ in entries)
    report["before_bytes"] = total
    entries.sort()                         # oldest (coldest) first
    for _, size, path in entries:
        if total <= max_bytes:
            break
        try:
            os.remove(path)
        except OSError:
            continue
        total -= size
        report["removed"] += 1
    report["after_bytes"] = total
    return report


def open_cache(explicit_dir: Optional[str]) -> Optional[ExecutableCache]:
    """Open the executable cache at ``explicit_dir`` (or the resolved
    default root); returns ``None`` when caching is disabled or the
    directory cannot be created."""
    root = resolve_cache_dir(explicit_dir)
    if not root:
        return None
    try:
        return ExecutableCache(root)
    except OSError:
        return None
