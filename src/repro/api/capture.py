"""Capture bundles — one directory that reproduces one compile.

``CompileOptions(capture=<dir>)`` (or ``$REPRO_CAPTURE_DIR``, a root
that gets one subdirectory per compile) makes :class:`JitExecutable`
record everything ``python -m repro.replay <bundle>`` needs to re-run
the compile offline and diff it against the record:

.. code-block:: text

    <bundle>/
      MANIFEST.json      format version, env fingerprint, sha256 of
                         every other file (tamper detection)
      graph.npz          the *input* graph (pre-pass), save_model format
      options.json       CompileOptions.to_dict()
      report.json        pass pipeline report + graph-decision report
      ir/NN-<pass>.txt   per-pass IR dumps (teed from dump_ir)
      tactics/<key>.json every tactic-cache entry the compile used —
                         kernel tactics and graph decisions — so replay
                         seeds a fresh cache and resolves identically
                         with autotune="cached"
      batches/<B>/
        selection.json   resolved kernel selection for batch B
        io.npz           seeded synthetic inputs + recorded outputs

The bundle is self-contained (weights included via ``graph.npz``) and
incremental: the manifest is rewritten after every record, so a bundle
from a crashed process is still replayable up to the last record.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Optional

import numpy as np

from ..core.graph import Graph
from ..frontends.container import save_model

#: Bundle layout version; replay refuses newer bundles.
CAPTURE_FORMAT_VERSION = 1

MANIFEST = "MANIFEST.json"

#: Environment variable naming a capture *root*: every compile writes a
#: bundle into a fresh ``<structhash12>-<target>`` subdirectory of it.
CAPTURE_DIR_ENV = "REPRO_CAPTURE_DIR"


def resolve_capture_dir(explicit: Optional[str], graph: Graph,
                        target: str) -> Optional[str]:
    """The bundle directory for one compile: an explicit
    ``CompileOptions.capture`` *is* the bundle dir; ``$REPRO_CAPTURE_DIR``
    is a root that gets a per-compile subdirectory (so a benchmark
    sweep run under the env var captures every config separately)."""
    if explicit:
        return explicit
    root = os.environ.get(CAPTURE_DIR_ENV)
    if not root:
        return None
    sub = f"{graph.structure_hash()[:12]}-{target}"
    return os.path.join(root, sub)


def seeded_inputs(graph: Graph, batch_size: int) -> Dict[str, np.ndarray]:
    """Deterministic synthetic inputs for one batch specialization —
    the same ``default_rng(0)`` convention the autotuner measures with,
    so capture and replay agree on the bytes without shipping real
    traffic."""
    rng = np.random.default_rng(0)
    out: Dict[str, np.ndarray] = {}
    for name, spec in graph.inputs.items():
        a = rng.standard_normal((batch_size,) + spec.shape)
        out[name] = a.astype(spec.dtype)
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CaptureSession:
    """Incrementally records one compile into a bundle directory.

    Created by :class:`~repro.api.targets.JitExecutable` when capture is
    enabled; every ``record_*`` call writes its files and refreshes the
    manifest, so the bundle is valid after each step."""

    def __init__(self, bundle_dir: str, graph: Graph, options,
                 *, lowering_target: str) -> None:
        from ..autotune.cache import environment_fingerprint

        self.dir = bundle_dir
        self.ir_dir = os.path.join(bundle_dir, "ir")
        os.makedirs(self.ir_dir, exist_ok=True)
        os.makedirs(os.path.join(bundle_dir, "tactics"), exist_ok=True)
        self._fingerprint = environment_fingerprint()
        self._report: dict = {}
        with open(os.path.join(bundle_dir, "graph.npz"), "wb") as f:
            save_model(graph, f)
        self._write_json("options.json", options.to_dict())
        self._write_json("report.json", self._report)
        self._meta = {"lowering_target": lowering_target,
                      "batches": []}
        self.refresh_manifest()

    # -- recording -----------------------------------------------------
    def _write_json(self, rel: str, obj) -> None:
        path = os.path.join(self.dir, rel)
        os.makedirs(os.path.dirname(path) or self.dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(obj, f, indent=2, sort_keys=True, default=str)

    def _store_tactics(self, entries: Optional[Dict[str, dict]]) -> None:
        """Persist raw tactic-cache entries (kernel or graph-decision)
        under ``tactics/<key>.json`` — exactly the on-disk format of
        :class:`~repro.autotune.cache.TacticCache`, so replay can copy
        them into a fresh cache directory verbatim."""
        for key, entry in (entries or {}).items():
            self._write_json(os.path.join("tactics", f"{key}.json"), entry)

    def record_pipeline(self, pass_report: dict,
                        decisions_report: Optional[dict]) -> None:
        """Record the pass pipeline outcome and the graph-decision
        report (winners + per-candidate µs), harvesting decision cache
        entries into ``tactics/``."""
        self._report["pipeline"] = list(pass_report.get("pipeline", ()))
        self._report["passes"] = [
            {k: v for k, v in row.items()} for row in
            pass_report.get("passes", [])]
        if decisions_report is not None:
            pub = {k: v for k, v in decisions_report.items()
                   if k != "entries"}
            self._report["graph_decisions"] = pub
            self._store_tactics(decisions_report.get("entries"))
        self._write_json("report.json", self._report)
        self.refresh_manifest()

    def record_batch(self, batch_size: int, selection,
                     autotune_report: Optional[dict],
                     inputs: Dict[str, np.ndarray],
                     outputs: Dict[str, np.ndarray]) -> None:
        """Record one batch specialization: the resolved kernel
        selection, its autotune report, and the seeded input / recorded
        output tensors replay diffs against."""
        rel = os.path.join("batches", str(batch_size))
        self._write_json(
            os.path.join(rel, "selection.json"),
            {name: choice.to_dict()
             for name, choice in sorted(selection.items())})
        if autotune_report is not None:
            pub = {k: v for k, v in autotune_report.items()
                   if k != "entries"}
            self._write_json(os.path.join(rel, "autotune.json"), pub)
            self._store_tactics(autotune_report.get("entries"))
        arrays = {f"in::{k}": np.asarray(v) for k, v in inputs.items()}
        arrays.update({f"out::{k}": np.asarray(v)
                       for k, v in outputs.items()})
        np.savez(os.path.join(self.dir, rel, "io.npz"), **arrays)
        if batch_size not in self._meta["batches"]:
            self._meta["batches"].append(batch_size)
        self.refresh_manifest()

    # -- manifest ------------------------------------------------------
    def refresh_manifest(self) -> None:
        """(Re)write MANIFEST.json with a sha256 of every bundle file —
        the tamper seal ``repro.replay`` verifies before trusting the
        record."""
        files = {}
        for root, _, names in os.walk(self.dir):
            for name in sorted(names):
                if name == MANIFEST:
                    continue
                path = os.path.join(root, name)
                rel = os.path.relpath(path, self.dir)
                files[rel] = _sha256(path)
        manifest = {
            "format": "repro-capture",
            "version": CAPTURE_FORMAT_VERSION,
            "fingerprint": self._fingerprint,
            **self._meta,
            "files": files,
        }
        tmp = os.path.join(self.dir, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
        os.replace(tmp, os.path.join(self.dir, MANIFEST))
