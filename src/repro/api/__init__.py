"""repro.api — the single compilation funnel.

    import repro
    exe = repro.compile(graph, repro.CompileOptions(target="jit"))
    out = exe(input=x)

One entry point (`compile`), one options object (`CompileOptions`), one
result protocol (`Executable`), a named-target registry, and a
persistent on-disk executable cache.  The legacy ``CompiledModel`` is a
deprecated shim over this package.
"""

from __future__ import annotations

from typing import Optional

from .. import frontends
from ..core.graph import Graph, Signature
from ..frontends import available_frontends, get_frontend, register_frontend
from ..frontends.trace import trace
from ..dist.mesh import MeshSpec, MeshUnavailableError
from ..runtime.buckets import Bucket, BucketPolicy
from ..serve.options import SchedulerOptions
from .cache import ExecutableCache, prune, resolve_cache_dir
from .executable import Executable, deserialize
from .options import CompileOptions
from .serve import serve
from .targets import (available_targets, get_target, register_target,
                      GraphExecutable, InterpretExecutable, JitExecutable)

_GRAPH_TARGET_HINT = (
    "graph-IR targets take a repro.core.Graph; pass "
    "CompileOptions(target='engine') to compile a framework-scale "
    "ArchConfig/Model"
)

#: Keyword args routed to the frontend registry, not CompileOptions.
_FRONTEND_KW = ("frontend", "specs", "example_inputs", "input_names",
                "outputs")


@register_target("engine")
def _build_engine(model_or_cfg, options: CompileOptions, **kw):
    from .engine_adapter import ModelExecutable  # lazy: pulls the model zoo
    return ModelExecutable(model_or_cfg, options, **kw)


def compile(model, options: Optional[CompileOptions] = None,
            **kw) -> Executable:
    """Compile ``model`` into an :class:`Executable`.

    ``model`` is a graph IR (:class:`repro.core.Graph`) — routed to the
    target named in ``options.target`` — a framework-scale
    ``ArchConfig``/``models.api.Model`` routed to the ``"engine"``
    adapter, or anything a registered frontend can normalize into a
    Graph: a ``ModelBuilder``, an ``.npz`` container path, or a bare
    callable (traced; pass ``example_inputs=`` — arrays with a batch
    dim — or ``specs=``; ``frontend=`` forces a specific frontend).
    Remaining keyword args override ``CompileOptions`` fields
    (``repro.compile(g, target="interpret")``), except ``params`` /
    ``init_seed`` which are forwarded to the engine adapter.
    """
    factory_kw = {k: kw.pop(k) for k in ("params", "init_seed") if k in kw}
    frontend_kw = {k: kw.pop(k) for k in _FRONTEND_KW if k in kw}
    if options is None:
        options = CompileOptions()
    if kw:
        options = options.replace(**kw)

    if not isinstance(model, Graph):
        is_cfg = hasattr(model, "family") and hasattr(model, "name")
        is_model = hasattr(model, "cfg") and hasattr(model, "forward")
        if is_cfg or is_model:
            if frontend_kw:
                raise TypeError(f"unexpected args for the engine target: "
                                f"{sorted(frontend_kw)}")
            if options.target != "engine":
                raise TypeError(
                    f"target {options.target!r}: {_GRAPH_TARGET_HINT}")
            return get_target("engine")(model, options, **factory_kw)
        # Everything else goes through the frontend registry (raises a
        # TypeError naming the registered frontends if nothing accepts).
        model = frontends.resolve(model, **frontend_kw)
    elif frontend_kw:
        raise TypeError(f"unexpected args for graph models: "
                        f"{sorted(frontend_kw)}")

    if options.target == "engine":
        raise TypeError("target='engine' compiles ArchConfig/Model, "
                        "not a graph IR; use 'jit'/'pallas'/'interpret'")
    if factory_kw:
        raise TypeError(f"unexpected args for graph targets: "
                        f"{sorted(factory_kw)}")
    if options.mesh is not None and options.target in ("jit", "pallas"):
        # Sharded compilation (repro.dist): the mesh is a compile-time
        # input; placement is propagated by the pass pipeline and the
        # result still subclasses JitExecutable, so bucketing below and
        # every other wrapper keep working.
        from ..dist.executable import ShardedExecutable
        exe = ShardedExecutable(model, options)
    else:
        exe = get_target(options.target)(model, options)
    if options.buckets is not None:
        # Shape-polymorphic dispatch: one warm program per batch bucket,
        # cold buckets compiled in the background (repro.runtime).
        if not isinstance(exe, JitExecutable):
            raise TypeError(
                f"buckets= requires a per-batch-compiling target "
                f"('jit'/'pallas'), not {options.target!r}")
        from ..runtime.bucketed import BucketedExecutable
        exe = BucketedExecutable(exe, options.buckets)
    return exe


__all__ = [
    "Bucket",
    "BucketPolicy",
    "CompileOptions",
    "Executable",
    "ExecutableCache",
    "GraphExecutable",
    "InterpretExecutable",
    "JitExecutable",
    "MeshSpec",
    "MeshUnavailableError",
    "Signature",
    "available_frontends",
    "available_targets",
    "compile",
    "deserialize",
    "get_frontend",
    "get_target",
    "register_frontend",
    "prune",
    "register_target",
    "resolve_cache_dir",
    "SchedulerOptions",
    "serve",
    "trace",
]
