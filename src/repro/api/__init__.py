"""repro.api — the single compilation funnel.

    import repro
    exe = repro.compile(graph, repro.CompileOptions(target="jit"))
    out = exe(input=x)

One entry point (`compile`), one options object (`CompileOptions`), one
result protocol (`Executable`), a named-target registry, and a
persistent on-disk executable cache.  The legacy ``CompiledModel`` is a
deprecated shim over this package.
"""

from __future__ import annotations

from typing import Optional

from ..core.graph import Graph
from ..serve.options import SchedulerOptions
from .cache import ExecutableCache, resolve_cache_dir
from .executable import Executable, deserialize
from .options import CompileOptions
from .serve import serve
from .targets import (available_targets, get_target, register_target,
                      GraphExecutable, InterpretExecutable, JitExecutable)

_GRAPH_TARGET_HINT = (
    "graph-IR targets take a repro.core.Graph; pass "
    "CompileOptions(target='engine') to compile a framework-scale "
    "ArchConfig/Model"
)


@register_target("engine")
def _build_engine(model_or_cfg, options: CompileOptions, **kw):
    from .engine_adapter import ModelExecutable  # lazy: pulls the model zoo
    return ModelExecutable(model_or_cfg, options, **kw)


def compile(model, options: Optional[CompileOptions] = None,
            **kw) -> Executable:
    """Compile ``model`` into an :class:`Executable`.

    ``model`` is either a graph IR (:class:`repro.core.Graph`) — routed
    to the target named in ``options.target`` — or a framework-scale
    ``ArchConfig``/``models.api.Model``, routed to the ``"engine"``
    adapter.  Remaining keyword args override ``CompileOptions`` fields
    (``repro.compile(g, target="interpret")``), except ``params`` /
    ``init_seed`` which are forwarded to the engine adapter.
    """
    factory_kw = {k: kw.pop(k) for k in ("params", "init_seed") if k in kw}
    if options is None:
        options = CompileOptions()
    if kw:
        options = options.replace(**kw)

    if isinstance(model, Graph):
        if options.target == "engine":
            raise TypeError("target='engine' compiles ArchConfig/Model, "
                            "not a graph IR; use 'jit'/'pallas'/'interpret'")
        if factory_kw:
            raise TypeError(f"unexpected args for graph targets: "
                            f"{sorted(factory_kw)}")
        return get_target(options.target)(model, options)

    is_cfg = hasattr(model, "family") and hasattr(model, "name")
    is_model = hasattr(model, "cfg") and hasattr(model, "forward")
    if not (is_cfg or is_model):
        raise TypeError(f"cannot compile {type(model).__name__}: expected "
                        f"a Graph, ArchConfig or Model")
    if options.target != "engine":
        raise TypeError(f"target {options.target!r}: {_GRAPH_TARGET_HINT}")
    return get_target("engine")(model, options, **factory_kw)


__all__ = [
    "CompileOptions",
    "Executable",
    "ExecutableCache",
    "GraphExecutable",
    "InterpretExecutable",
    "JitExecutable",
    "available_targets",
    "compile",
    "deserialize",
    "get_target",
    "register_target",
    "resolve_cache_dir",
    "SchedulerOptions",
    "serve",
]
