"""``repro.serve`` — the serving funnel over compiled executables.

    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    sched = repro.serve(exe, repro.SchedulerOptions(slots=8))

Takes an :class:`Executable` produced by the ``"engine"`` target (or
anything exposing ``model`` + ``params``) and returns a
:class:`repro.serve.Scheduler` — the continuous-batching step loop,
slot/KV-cache manager and per-request metrics live in
:mod:`repro.serve`; this module is only the API seam that pairs the
compiled artifact with a scheduling policy.
"""

from __future__ import annotations

from typing import Callable, Optional

from ..serve.options import SchedulerOptions
from ..serve.scheduler import Scheduler

_SERVE_HINT = (
    "repro.serve() drives framework-scale executables: compile with "
    "CompileOptions(target='engine') first (graph-IR executables are "
    "single-shot programs with no KV cache to schedule over)"
)


def _precision_report(executable) -> Optional[dict]:
    """Audit record for ``Scheduler.summary()["precision"]``: the
    compiled precision, the calibration setting, and — when the
    executable reports one — the per-site f32/bf16/int8 decision counts
    from its quantization report."""
    opts = getattr(executable, "options", None)
    prec = getattr(opts, "precision", None)
    if prec is None:
        return None
    info = {"precision": prec}
    if getattr(opts, "calibrate", None) is not None:
        info["calibrate"] = opts.calibrate
    try:
        quant = executable.cost_summary().get("quant")
    except Exception:
        quant = None
    if quant and quant.get("decisions"):
        info["decisions"] = dict(quant["decisions"])
    return info


def serve(executable, options: Optional[SchedulerOptions] = None, *,
          sampler: Optional[Callable] = None,
          clock: Optional[Callable[[], float]] = None,
          engine_worker: Optional[str] = None,
          device_source: Optional[Callable] = None,
          **kw) -> Scheduler:
    """Build a continuous-batching :class:`Scheduler` over ``executable``.

    ``executable`` must expose ``model`` (a ``models.api.Model``) and
    ``params`` — i.e. come from ``repro.compile(cfg,
    CompileOptions(target="engine"))``.  Remaining keyword args override
    ``SchedulerOptions`` fields (``repro.serve(exe, slots=8)``);
    ``sampler`` and ``clock`` are injection points for tests
    (deterministic token streams, fake time).

    When the executable was compiled with ``CompileOptions(mesh=...)``
    and the scheduler options leave ``mesh`` unset, the compile-time
    mesh carries over — the serving placement follows the compiled
    artifact unless explicitly overridden.
    """
    model = getattr(executable, "model", None)
    params = getattr(executable, "params", None)
    if model is None or params is None or not hasattr(model, "decode_step"):
        raise TypeError(
            f"cannot serve {type(executable).__name__}: {_SERVE_HINT}")
    if options is None:
        options = SchedulerOptions()
    if kw:
        options = options.replace(**kw)
    if options.mesh is None:
        compiled_mesh = getattr(getattr(executable, "options", None),
                                "mesh", None)
        if compiled_mesh is not None:
            options = options.replace(mesh=compiled_mesh)
    extra = {}
    if clock is not None:
        extra["clock"] = clock
    if engine_worker is not None:
        extra["engine_worker"] = engine_worker
    if device_source is not None:
        extra["device_source"] = device_source
    info = _precision_report(executable)
    if info is not None:
        extra["precision_info"] = info
    return Scheduler(model, params, options, sampler=sampler, **extra)
