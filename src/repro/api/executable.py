"""The Executable protocol — what ``repro.compile`` returns.

Every target produces an object with the same surface, so benchmarks,
examples, launch scripts and tests never care which backend they got:

    exe(**inputs)        -> dict of named outputs
    exe.compile_time     -> seconds spent compiling (None until first use
                            for lazily-specializing targets)
    exe.cost_summary()   -> static cost/report dict
    exe.serialize()      -> self-contained bytes
    deserialize(blob)    -> an equivalent Executable (recompiled, or
                            loaded from the persistent executable cache)

The serialized form is a small framed container: a magic line, a JSON
meta line (kind + CompileOptions), and an ``.npz`` body holding the
model itself — deliberately *source-level* (graph or params), with the
machine-code level handled by the on-disk executable cache keyed from
the same bytes, so a deserialized executable is correct on any backend
and merely *fast* to bring up on the one that populated the cache.
"""

from __future__ import annotations

import abc
import io
import json
from typing import Any, Dict, Optional

from ..core.graph import Signature
from .options import CompileOptions

MAGIC = b"REPROEXE1"
FORMAT = "repro-executable"
VERSION = 1


class Executable(abc.ABC):
    """Abstract base for all compiled artifacts."""

    options: CompileOptions
    compile_time: Optional[float]
    #: The model's public I/O contract: ordered, named inputs and
    #: outputs.  ``__call__`` binds arguments against it (positional or
    #: keyword) and keys the output dict by its output names.
    signature: Optional[Signature] = None

    @abc.abstractmethod
    def __call__(self, *args, **inputs) -> Dict[str, Any]:
        """Run inference; inputs bind positionally (signature order) or
        by keyword; returns a dict keyed by the signature's output
        names."""

    @abc.abstractmethod
    def cost_summary(self) -> Dict[str, Any]:
        """Static summary: nodes/params/memory plan/XLA cost terms."""

    @abc.abstractmethod
    def serialize(self) -> bytes:
        """Self-contained bytes; invert with :func:`deserialize`."""


# ---------------------------------------------------------------------------
def pack(kind: str, options: CompileOptions, body: bytes,
         extra: Optional[dict] = None) -> bytes:
    """Frame ``body`` in the artifact container: magic line, one JSON
    metadata line (format/version/kind/options + ``extra``), then the
    raw payload bytes."""
    meta = {"format": FORMAT, "version": VERSION, "kind": kind,
            "options": options.to_dict(), **(extra or {})}
    return MAGIC + b"\n" + json.dumps(meta, default=str).encode() + b"\n" + body


def unpack(data: bytes):
    """Split container bytes into ``(meta, body)``, validating magic,
    format and version; raises ``ValueError`` on anything malformed."""
    try:
        magic, meta_line, body = data.split(b"\n", 2)
    except ValueError:
        raise ValueError("not a repro executable container") from None
    if magic != MAGIC:
        raise ValueError(f"bad magic {magic!r}; expected {MAGIC!r}")
    meta = json.loads(meta_line.decode())
    if meta.get("format") != FORMAT:
        raise ValueError(f"unknown container format {meta.get('format')!r}")
    if meta.get("version", 0) > VERSION:
        raise ValueError(f"container version {meta['version']} too new")
    return meta, body


def deserialize(data: bytes) -> Executable:
    """Reconstruct an Executable from :meth:`Executable.serialize` bytes."""
    meta, body = unpack(data)
    options = CompileOptions.from_dict(meta["options"])
    # Never honor a cache_dir embedded in (possibly untrusted) bytes:
    # the cache pickle-loads from that directory.  None still falls
    # back to the local $REPRO_CACHE_DIR.  Same for dump_ir and
    # capture, which write files to an arbitrary path.
    options = options.replace(cache_dir=None, dump_ir=None, capture=None)
    kind = meta.get("kind")
    if kind in ("graph", "bucketed"):
        from ..frontends.container import load_model
        from . import compile as api_compile
        graph = load_model(io.BytesIO(body))
        if kind == "bucketed":
            # Manifest container: re-wrap with the serialized policy.
            # The per-bucket artifacts live in the persistent executable
            # cache; buckets present locally pre-warm at construction.
            from ..runtime.buckets import BucketPolicy
            options = options.replace(
                buckets=BucketPolicy.from_dict(meta["policy"]))
        return api_compile(graph, options)
    if kind == "sharded":
        # Sharded artifact: source graph + the resolved placement
        # (specs and collective edit log).  Construct the executable
        # directly with the stored placement so the propagation pass
        # replays it instead of re-deriving — the node list and
        # graph.dist come out byte-identical to the process that
        # serialized, so a warm executable cache hits with zero
        # recompiles.
        from ..dist.executable import ShardedExecutable
        from ..frontends.container import load_model
        graph = load_model(io.BytesIO(body))
        return ShardedExecutable(graph, options, resolved=meta["dist"])
    if kind == "engine":
        from .engine_adapter import deserialize_engine
        return deserialize_engine(meta, body, options)
    raise ValueError(f"unknown executable kind {kind!r}")
