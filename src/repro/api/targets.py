"""Target registry + the graph-IR lowering targets.

A *target* is a named factory ``(graph, options) -> Executable``.  The
three built-ins mirror the paper's cast:

    "interpret"  SimpleNN semantics — node-by-node eager oracle.
    "jit"        the optimized path: pass pipeline + one specialized
                 XLA program per batch size (CompiledNN's role).
    "pallas"     same front end, dense nodes routed through the fused
                 Pallas kernel (TPU; interpret-mode on CPU).

New backends register with::

    @register_target("my-backend")
    def build(graph, options):
        return MyExecutable(graph, options)
"""

from __future__ import annotations

import functools
import hashlib
import io
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph
from ..frontends.container import save_model
from ..core.lowering import execute_graph, lowering_fingerprint
from ..core.passes import run_pipeline
from ..core.selection import KernelChoice, select_kernels
from ..core.simple import SimpleNN
from .cache import cache_key, open_cache
from .executable import Executable, pack
from .options import QUANT_PRECISIONS, CompileOptions


def _quant_request(options: CompileOptions, *, measure: bool) -> Optional[dict]:
    """The quantization request a target rides on ``graph.quant`` for
    the quantize pass (None when ``options.precision`` is not a
    quantizing mode).  ``measure`` gates mixed-mode micro-benchmarks —
    the eager interpret target never measures; it reuses cached
    decisions so a jit-compile with the same cache dir stays the
    source of truth."""
    if options.precision not in QUANT_PRECISIONS \
            or options.precision == "f32":
        return None
    req = {"mode": options.precision,
           "calibrate": options.calibrate,
           "budget": options.precision_budget,
           "budget_ms": options.autotune_budget_ms,
           "cache_dir": options.cache_dir}
    req = {k: v for k, v in req.items() if v is not None}
    req["measure"] = measure and options.autotune != "cached"
    return req

TargetFactory = Callable[[Graph, CompileOptions], Executable]

_TARGETS: Dict[str, TargetFactory] = {}


def register_target(name: str) -> Callable[[TargetFactory], TargetFactory]:
    """Decorator: register a factory under ``name`` (overwrites)."""

    def deco(factory: TargetFactory) -> TargetFactory:
        _TARGETS[name] = factory
        return factory

    return deco


def get_target(name: str) -> TargetFactory:
    """Look up a registered target factory; KeyError lists what exists."""
    try:
        return _TARGETS[name]
    except KeyError:
        raise KeyError(
            f"unknown target {name!r}; available: {available_targets()}"
        ) from None


def available_targets() -> Tuple[str, ...]:
    """Sorted names of every registered compile target."""
    return tuple(sorted(_TARGETS))


# ---------------------------------------------------------------------------
class GraphExecutable(Executable):
    """Shared surface for graph-IR executables (source kept for
    serialization; subclasses own the actual lowering)."""

    def __init__(self, graph: Graph, options: CompileOptions) -> None:
        self.source = graph
        self.options = options
        self.signature = graph.signature()
        self.compile_time: Optional[float] = None

    def serialize(self) -> bytes:
        """Pack the source graph + options + signature into the portable
        artifact container (recompiled, not unpickled, on load)."""
        buf = io.BytesIO()
        save_model(self.source, buf)
        return pack("graph", self.options, buf.getvalue(),
                    extra={"signature": self.signature.to_dict()})

    def ensure_compiled(self, batch_size: int = 1) -> Callable:
        """Callable taking inputs positionally in signature order, with
        any per-batch specialization done up front.  Base implementation
        (eager targets) just forwards; JitExecutable overrides it with
        the AOT-compiled program."""
        return lambda *args: self(*args)

    def cache_info(self) -> dict:
        """Disk-cache counters; zeros for targets without one."""
        return {"dir": None, "hits": 0, "misses": 0}

    def _gather_inputs(self, pos, inputs) -> List[jnp.ndarray]:
        """Bind positional-or-keyword call args against the signature;
        returns arrays ordered by the graph's declared inputs."""
        inputs = self.signature.bind(pos, inputs)
        missing = [n for n in self.source.inputs if n not in inputs]
        if missing:
            raise ValueError(f"missing inputs {missing}; expected "
                             f"{list(self.source.inputs)}")
        unknown = [k for k in inputs if k not in self.source.inputs]
        if unknown:
            raise TypeError(f"unexpected inputs {unknown}; expected "
                            f"{list(self.source.inputs)}")
        args = []
        for n, spec in self.source.inputs.items():
            a = jnp.asarray(inputs[n])
            if a.shape[1:] != spec.shape:
                raise ValueError(
                    f"input {n!r}: expected (batch,)+{spec.shape}, "
                    f"got {a.shape}")
            args.append(a)
        return args

    def _public_outputs(self, out) -> dict:
        """Re-key an output dict from graph tensor names to the
        signature's public output names."""
        return {pub: out[t]
                for pub, t in zip(self.source.output_names,
                                  self.source.outputs)}


@register_target("interpret")
class InterpretExecutable(GraphExecutable):
    """The oracle as an Executable: exact, unoptimized, eager."""

    def __init__(self, graph: Graph, options: CompileOptions) -> None:
        super().__init__(graph, options)
        t0 = time.perf_counter()
        # Low-precision modes change the *semantics*, not just the
        # compilation strategy, so even the oracle honors them: run the
        # quantize pass alone (no fusion/layout — this target stays the
        # unoptimized reference) and interpret the annotated graph.
        nn_graph = graph
        self._quant_report: Optional[dict] = None
        req = _quant_request(options, measure=False)
        if req is not None:
            qg = graph.copy()
            qg.quant = req
            nn_graph, self._quant_report = run_pipeline(qg, ("quantize",))
        self._nn = SimpleNN(nn_graph)
        self.compile_time = time.perf_counter() - t0

    def __call__(self, *pos, **inputs):
        args = self._gather_inputs(pos, inputs)
        return self._public_outputs(
            self._nn(**dict(zip(self.source.inputs, args))))

    def cost_summary(self):
        """Source-graph counts only — the interpreter runs no passes
        (plus the quantization decision record under low precision)."""
        out = {
            "target": self.options.target,
            "nodes": len(self.source.nodes),
            "params": len(self.source.params),
            "param_bytes": int(sum(v.nbytes
                                   for v in self.source.params.values())),
        }
        if self._nn.graph.quant:
            out["quant"] = dict(self._nn.graph.quant)
        return out


class JitExecutable(GraphExecutable):
    """Pass pipeline + AOT-compiled XLA program per batch size, with the
    persistent on-disk executable cache.

    ``lowering_target`` names the lowering-rule registry slice to
    compile with (``"jit"`` uses only the generic rules; ``"pallas"``
    activates the Pallas-kernel overrides, gated per node by the static
    kernel selector).  ``use_pallas=True`` is the legacy spelling of
    ``lowering_target="pallas"``.
    """

    def __init__(self, graph: Graph, options: CompileOptions,
                 *, lowering_target: Optional[str] = None,
                 use_pallas: bool = False) -> None:
        super().__init__(graph, options)
        self.lowering_target = (lowering_target
                                or ("pallas" if use_pallas else "jit"))
        t0 = time.perf_counter()
        # Capture bundle (CompileOptions.capture / $REPRO_CAPTURE_DIR):
        # records the *input* graph, then tees IR dumps and selection
        # reports below.  self.capture_path is the bundle dir or None.
        from .capture import CaptureSession, resolve_capture_dir
        self.capture_path = resolve_capture_dir(
            options.capture, graph, self.lowering_target)
        self._capture = (CaptureSession(self.capture_path, graph, options,
                                        lowering_target=self.lowering_target)
                         if self.capture_path else None)
        # Graph-level decision tuning (repro.autotune.decisions): winners
        # land as tune.* attrs on a copy — self.source stays the
        # untouched input graph — and may swap the pass pipeline.  With
        # autotune="off" nothing runs and the compile is bit-identical
        # to the heuristic pipeline.
        self._decisions_report: Optional[dict] = None
        effective_graph, effective_passes = graph, options.passes
        if options.autotune != "off":
            from ..autotune import open_tactic_cache, tune_graph_decisions
            effective_graph, effective_passes, self._decisions_report = (
                tune_graph_decisions(
                    graph,
                    target=self.lowering_target,
                    precision=options.precision,
                    passes=options.passes,
                    mode=options.autotune,
                    budget_ms=options.autotune_budget_ms,
                    cache=open_tactic_cache(options.cache_dir)))
        # Low-precision request for the quantize pass: attached to a
        # copy (self.source stays the untouched input graph); the pass
        # consumes the request and leaves only the semantic record —
        # mode + quant.* node attrs — which flow into structure_hash()
        # and therefore the executable cache key for free.
        req = _quant_request(options, measure=True)
        if req is not None:
            if effective_graph is graph:
                effective_graph = graph.copy()
            effective_graph.quant = req
        dump_ir = options.dump_ir
        if self._capture is not None:
            from ..core.passes.manager import _resolve_dump_ir
            # Tee the per-pass IR into the bundle alongside any
            # user-requested sink (including $REPRO_DUMP_IR).
            dump_ir = list(_resolve_dump_ir(dump_ir)) + [self._capture.ir_dir]
        self.graph, self.report = run_pipeline(
            effective_graph, effective_passes, dump_ir=dump_ir)
        if self._capture is not None:
            self._capture.record_pipeline(self.report,
                                          self._decisions_report)
        self._pass_time = time.perf_counter() - t0
        # ensure_compiled may be entered from a BucketedExecutable's
        # background-compile worker concurrently with the request path;
        # one lock keeps the memo/compile/stat updates coherent.
        self._compile_lock = threading.RLock()
        self._fns: Dict[int, Callable] = {}
        self._selections: Dict[int, Dict[str, KernelChoice]] = {}
        self._autotune_reports: Dict[int, dict] = {}
        self._disk = open_cache(options.cache_dir)
        self._xla_cost: Optional[dict] = None
        self._weights_digest_memo: Optional[str] = None

    @property
    def use_pallas(self) -> bool:
        """True when dense ops lower through hand-written Pallas kernels."""
        return self.lowering_target == "pallas"

    # -- cache key -----------------------------------------------------
    def _weights_digest(self) -> str:
        if self._weights_digest_memo is None:
            h = hashlib.sha256()
            for k in sorted(self.graph.params):
                v = np.ascontiguousarray(self.graph.params[k])
                h.update(k.encode())
                h.update(str(v.shape).encode())
                h.update(v.tobytes())
            self._weights_digest_memo = h.hexdigest()
        return self._weights_digest_memo

    @staticmethod
    def _selection_token(selection: Dict[str, KernelChoice]) -> str:
        """Stable digest of the *resolved* kernel selection (kernel +
        block geometry per node).  Mixing this into the executable-cache
        key — instead of the autotune mode — means two compiles that
        resolve to the same kernels share one cached executable, and a
        new tactic measurement (different winner or block) misses
        cleanly instead of serving the old program."""
        payload = json.dumps(
            sorted((name, c.kernel, list(c.block) if c.block else None)
                   for name, c in selection.items()))
        return hashlib.sha256(payload.encode()).hexdigest()

    def _key(self, batch_size: int,
             selection: Optional[Dict[str, KernelChoice]] = None) -> str:
        weights = self._weights_digest() if self.options.embed_weights else ""
        return cache_key(self.graph.structure_hash(), weights,
                         self.options.cache_token(), f"batch={batch_size}",
                         f"sig={self.signature.cache_token()}",
                         f"rules={lowering_fingerprint(self.lowering_target)}",
                         f"sel={self._selection_token(selection or {})}")

    # -- sharding hooks (overridden by repro.dist.ShardedExecutable) ---
    def _lowering_extras(self) -> dict:
        """Extra ``execute_graph`` kwargs (mesh + shardings for sharded
        compiles); the unsharded base adds nothing."""
        return {}

    def _input_sharding(self, name: str, batch_size: int):
        """Sharding for the AOT input spec of graph input ``name``
        (None = let XLA choose, the unsharded default)."""
        return None

    def _wrap_compiled(self, fn: Callable, batch_size: int) -> Callable:
        """Post-compile hook around the AOT entry point (sharded
        executables re-place call arguments here)."""
        return fn

    # -- compilation ---------------------------------------------------
    def _resolve_selection(self, batch_size: int, *,
                           probe: bool = False):
        """Kernel selection for one batch specialization: the static
        heuristic prior, refined by the autotuner when enabled.  With
        ``probe=True`` the autotune mode is downgraded ``"full"`` →
        ``"cached"`` so probing a cache key never spends measurement
        budget (used by :meth:`disk_key` / bucket pre-warming)."""
        selection = select_kernels(
            self.graph, batch_size=batch_size,
            target=self.lowering_target,
            precision=self.options.precision)
        report = None
        if selection and self.options.autotune != "off":
            # Profile-guided refinement: measured tactics override the
            # heuristic prior; any failure leaves the prior untouched.
            from ..autotune import open_tactic_cache, tune_selection
            mode = ("cached" if probe and self.options.autotune == "full"
                    else self.options.autotune)
            # Graph-level decision tuning already spent part of the
            # budget at construction time; kernel tactics get the rest.
            budget = self.options.autotune_budget_ms
            if budget is not None and self._decisions_report is not None:
                budget = max(
                    0.0,
                    budget - self._decisions_report.get("spent_ms", 0.0))
            selection, report = tune_selection(
                self.graph, selection,
                batch_size=batch_size,
                precision=self.options.precision,
                mode=mode,
                budget_ms=budget,
                cache=open_tactic_cache(self.options.cache_dir))
        return selection, report

    def disk_key(self, batch_size: int) -> str:
        """The persistent-cache key this batch specialization resolves
        to today (autotune measurements are never triggered: in
        ``"full"`` mode the probe sees the cached tactics only)."""
        selection, _ = self._resolve_selection(batch_size, probe=True)
        return self._key(batch_size, selection)

    def has_disk_entry(self, batch_size: int) -> bool:
        """True if the persistent on-disk cache already holds the
        executable for this batch specialization."""
        if self._disk is None:
            return False
        import os
        return os.path.exists(self._disk._path(self.disk_key(batch_size)))

    def ensure_compiled(self, batch_size: int = 1) -> Callable:
        """Compile (or fetch) the program specialized to ``batch_size``;
        returns a callable taking inputs positionally in graph order."""
        if batch_size in self._fns:
            return self._fns[batch_size]
        with self._compile_lock:
            return self._compile_batch(batch_size)

    def _compile_batch(self, batch_size: int) -> Callable:
        if batch_size in self._fns:
            return self._fns[batch_size]
        t0 = time.perf_counter()
        input_names = list(self.graph.inputs)
        params = {k: jnp.asarray(v) for k, v in self.graph.params.items()}
        # Static kernel selection for this specialization: decided from
        # shapes before tracing, honored by the target lowering rules,
        # surfaced in cost_summary().
        selection, report = self._resolve_selection(batch_size)
        if report is not None:
            self._autotune_reports[batch_size] = report
        if selection:   # targets without kernel decisions stay silent
            self._selections[batch_size] = selection
        lower_kw = dict(precision=self.options.precision,
                        target=self.lowering_target,
                        batch_size=batch_size,
                        selection=selection,
                        **self._lowering_extras())
        in_specs = [
            jax.ShapeDtypeStruct((batch_size,) + self.graph.inputs[n].shape,
                                 self.graph.inputs[n].dtype,
                                 sharding=self._input_sharding(n, batch_size))
            for n in input_names
        ]

        if self.options.embed_weights:
            def program(*args):
                env = dict(zip(input_names, args))
                return execute_graph(self.graph, env, params, **lower_kw)

            donate = (tuple(range(len(input_names)))
                      if self.options.donate_inputs else ())
            specs = in_specs
            wrap = lambda exe: exe
        else:
            def program(param_arg, *args):
                env = dict(zip(input_names, args))
                return execute_graph(self.graph, env, param_arg, **lower_kw)

            donate = (tuple(range(1, len(input_names) + 1))
                      if self.options.donate_inputs else ())
            specs = [jax.eval_shape(lambda: params)] + in_specs
            wrap = lambda exe: functools.partial(exe, params)

        jitted = jax.jit(program, donate_argnums=donate)
        key = self._key(batch_size, selection)
        exe = self._disk.load(key) if self._disk else None
        if exe is None:
            exe = jitted.lower(*specs).compile()
            if self._disk:
                self._disk.store(key, exe)
        try:
            cost = exe.cost_analysis()
            self._xla_cost = cost[0] if isinstance(cost, list) else cost
        except Exception:
            pass
        fn = self._wrap_compiled(wrap(exe), batch_size)
        self._fns[batch_size] = fn
        if self._capture is not None:
            # Record this specialization: resolved selection, autotune
            # report, and one seeded forward pass replay can diff.
            from .capture import seeded_inputs
            ins = seeded_inputs(self.graph, batch_size)
            out = fn(*[jnp.asarray(v) for v in ins.values()])
            self._capture.record_batch(
                batch_size, selection or {}, report, ins,
                {k: np.asarray(v) for k, v in out.items()})
        # Total seconds spent compiling: pass pipeline once, plus every
        # per-batch-size XLA compile so far.
        base = (self.compile_time if self.compile_time is not None
                else self._pass_time)
        self.compile_time = base + (time.perf_counter() - t0)
        return fn

    # -- execution -----------------------------------------------------
    def _pick_bucket(self, batch: int) -> int:
        for b in self.options.batch_buckets:
            if b >= batch:
                return b
        return batch

    def __call__(self, *pos, **inputs):
        args = self._gather_inputs(pos, inputs)
        batch = args[0].shape[0]
        bucket = self._pick_bucket(batch)
        fn = self.ensure_compiled(bucket)
        if bucket != batch:
            args = [
                jnp.concatenate(
                    [a, jnp.zeros((bucket - batch,) + a.shape[1:], a.dtype)])
                for a in args
            ]
        out = fn(*args)
        if bucket != batch:
            out = {k: v[:batch] for k, v in out.items()}
        # Passes may rename output tensors (e.g. a fused terminal
        # activation); the public contract keys outputs by the
        # signature's names, identically across targets.
        return {pub: out[opt] for pub, opt in
                zip(self.source.output_names, self.graph.outputs)}

    # -- introspection -------------------------------------------------
    def cache_info(self) -> dict:
        """Executable disk-cache counters (zeros when caching is off)."""
        if self._disk is None:
            return super().cache_info()
        return self._disk.stats()

    def cost_summary(self):
        """Compile-time facts for this executable: pass reports, memory
        plan, per-batch kernel selections, and — when tuned — the
        autotune and graph-decision reports."""
        out = {
            "target": self.options.target,
            "nodes": len(self.graph.nodes),
            "params": len(self.graph.params),
            "param_bytes": int(sum(v.nbytes
                                   for v in self.graph.params.values())),
            "pipeline": self.report.get("pipeline"),
            "passes": self.report["passes"],
            "memory_plan": self.report["memory_plan"],
        }
        if self._selections:
            # Kernel-selector decisions, per compiled batch size; each
            # entry carries source ("heuristic"|"measured"), the block
            # geometry, and — for measured tactics — per-candidate µs.
            out["kernel_selection"] = {
                batch: [c.to_dict() for c in sel.values()]
                for batch, sel in sorted(self._selections.items())
            }
        if self._autotune_reports:
            # Raw cache "entries" are a capture-bundle implementation
            # detail; the human-facing report is everything else.
            out["autotune"] = {
                batch: {k: v for k, v in rep.items() if k != "entries"}
                for batch, rep in sorted(self._autotune_reports.items())
            }
        if self._decisions_report is not None:
            # Graph-level decisions (fusion/layout/pipeline winners with
            # per-candidate µs) — see repro.autotune.decisions.
            out["graph_decisions"] = {
                k: v for k, v in self._decisions_report.items()
                if k != "entries"}
        if self.graph.quant:
            # Quantization record: mode + per-precision site counts
            # (the quantize pass's decisions, measured or prior).
            out["quant"] = dict(self.graph.quant)
        if self._xla_cost:
            out["xla"] = {k: self._xla_cost[k]
                          for k in ("flops", "bytes accessed")
                          if k in self._xla_cost}
        return out


@register_target("jit")
def _build_jit(graph: Graph, options: CompileOptions) -> Executable:
    return JitExecutable(graph, options, lowering_target="jit")


@register_target("pallas")
def _build_pallas(graph: Graph, options: CompileOptions) -> Executable:
    return JitExecutable(graph, options, lowering_target="pallas")
