"""Deterministic synthetic token pipeline — shard-aware, restart-safe.

Real deployments stream tokenized shards; this environment has no
corpus, so the pipeline synthesizes a *deterministic* stream: batch
contents are a pure function of (seed, step, position), which gives the
two properties fault tolerance needs for free:

* **skip-on-restart**: resuming from step k just means asking for
  batch(k) — no iterator state to checkpoint;
* **shard-awareness**: a host that owns rows [lo, hi) of the global
  batch generates exactly those rows (`host_slice`), so no host ever
  materializes the global batch.

The token distribution is a Zipf-ish mixture with enough sequential
structure (a noisy copy task) that a ~100M model's loss visibly drops
within a few hundred steps — used by examples/train_lm.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    copy_period: int = 64      # structure: tokens repeat with this period
    noise: float = 0.1


class SyntheticTokens:
    """batch(step) -> {"tokens": (B,S) int32, "labels": (B,S) int32}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _row(self, step: int, row: int) -> np.ndarray:
        """One sequence, a pure function of (seed, step, absolute row) —
        the property that makes host sharding and restart-skipping
        trivially consistent."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row]))
        base = (rng.zipf(1.5, size=cfg.copy_period) - 1) % cfg.vocab
        reps = -(-(cfg.seq_len + 1) // cfg.copy_period)
        seq = np.tile(base, reps)[: cfg.seq_len + 1]
        mask = rng.random(seq.shape) < cfg.noise
        return np.where(mask, rng.integers(0, cfg.vocab, seq.shape), seq)

    def batch(self, step: int, row_lo: int = 0,
              row_hi: Optional[int] = None) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        row_hi = cfg.global_batch if row_hi is None else row_hi
        seq = np.stack([self._row(step, r) for r in range(row_lo, row_hi)])
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}

    def host_slice(self, step: int, host_id: int, n_hosts: int):
        """The rows this host owns of the global batch at `step`."""
        per = self.cfg.global_batch // n_hosts
        lo = host_id * per
        return self.batch(step, lo, lo + per)
