"""ShardedExecutable — a JitExecutable whose mesh is a compile input.

Built by ``repro.compile`` whenever ``CompileOptions(mesh=...)`` is set
on the ``"jit"``/``"pallas"`` targets.  It rides the entire existing
machinery — pass pipeline, kernel selection, persistent executable
cache, capture bundles — and adds exactly three things through the
:class:`~repro.api.targets.JitExecutable` sharding hooks:

* the ``propagate_sharding`` pass input: ``graph.dist`` carries the
  mesh spec + rules in, and the resolved per-tensor specs + collective
  edit log out;
* sharded lowering: AOT input specs get ``NamedSharding``s, every
  traced tensor its propagated constraint (``execute_graph``), and call
  arguments are re-placed with ``device_put`` so the AOT program's
  committed input shardings are always satisfied;
* a manifest in the executable cache grouping the per-batch artifacts,
  so ``repro.prune`` evicts a sharded executable atomically.

Mesh + shardings are part of both the persistent cache key (via
``graph.dist`` in ``structure_hash`` and ``mesh``/``sharding_rules`` in
``CompileOptions.cache_token``) and the ``serialize()`` manifest — a
second process deserializing the artifact replays the placement with
zero re-propagation and hits the warm cache with zero recompiles.
"""

from __future__ import annotations

import io
import json
import os
from typing import Callable, Optional

import jax

from ..api.executable import pack
from ..api.targets import JitExecutable
from ..frontends.container import save_model
from .mesh import MeshSpec, ensure_mesh_available
from .propagate import collective_summary


class ShardedExecutable(JitExecutable):
    """Mesh-aware compiled artifact: per-tensor PartitionSpecs, explicit
    collectives, and a device mesh bound at call time.

    A single-device mesh is bit-identical to the unsharded
    ``JitExecutable`` path: every collective lowers to the identity and
    every constraint is trivial.
    """

    def __init__(self, graph, options, *,
                 lowering_target: Optional[str] = None,
                 resolved: Optional[dict] = None) -> None:
        if options.mesh is None:
            raise ValueError("ShardedExecutable needs CompileOptions(mesh=...)")
        spec: MeshSpec = options.mesh
        # Fail before compiling, with the unfillable axes named —
        # never an opaque XLA device error.
        ensure_mesh_available(spec)
        self._mesh_spec = spec
        self._mesh = None
        annotated = graph.copy()
        annotated.dist = {"mesh": spec.to_dict(),
                          "rules": [list(p) for p in
                                    (options.sharding_rules or ())]}
        if resolved is not None:
            # Manifest round-trip: replay the recorded placement
            # instead of re-propagating (see dist.propagate._replay).
            annotated.dist["resolved"] = resolved
        super().__init__(annotated, options,
                         lowering_target=lowering_target
                         or ("pallas" if options.target == "pallas"
                             else "jit"))

    # -- mesh ----------------------------------------------------------
    @property
    def mesh_spec(self) -> MeshSpec:
        """The static mesh description this executable was compiled for."""
        return self._mesh_spec

    @property
    def mesh(self):
        """The live ``jax.sharding.Mesh`` (built lazily; raises
        ``MeshUnavailableError`` if the device set shrank)."""
        if self._mesh is None:
            self._mesh = self._mesh_spec.build()
        return self._mesh

    def partition_spec(self, name: str):
        """The resolved (batch-inclusive) ``PartitionSpec`` of a graph
        tensor — or of a public output name."""
        from jax.sharding import PartitionSpec
        shardings = self.graph.dist["shardings"]
        if name not in shardings:
            public = dict(zip(self.source.output_names, self.graph.outputs))
            if name in public:
                name = public[name]
        entry = shardings.get(name)
        if entry is None:
            raise KeyError(f"no resolved sharding for tensor {name!r}; "
                           f"known: {sorted(shardings)[:8]}...")
        return PartitionSpec(*(
            None if not axes else (axes[0] if len(axes) == 1
                                   else tuple(axes))
            for axes in entry))

    # -- sharding hooks (consumed by JitExecutable._compile_batch) -----
    def _lowering_extras(self) -> dict:
        return {"mesh": self.mesh,
                "shardings": self.graph.dist["shardings"]}

    def _input_sharding(self, name: str, batch_size: int):
        from jax.sharding import NamedSharding, PartitionSpec
        entry = self.graph.dist["shardings"].get(name) or []
        sizes = dict(self.mesh.shape)
        shape = (batch_size,) + self.graph.inputs[name].shape
        parts = []
        for dim, axes in zip(shape, entry):
            axes = [a for a in (axes or ()) if a in sizes]
            k = 1
            for a in axes:
                k *= sizes[a]
            if k <= 1 or dim % k:
                parts.append(None)
            else:
                parts.append(axes[0] if len(axes) == 1 else tuple(axes))
        return NamedSharding(self.mesh, PartitionSpec(*parts))

    def _wrap_compiled(self, fn: Callable, batch_size: int) -> Callable:
        # An AOT-compiled program rejects committed arguments whose
        # placement disagrees with its input shardings; re-placing with
        # device_put is a no-op when they already agree.
        shardings = [self._input_sharding(n, batch_size)
                     for n in self.graph.inputs]
        self._record_manifest(batch_size)

        def call(*args):
            placed = [jax.device_put(a, s) for a, s in zip(args, shardings)]
            return fn(*placed)

        return call

    # -- cache manifest (repro.prune atomic groups) --------------------
    def manifest_key(self) -> str:
        """Identity of this executable's cache-manifest group (all batch
        specializations of one sharded compile)."""
        from ..api.cache import cache_key
        return cache_key("shard-manifest", self.graph.structure_hash(),
                         self.options.cache_token())

    def _record_manifest(self, batch_size: int) -> None:
        """Append this batch's artifact key to the on-disk manifest, so
        ``repro.prune`` treats the per-batch entries + manifest as one
        atomic LRU group (best-effort, like the cache itself)."""
        if self._disk is None:
            return
        path = os.path.join(self._disk.root,
                            f"{self.manifest_key()}.manifest.json")
        try:
            doc = {"mesh": self._mesh_spec.to_dict(), "members": []}
            if os.path.exists(path):
                with open(path) as f:
                    doc = json.load(f)
            key = self._key(batch_size,
                            self._selections.get(batch_size) or {})
            if key not in doc["members"]:
                doc["members"].append(key)
            tmp = f"{path}.tmp"
            with open(tmp, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, path)
        except (OSError, ValueError):
            pass

    # -- introspection / serialization ---------------------------------
    def cost_summary(self):
        """Compile-time facts plus a ``"sharding"`` block: mesh, device
        count, per-axis collective counts / bytes-moved estimates, and
        the number of tensors with resolved specs."""
        out = super().cost_summary()
        out["sharding"] = {
            "mesh": self._mesh_spec.describe(),
            "devices": self._mesh_spec.size,
            "collectives": collective_summary(self.graph, self._mesh_spec),
            "tensors": len(self.graph.dist.get("shardings", {})),
        }
        return out

    def serialize(self) -> bytes:
        """Artifact container of kind ``"sharded"``: the source graph
        plus the resolved placement (specs + collective edit log), so
        ``repro.deserialize`` reconstructs it with zero
        re-propagation."""
        buf = io.BytesIO()
        save_model(self.source, buf)
        dist = self.graph.dist
        return pack("sharded", self.options, buf.getvalue(),
                    extra={"signature": self.signature.to_dict(),
                           "dist": {"shardings": dist["shardings"],
                                    "edits": dist["edits"]}})
