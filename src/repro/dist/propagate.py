"""Sharding propagation — per-tensor PartitionSpecs from logical rules.

The compile-time half of the subsystem.  ``propagate_shardings`` reads
the mesh + logical-axis rules a :class:`ShardedExecutable` attached to
``graph.dist``, walks the optimized graph once, and

* decides a placement for every tensor: the batch dim shards over the
  ``batch``-rule mesh axes (pure data parallelism), and dense layers go
  Megatron-style tensor-parallel over the ``mlp``-rule axes — column
  parallel (cout sharded) when the channel count divides the model-axis
  size, row parallel (contraction over an already-sharded channel dim)
  when the producer left the input sharded;
* inserts the collectives that placement implies as first-class graph
  nodes — a ``psum`` closing every row-parallel contraction, an
  ``all_gather`` in front of every op that needs the channel dim whole
  (softmax, flatten/reshape, convs, mismatched elementwise) and every
  graph output;
* records the result in ``graph.dist["shardings"]`` — one
  batch-*inclusive* axis list per tensor, JSON-plain so it round-trips
  through the artifact manifest byte-for-byte.

Everything here is advisory at the value level: the collectives lower
to identities and the specs become ``with_sharding_constraint`` calls
(see ``execute_graph``), so XLA's SPMD partitioner supplies the actual
communication and numerics are mesh-independent by construction.

``check_shardings`` is the pipeline verifier hook: after every pass the
:class:`~repro.core.passes.manager.PassManager` re-checks (like shape
inference) that collective attrs name real mesh axes and — once the
propagation pass has run — that every live tensor has a resolved spec
of the right rank.

A deserialized manifest injects its stored placement as
``graph.dist["resolved"]`` — the spec table plus the exact graph edits
(inserted collectives, input rewires, final outputs) the original
propagation made.  ``propagate_shardings`` then *replays* the edits
mechanically instead of re-deriving anything, so a second process
reconstructs placement with **zero re-propagation** and ends up with a
byte-identical ``graph.dist`` / node list — i.e. the same persistent
executable-cache key, hence zero recompiles on a warm cache.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from ..core.graph import Graph
from ..distributed.sharding import DEFAULT_RULES
from .collectives import COLLECTIVE_OPS, axis_names
from .mesh import MeshSpec


class ShardingError(ValueError):
    """A sharding annotation is inconsistent with the graph or mesh."""


#: Ops that keep their input's channel (last-dim) sharding: elementwise
#: or spatial-only, so a sharded channel dim passes straight through.
PRESERVE_LAST = frozenset({
    "batchnorm", "maxpool2d", "avgpool2d", "upsample2d", "zero_pad2d",
    "global_avg_pool",
})


def _norm_axes(value) -> Tuple[str, ...]:
    """A rule value (str or sequence of str) as a tuple of axis names."""
    if value is None:
        return ()
    if isinstance(value, str):
        return (value,)
    return tuple(str(v) for v in value)


def merged_rules(overrides=None) -> Dict[str, Tuple[str, ...]]:
    """``DEFAULT_RULES`` with ``overrides`` applied on top.

    ``overrides`` accepts a mapping or ``(logical, axes)`` pairs (the
    normalized form ``CompileOptions.sharding_rules`` stores); values
    are a mesh-axis name or sequence of names; ``None`` deletes the
    rule (forces replication for that logical axis).
    """
    rules = {k: _norm_axes(v) for k, v in DEFAULT_RULES.items()}
    if overrides:
        items = overrides.items() if hasattr(overrides, "items") else overrides
        for k, v in items:
            if v is None:
                rules.pop(str(k), None)
            else:
                rules[str(k)] = _norm_axes(v)
    return rules


def _rules_pairs(rules: Dict[str, Tuple[str, ...]]) -> List[list]:
    """Rules as sorted JSON-plain pairs (the form stored on
    ``graph.dist`` and in the artifact manifest)."""
    return [[k, list(v)] for k, v in sorted(rules.items())]


def _axes_for(logical: str, rules: Dict[str, Tuple[str, ...]],
              mesh: MeshSpec) -> Tuple[str, ...]:
    """Mesh axes the logical axis ``logical`` shards over: the rule's
    axes filtered to ones this mesh actually has, deduplicated."""
    out: List[str] = []
    names = set(mesh.names)
    for ax in rules.get(logical, ()):
        if ax in names and ax not in out:
            out.append(ax)
    return tuple(out)


def _axes_size(axes, mesh: MeshSpec) -> int:
    return math.prod(mesh.axis_size(a) for a in axes) if axes else 1


def _normalize_shardings(shardings: Dict[str, list]) -> Dict[str, list]:
    """Canonical JSON-plain form: every entry a list of axis-name lists
    or None, so a fresh propagation and a manifest round-trip produce
    byte-identical ``graph.dist`` (and hence the same cache key)."""
    out = {}
    for t, entry in shardings.items():
        out[str(t)] = [None if e is None else [str(a) for a in e]
                       for e in entry]
    return out


def _fresh_name(graph: Graph, base: str) -> str:
    """A node name whose output tensor name is unused in the graph."""
    name, i = base, 1
    while f"{name}:out" in graph._producers or f"{name}:out" in graph.inputs:
        name = f"{base}{i}"
        i += 1
    return name


def propagate_shardings(graph: Graph) -> Dict[str, object]:
    """Annotate ``graph`` (in place) with per-tensor shardings and the
    collectives they imply; returns pass stats.

    Expects ``graph.dist`` to carry ``{"mesh": ..., "rules": ...}`` (as
    set by :class:`ShardedExecutable`); leaves it carrying the
    normalized ``{"mesh", "rules", "shardings"}`` triple.
    """
    dist = getattr(graph, "dist", None)
    if not dist:
        return {"sharded": False}
    mesh = MeshSpec.coerce(dist["mesh"])
    rules = merged_rules(dist.get("rules"))
    dist["mesh"] = mesh.to_dict()
    dist["rules"] = _rules_pairs(rules)

    resolved = dist.pop("resolved", None)
    if resolved is not None:
        return _replay(graph, dist, resolved)

    model_axes = _axes_for("mlp", rules, mesh)
    batch_axes = tuple(a for a in _axes_for("batch", rules, mesh)
                       if a not in model_axes)
    model_size = _axes_size(model_axes, mesh)

    specs = graph.infer_shapes()
    #: tensor -> mesh axes its LAST dim is sharded over (() = whole).
    part: Dict[str, Tuple[str, ...]] = {t: () for t in graph.inputs}
    #: tensor -> replacement every later consumer must read instead
    #: (set when a psum closes a row-parallel partial sum).
    alias: Dict[str, str] = {}
    #: tensor -> its all_gather'ed copy (dedup across consumers).
    gathered: Dict[str, str] = {}
    #: The edit log: everything the walk changes, recorded so a
    #: deserialized manifest can replay placement without re-deriving it.
    edits: Dict[str, object] = {"inserted": [], "rewires": {}, "outputs": []}

    def insert(op: str, base: str, inputs: List[str], attrs: dict) -> str:
        name = _fresh_name(graph, base)
        out = graph.add_node(op, name, inputs, attrs=attrs)
        edits["inserted"].append({
            "op": op, "name": name, "inputs": list(inputs),
            "output": out, "attrs": attrs})
        return out

    def gather(t: str) -> str:
        """The replicated view of sharded tensor ``t`` (memoized)."""
        if t in gathered:
            return gathered[t]
        axes = part[t]
        prod = graph.producer(t)
        out = insert(
            "all_gather", f"{prod.name if prod else t}.gather", [t],
            {"axis": list(axes), "dim": -1,
             "axis_size": _axes_size(axes, mesh)})
        part[out] = ()
        gathered[t] = out
        return out

    for node in list(graph.toposort()):
        orig_inputs = list(node.inputs)
        node.inputs = [alias.get(t, t) for t in node.inputs]
        op = node.op
        ins_part = [part.get(t, ()) for t in node.inputs]
        out = node.output

        if op in COLLECTIVE_OPS:
            # Hand-inserted collective: trust its declared effect.
            if op == "reduce_scatter":
                part[out] = axis_names(node)
            elif op == "ppermute":
                part[out] = ins_part[0]
            else:
                part[out] = ()
        elif op == "dense":
            if ins_part[0]:
                # Row parallel: the contraction runs over a sharded
                # channel dim, so each shard holds a partial sum — a
                # psum closes it and every later consumer reads the
                # reduced value.
                axes = ins_part[0]
                red = insert(
                    "psum", f"{node.name}.psum", [out],
                    {"axis": list(axes),
                     "axis_size": _axes_size(axes, mesh)})
                part[out] = ()
                part[red] = ()
                alias[out] = red
            elif (model_size > 1
                    and specs[out].shape[-1] % model_size == 0
                    and node.epilogue != "softmax"
                    and "orig_cout" not in node.attrs):
                # Column parallel: shard cout (the kernel splits for
                # free — weights are compile-time constants).  Padded
                # (orig_cout) and softmax-epilogue denses stay whole:
                # slicing/softmax need the full channel dim.
                part[out] = model_axes
            else:
                part[out] = ()
        elif op == "activation":
            if node.attrs.get("fn") == "softmax" and ins_part[0]:
                node.inputs[0] = gather(node.inputs[0])
            part[out] = part.get(node.inputs[0], ())
        elif op in PRESERVE_LAST:
            part[out] = ins_part[0]
        elif op in ("add", "mul"):
            if ins_part[0] != ins_part[1]:
                node.inputs = [gather(t) if part.get(t) else t
                               for t in node.inputs]
            part[out] = part.get(node.inputs[0], ())
        elif op == "concat":
            rank = len(specs[out].shape)
            same = all(p == ins_part[0] for p in ins_part)
            if node.attrs["axis"] == rank - 1 or not same:
                node.inputs = [gather(t) if part.get(t) else t
                               for t in node.inputs]
            part[out] = part.get(node.inputs[0], ())
        else:
            # Conservative default (convs, flatten, reshape, softmax,
            # decode_attention, plug-ins): these need the channel dim
            # whole — gather any sharded input, output replicated.
            node.inputs = [gather(t) if part.get(t) else t
                           for t in node.inputs]
            part[out] = ()

        if node.inputs != orig_inputs:
            edits["rewires"][node.name] = list(node.inputs)

    # Graph outputs are the public contract: always whole.
    graph.outputs = [alias.get(t, t) for t in graph.outputs]
    graph.outputs = [gather(t) if part.get(t) else t for t in graph.outputs]
    edits["outputs"] = list(graph.outputs)

    inserted = len(edits["inserted"])
    if inserted:
        graph.nodes = graph.toposort()
    graph.rebuild_index()

    specs = graph.infer_shapes()
    shardings: Dict[str, list] = {}
    batch_entry = list(batch_axes) if batch_axes else None
    for t, spec in specs.items():
        entry: List[Optional[list]] = [batch_entry] + [None] * len(spec.shape)
        if spec.shape and part.get(t):
            entry[-1] = list(part[t])
        shardings[t] = entry
    dist["shardings"] = _normalize_shardings(shardings)
    dist["edits"] = edits
    return {"sharded": True, "reused": False, "collectives": inserted}


def _replay(graph: Graph, dist: dict, resolved: dict) -> Dict[str, object]:
    """Re-apply a serialized placement: insert the recorded collectives,
    rewire the recorded inputs, restore the recorded outputs, and adopt
    the stored spec table — no propagation logic runs.  Ends with the
    same node list and ``graph.dist`` as the original compile, so the
    persistent-cache key matches and the warm cache hits."""
    edits = resolved.get("edits") or {"inserted": [], "rewires": {},
                                      "outputs": list(graph.outputs)}
    for nd in edits["inserted"]:
        graph.add_node(nd["op"], nd["name"], nd["inputs"],
                       output=nd["output"], attrs=nd["attrs"])
    by_name = {n.name: n for n in graph.nodes}
    for name, new_inputs in edits["rewires"].items():
        node = by_name.get(name)
        if node is None:
            raise ShardingError(
                f"sharding manifest rewires unknown node {name!r}")
        node.inputs = list(new_inputs)
    graph.outputs = list(edits["outputs"])
    if edits["inserted"]:
        graph.nodes = graph.toposort()
    graph.rebuild_index()
    dist["shardings"] = _normalize_shardings(resolved["shardings"])
    dist["edits"] = {"inserted": [dict(d) for d in edits["inserted"]],
                     "rewires": {k: list(v)
                                 for k, v in edits["rewires"].items()},
                     "outputs": list(edits["outputs"])}
    return {"sharded": True, "reused": True,
            "collectives": len(edits["inserted"])}


def check_shardings(graph: Graph) -> None:
    """Pipeline-verifier hook: validate ``graph.dist`` against the graph.

    Cheap invariants, re-checked after every pass like shape inference:
    collective nodes name real mesh axes, and — once ``shardings`` is
    resolved — every live tensor has a spec whose rank matches its
    (batch-inclusive) shape and whose axes exist on the mesh.  Raises
    :class:`ShardingError`.
    """
    dist = getattr(graph, "dist", None)
    if not dist:
        return
    mesh = MeshSpec.coerce(dist["mesh"])
    names = set(mesh.names)
    for node in graph.nodes:
        if node.op in COLLECTIVE_OPS:
            for ax in axis_names(node):
                if ax not in names:
                    raise ShardingError(
                        f"collective {node.name!r} ({node.op}) names mesh "
                        f"axis {ax!r}; mesh has {sorted(names)}")
    shardings = dist.get("shardings")
    if shardings is None:
        return
    specs = graph.infer_shapes()
    for t, spec in specs.items():
        entry = shardings.get(t)
        if entry is None:
            raise ShardingError(f"tensor {t!r} has no resolved sharding")
        if len(entry) != len(spec.shape) + 1:
            raise ShardingError(
                f"tensor {t!r}: sharding rank {len(entry)} != "
                f"batch-inclusive rank {len(spec.shape) + 1}")
        for e in entry:
            for ax in (e or ()):
                if ax not in names:
                    raise ShardingError(
                        f"tensor {t!r} sharded over unknown mesh axis "
                        f"{ax!r}; mesh has {sorted(names)}")


def collective_summary(graph: Graph, mesh=None,
                       batch_size: int = 1) -> Dict[str, object]:
    """Static per-axis collective counts and bytes-moved estimates.

    Ring-algorithm estimates per collective over ``k`` devices on
    ``n``-byte tensors: psum moves ``2n(k-1)/k`` (reduce-scatter +
    all-gather), all_gather / reduce_scatter ``n(k-1)/k``, ppermute
    ``n/k`` (one shard hop).  Multi-axis collectives split the estimate
    evenly across their axes.
    """
    dist = getattr(graph, "dist", None)
    if mesh is None and dist:
        mesh = MeshSpec.coerce(dist["mesh"])
    mesh = MeshSpec.coerce(mesh) if mesh is not None else None
    specs = graph.infer_shapes()
    counts: Dict[str, int] = {}
    per_axis: Dict[str, Dict[str, float]] = {}
    total = 0.0
    for node in graph.nodes:
        if node.op not in COLLECTIVE_OPS:
            continue
        counts[node.op] = counts.get(node.op, 0) + 1
        axes = axis_names(node)
        if mesh is not None:
            k = _axes_size(axes, mesh)
        else:
            k = int(node.attrs.get("axis_size", 1))
        n = specs[node.output].nbytes * max(batch_size, 1)
        if k <= 1:
            moved = 0.0
        elif node.op == "psum":
            moved = 2.0 * n * (k - 1) / k
        elif node.op == "ppermute":
            moved = n / k
        else:
            moved = n * (k - 1) / k
        total += moved
        for ax in axes:
            slot = per_axis.setdefault(ax, {"count": 0, "bytes": 0.0})
            slot["count"] += 1
            slot["bytes"] += moved / max(len(axes), 1)
    return {"counts": counts, "per_axis": per_axis,
            "total_bytes": int(total)}
