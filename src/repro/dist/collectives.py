"""Collectives as first-class graph ops.

``psum`` / ``all_gather`` / ``reduce_scatter`` / ``ppermute`` register
through the ordinary plug-in op machinery (``register_op`` +
``register_shape_rule`` + ``@register_lowering``), so every target —
the interpret oracle included — handles them and ``cost_summary()``
counts them like any other node.

Their *value* semantics are target-independent by construction, which
is what keeps a single-device mesh bit-identical to the unsharded path:

* ``psum``, ``all_gather`` and ``reduce_scatter`` are logical
  identities.  They mark the points where the propagated placement
  changes — the lowering re-applies the tensor's sharding constraint
  there (see ``execute_graph``), and XLA's SPMD partitioner materializes
  the actual all-reduce / all-gather / reduce-scatter on a real mesh.
  The TensorRT/NCCL-converter shape: collectives are ordinary ops in
  the graph, the runtime decides the wire traffic.
* ``ppermute`` rolls the tensor by whole shards along ``dim``:
  ``shift`` shard-blocks of ``size/axis_size`` elements.  With
  ``axis_size`` 1 (no mesh, or a degenerate axis) the roll is a full
  revolution — the identity — so the same graph runs everywhere.

Attrs:

    psum            axis            mesh axis (or list of axes) reduced over
    all_gather      axis, dim      gather ``dim`` back from ``axis``
    reduce_scatter  axis, dim      scatter ``dim`` across ``axis``
    ppermute        axis, shift    roll by ``shift`` shards along ``dim``
                                   (optional attrs: dim=-1, axis_size)
"""

from __future__ import annotations

import jax.numpy as jnp

from ..core.graph import register_op, register_shape_rule
from ..core.lowering import register_lowering

#: op name -> required attrs, as registered with the graph IR.
COLLECTIVE_OPS = {
    "psum": ("axis",),
    "all_gather": ("axis", "dim"),
    "reduce_scatter": ("axis", "dim"),
    "ppermute": ("axis", "shift"),
}

for _op, _attrs in COLLECTIVE_OPS.items():
    register_op(_op, _attrs)


def _identity_spec(node, input_specs, graph):
    """Collectives never change the logical tensor: same shape/dtype."""
    return input_specs[0]


for _op in COLLECTIVE_OPS:
    register_shape_rule(_op)(_identity_spec)


def axis_names(node) -> tuple:
    """The mesh axis (or axes) a collective node names, as a tuple."""
    ax = node.attrs["axis"]
    return (ax,) if isinstance(ax, str) else tuple(ax)


def declared_axis_size(node, ctx) -> int:
    """Static size of the collective's mesh axis: an explicit
    ``axis_size`` attr wins, else the mesh spec the lowering context
    carries, else 1 (no mesh: the degenerate, identity case)."""
    if "axis_size" in node.attrs:
        return int(node.attrs["axis_size"])
    sizes = getattr(ctx, "mesh_axis_sizes", None) or {}
    n = 1
    for ax in axis_names(node):
        n *= int(sizes.get(ax, 1))
    return n


@register_lowering("psum")
def _lower_psum(node, ins, ctx):
    # Logical identity: marks where a row-parallel partial sum becomes
    # the full value.  execute_graph re-applies the (replicated-dim)
    # sharding constraint on the output; GSPMD emits the all-reduce.
    return ins[0]


@register_lowering("all_gather")
def _lower_all_gather(node, ins, ctx):
    # Logical identity: marks where a sharded dim becomes replicated.
    return ins[0]


@register_lowering("reduce_scatter")
def _lower_reduce_scatter(node, ins, ctx):
    # Logical identity: marks where a replicated dim becomes sharded.
    return ins[0]


@register_lowering("ppermute")
def _lower_ppermute(node, ins, ctx):
    x = ins[0]
    dim = int(node.attrs.get("dim", -1))
    size = x.shape[dim]
    k = max(declared_axis_size(node, ctx), 1)
    block = size // k
    shift = (int(node.attrs["shift"]) * block) % max(size, 1)
    return jnp.roll(x, shift, axis=dim) if shift else x
