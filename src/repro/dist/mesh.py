"""MeshSpec — the device mesh as a frozen, serializable compile input.

``jax.sharding.Mesh`` holds live device objects, which makes it
unsuitable as a field of :class:`repro.CompileOptions` (options must be
hashable, comparable and JSON-serializable so they double as persistent
cache-key material).  ``MeshSpec`` is the static description — ordered
``(axis_name, size)`` pairs — and ``build()`` late-binds it to whatever
devices exist, raising a typed :class:`MeshUnavailableError` naming the
axes that cannot be filled when the device set is too small (simulated
or real device loss), instead of an opaque XLA error.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Sequence, Tuple


class MeshUnavailableError(RuntimeError):
    """The current device set cannot realize a :class:`MeshSpec`.

    Raised at executable construction, before each sharded call, and by
    the serve scheduler's step loop (surfaced in
    ``summary()["faults"]``) when the visible device set shrinks below
    what the mesh needs.  ``missing_axes`` names the axes that can no
    longer be filled, in mesh order.
    """

    def __init__(self, spec: "MeshSpec", available: int) -> None:
        self.spec = spec
        self.available = available
        self.needed = spec.size
        self.missing_axes = spec.missing_axes(available)
        super().__init__(
            f"mesh {spec.describe()} needs {self.needed} device(s) but only "
            f"{available} are visible; axes that cannot be filled: "
            f"{', '.join(self.missing_axes) or '(none)'}")


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A device mesh described by ordered ``(axis_name, size)`` pairs.

    The canonical data×model serving mesh is
    ``MeshSpec(axes=(("data", 4), ("model", 2)))`` — batch rows shard
    over ``data``, tensor-parallel dims over ``model``.  Accepts a dict
    (``{"data": 4, "model": 2}``, insertion-ordered) or a sequence of
    pairs; ``parse`` accepts the CLI spelling ``"data=4,model=2"``.
    """

    axes: Tuple[Tuple[str, int], ...] = (("data", 1),)

    def __post_init__(self) -> None:
        axes = self.axes
        if isinstance(axes, dict):
            axes = tuple(axes.items())
        axes = tuple((str(n), int(s)) for n, s in axes)
        if not axes:
            raise ValueError("mesh needs at least one axis")
        names = [n for n, _ in axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh axis names in {names}")
        for n, s in axes:
            if s <= 0:
                raise ValueError(f"mesh axis {n!r} must have positive "
                                 f"size, got {s}")
        object.__setattr__(self, "axes", axes)

    # -- construction ---------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        """Build from the CLI spelling, e.g. ``"data=4,model=2"``."""
        axes = []
        for part in str(text).split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"bad mesh axis {part!r}; expected name=size "
                    f"(e.g. 'data=4,model=2')")
            name, size = part.split("=", 1)
            axes.append((name.strip(), int(size)))
        return cls(axes=tuple(axes))

    @classmethod
    def coerce(cls, value) -> "MeshSpec":
        """Normalize any accepted spelling (MeshSpec, dict-of-sizes,
        ``to_dict`` output, pair sequence, or ``"data=4"`` string)."""
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls.parse(value)
        if isinstance(value, dict):
            if set(value) == {"axes"}:         # to_dict round-trip
                return cls(axes=tuple(tuple(p) for p in value["axes"]))
            return cls(axes=tuple(value.items()))
        return cls(axes=tuple(tuple(p) for p in value))

    # -- queries --------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        """Axis names, in mesh order."""
        return tuple(n for n, _ in self.axes)

    @property
    def shape(self) -> Tuple[int, ...]:
        """Axis sizes, in mesh order."""
        return tuple(s for _, s in self.axes)

    @property
    def size(self) -> int:
        """Total number of devices the mesh needs."""
        return math.prod(self.shape)

    @property
    def is_single_device(self) -> bool:
        """True when every axis has size 1 (the degenerate mesh that
        must stay bit-identical to the unsharded path)."""
        return self.size == 1

    def axis_size(self, name: str) -> int:
        """Size of axis ``name``; 1 for axes the mesh does not have."""
        return dict(self.axes).get(name, 1)

    def missing_axes(self, available: int) -> Tuple[str, ...]:
        """Axes that cannot be filled with ``available`` devices: the
        cumulative device product overflows at and after these axes."""
        missing = []
        running = 1
        for name, size in self.axes:
            running *= size
            if running > max(available, 0):
                missing.append(name)
        return tuple(missing)

    def describe(self) -> str:
        """The CLI spelling, e.g. ``"data=4,model=2"``."""
        return ",".join(f"{n}={s}" for n, s in self.axes)

    # -- serialization --------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-dict form for JSON artifacts; invert with ``coerce`` /
        ``from_dict``."""
        return {"axes": [[n, s] for n, s in self.axes]}

    @classmethod
    def from_dict(cls, d: dict) -> "MeshSpec":
        """Rebuild from ``to_dict`` output."""
        return cls.coerce(d)

    def cache_token(self) -> str:
        """Stable string for persistent cache keys."""
        return json.dumps(self.to_dict(), sort_keys=True)

    # -- realization ----------------------------------------------------
    def build(self, devices: Optional[Sequence] = None):
        """Late-bind to real devices: a ``jax.sharding.Mesh`` over the
        first ``size`` visible devices (or the given ones).  Raises
        :class:`MeshUnavailableError` naming the unfillable axes when
        too few devices are visible."""
        import jax
        import numpy as np
        from jax.sharding import Mesh

        devices = list(jax.devices() if devices is None else devices)
        if len(devices) < self.size:
            raise MeshUnavailableError(self, len(devices))
        arr = np.array(devices[: self.size]).reshape(self.shape)
        return Mesh(arr, self.names)


def ensure_mesh_available(spec: MeshSpec,
                          devices: Optional[Sequence] = None) -> None:
    """Raise :class:`MeshUnavailableError` if the visible device set
    cannot realize ``spec`` (the typed fault a sharded executable and
    the serve step loop check before running — see
    ``repro.distributed.fault``)."""
    import jax

    n = len(jax.devices() if devices is None else devices)
    if n < spec.size:
        raise MeshUnavailableError(spec, n)
