"""repro.dist — sharded compilation: the mesh as a compile-time input.

The paper's thesis is baking statically known properties of the network
into the compiled artifact; device placement is the largest such
property most systems still decide at call time.  This package makes it
part of the executable:

    spec = repro.dist.MeshSpec.parse("data=4,model=2")
    exe = repro.compile(graph, repro.CompileOptions(mesh=spec))
    exe.partition_spec("dense0:out")   # -> PartitionSpec('data', 'model')

``CompileOptions(mesh=...)`` routes the ``"jit"``/``"pallas"`` targets
to a :class:`ShardedExecutable`: a ``propagate_sharding`` pass (in the
ordinary PassManager registry, verified after every pass like shape
inference) annotates every graph tensor with a ``PartitionSpec`` derived
from the MaxText-style logical-axis rules in
``repro.distributed.sharding``, inserting the collectives the placement
implies — ``psum`` / ``all_gather`` / ``reduce_scatter`` / ``ppermute``
are first-class graph ops lowered through ``@register_lowering`` like
any other op, so the interpret oracle, the jit path and the Pallas path
all agree on their semantics.  The resolved mesh + shardings are
serialized into the artifact manifest and keyed into the persistent
executable cache, so a second process reconstructs the same placement
with zero re-propagation.

A single-device mesh is bit-identical to the unsharded path: every
collective degenerates to the identity and every sharding constraint is
trivial, which is what lets the same compiled-artifact pipeline run
from one CPU to a full pod.
"""

from __future__ import annotations

from .mesh import MeshSpec, MeshUnavailableError, ensure_mesh_available
from .collectives import COLLECTIVE_OPS
from .propagate import (ShardingError, check_shardings, collective_summary,
                        merged_rules, propagate_shardings)


def __getattr__(name: str):
    # ShardedExecutable pulls in repro.api (targets, cache); loading it
    # lazily keeps ``repro.dist`` importable from repro.api.options
    # without a cycle.
    if name == "ShardedExecutable":
        from .executable import ShardedExecutable
        return ShardedExecutable
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "COLLECTIVE_OPS",
    "MeshSpec",
    "MeshUnavailableError",
    "ShardedExecutable",
    "ShardingError",
    "check_shardings",
    "collective_summary",
    "ensure_mesh_available",
    "merged_rules",
    "propagate_shardings",
]
