"""Pallas kernels for the compiler's compute hot-spots.

Each kernel family is a package with ``kernel.py`` (the Pallas
implementation), ``ops.py`` (the public dispatch that falls back to
``ref.py`` off-TPU), and ``ref.py`` (the pure-lax reference the
golden tests compare against).  ``tiles.py`` owns tile geometry and
the block-candidate grid; ``qmath.py`` owns the shared quantization
arithmetic (scales, casts, int8 helpers).
"""
