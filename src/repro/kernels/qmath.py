"""Shared quantization arithmetic — one copy of the numerics.

Every consumer of a ``quant.*`` annotation — the SimpleNN oracle, the
generic lowering rules, the Pallas kernel wrappers, and the quantize
pass's own calibration accuracy checks — routes through the helpers
here, so "int8 dense" means *exactly* the same arithmetic on every
target.  That is what makes the golden interpret-vs-jit-vs-pallas
identity tests possible: int8 accumulation is exact in i32 and the
dequant is a single f32 multiply, so as long as the quantize/dequant
expressions are literally shared, the targets agree bit-for-bit.

Conventions (symmetric, TensorRT-style):

* activations: one per-tensor scale ``s_x = absmax / 127`` recorded by
  the calibration walk; ``q = clip(round(x / s_x), -127, 127)``.
* weights: per-output-channel scales ``s_w[n] = absmax_n / 127``
  computed from the f32 weights at annotation time (no calibration
  needed — weights are static).
* zero points are always 0 (symmetric): the graphs this compiler
  targets are activation-centric (relu/tanh around 0), and symmetric
  quantization keeps the matmul a plain int8×int8→i32 product with a
  single fused dequant multiply — no zero-point correction terms.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: Symmetric int8 clip range (−127..127; −128 is excluded so the range
#: is symmetric and ``-q`` is always representable).
Q8_MAX = 127.0
#: Scale floor — an all-zero tensor quantizes with this scale instead
#: of dividing by zero.
EPS = 1e-12


def tensor_scale(absmax: float) -> float:
    """Per-tensor symmetric scale from a calibrated |x| maximum."""
    return max(float(absmax), EPS) / Q8_MAX


def channel_scales(w: np.ndarray, axis: int) -> np.ndarray:
    """Per-channel symmetric scales: |w| max reduced over every axis
    except ``axis`` (the output-channel axis), divided by 127."""
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
    absmax = np.abs(np.asarray(w, dtype=np.float32)).max(axis=reduce_axes)
    return np.maximum(absmax, EPS).astype(np.float32) / np.float32(Q8_MAX)


def quantize_q8(x: jnp.ndarray, scale) -> jnp.ndarray:
    """``clip(round(x / scale), ±127) -> int8``.  ``scale`` broadcasts
    (a scalar for activations, a shaped array for per-channel weights).
    This is the ONE quantize expression — round-half-to-even via
    ``jnp.round``, division not reciprocal-multiply — shared by every
    target so quantized operands are bitwise identical everywhere."""
    q = jnp.clip(jnp.round(x / scale), -Q8_MAX, Q8_MAX)
    return q.astype(jnp.int8)


def dequant_scales(x_scale: float, w_scales) -> jnp.ndarray:
    """The fused f32 dequant vector ``s_x * s_w[n]``: one multiply per
    output channel, applied once to the exact i32 accumulator."""
    return (jnp.float32(x_scale)
            * jnp.asarray(w_scales, dtype=jnp.float32))


def conv2d_q8(x: jnp.ndarray, k: jnp.ndarray, x_scale: float, w_scales,
              *, strides, padding) -> jnp.ndarray:
    """Int8 NHWC/HWIO convolution: quantize both operands with the
    calibrated scales, accumulate exactly in i32, dequantize with one
    per-channel f32 multiply.  ``padding`` is the already-resolved lax
    padding (string or explicit pairs).  Shared verbatim by the oracle
    and the lowering rule — exact i32 accumulation makes the two
    bit-identical regardless of how XLA tiles the reduction."""
    ws = jnp.asarray(w_scales, dtype=jnp.float32)
    xq = quantize_q8(x, jnp.float32(x_scale))
    kq = quantize_q8(k.astype(jnp.float32), ws[None, None, None, :])
    acc = jax.lax.conv_general_dilated(
        xq, kq, window_strides=strides, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * dequant_scales(x_scale, ws)


def conv2d_bf16(x: jnp.ndarray, k: jnp.ndarray, *, strides, padding
                ) -> jnp.ndarray:
    """Bf16 NHWC/HWIO convolution: round both operands to bfloat16,
    accumulate in f32 (``preferred_element_type``)."""
    xq, kq = bf16_cast_pair(x, k)
    return jax.lax.conv_general_dilated(
        xq, kq, window_strides=strides, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)


def bf16_cast_pair(x: jnp.ndarray, w: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The bf16 mode's only transformation: round both operands to
    bfloat16.  Accumulation stays f32 (``preferred_element_type``) on
    every path, so bf16 compute is "quantize the operands, keep the
    reduction exact-ish" — the cheap mode the paper's static-shapes
    argument gets for free."""
    return x.astype(jnp.bfloat16), w.astype(jnp.bfloat16)
