"""Pure-jnp oracles for the fast activation approximations (paper §3.4).

Two families, exactly as in the paper:

* ``schraudolph_exp`` — Schraudolph (1999): exploit IEEE-754: writing
  ``i = A*x + B`` into the *exponent+mantissa* bits of a float yields
  2^(x/ln2) ≈ exp(x).  One multiply, one f2i convert, one int add, one
  bitcast ("one multiplication, one float-to-integer conversion and one
  integer addition, afterwards interpreting the result as a floating
  point number again").
* ``cf_tanh`` — Eq. 5: the continued fraction of tanh truncated to the
  degree-(7,8) rational; ``cf_sigmoid`` via Eq. 4
  (sigmoid(x) = (tanh(x/2)+1)/2).

These are the *reference semantics* of the approximation (what the
Pallas kernels must reproduce bit-for-bit up to float assoc); the
*accuracy* versus the exact functions is a separate, measured quantity
(see benchmarks/precision.py) — the paper likewise notes the
approximations "impact the precision of the calculations".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Schraudolph constants for float32.
# exp(x) = 2^(x/ln2); float32 bits of 2^y for y in [0,1) are approximated
# linearly.  A scales x into exponent units, B biases to exponent 127,
# C is Schraudolph's mean-error-minimizing correction (60801 in the
# double-precision/2^20 formulation; scaled by 8 for float32's 2^23).
_EXP_A = 12102203.161561485  # 2^23 / ln(2)
_EXP_B = 127.0 * (2.0 ** 23)
_EXP_C = 60801.0 * 8.0


def schraudolph_exp(x: jnp.ndarray) -> jnp.ndarray:
    """exp(x) via the IEEE-754 bit trick.  Max relative error ~4%."""
    x = jnp.asarray(x, jnp.float32)
    # Clamp to the representable exponent range to avoid int overflow.
    x = jnp.clip(x, -87.0, 88.0)
    i = (_EXP_A * x + (_EXP_B - _EXP_C)).astype(jnp.int32)
    return jax.lax.bitcast_convert_type(i, jnp.float32)


def cf_tanh(x: jnp.ndarray) -> jnp.ndarray:
    """tanh via the truncated continued fraction (paper Eq. 5).

    The rational is accurate below |x|≈4.97 and diverges beyond, so the
    input is clamped first (the emitted SSE code does the same with
    min/max ops).
    """
    x = jnp.asarray(x, jnp.float32)
    x = jnp.clip(x, -4.97, 4.97)
    x2 = x * x
    num = (((36.0 * x2 + 6930.0) * x2 + 270270.0) * x2 + 2027025.0) * x
    den = (((x2 + 630.0) * x2 + 51975.0) * x2 + 945945.0) * x2 + 2027025.0
    return num / den


def cf_sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    """sigmoid(x) = (tanh(x/2) + 1) / 2   (paper Eq. 4)."""
    x = jnp.asarray(x, jnp.float32)
    return 0.5 * (cf_tanh(0.5 * x) + 1.0)


def fast_softmax(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Two-pass softmax (§3.4) with the Schraudolph exp.

    Max-subtraction keeps the exp argument in a small range, and the
    normalization divides out most of Schraudolph's multiplicative bias.
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = schraudolph_exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


#: exact counterparts, for precision benchmarking
EXACT = {
    "exp": jnp.exp,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "softmax": jax.nn.softmax,
}

FAST = {
    "exp": schraudolph_exp,
    "tanh": cf_tanh,
    "sigmoid": cf_sigmoid,
    "softmax": fast_softmax,
}
