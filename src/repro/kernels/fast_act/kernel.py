"""Pallas TPU kernels for the fast activation approximations.

Elementwise maps over VMEM tiles.  The TPU adaptation of the paper's
register-batching (§3.3): instead of sizing batches to ``4·(n_xmm−k)``
XMM registers, the tile is sized so a (block_rows × 128-lane) slab and
its intermediates fit VMEM; the VPU then executes the polynomial with
full lane parallelism.  The Schraudolph trick survives intact because
TPUs are IEEE-754: ``bitcast_convert_type`` compiles to a vector
reinterpret, exactly like x86's ``movd``-free punning.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shape: sublane × lane aligned for f32.  bf16 inputs double the
# row cap (same VMEM bytes — the register file packs narrow elements
# deeper, exactly the tiles.py granule story).
BLOCK_ROWS = 256
BLOCK_COLS = 128

_EXP_A = 12102203.161561485
_EXP_B = 127.0 * (2.0 ** 23)
_EXP_C = 60801.0 * 8.0


def _exp_body(x):
    x = jnp.clip(x, -87.0, 88.0)
    i = (_EXP_A * x + (_EXP_B - _EXP_C)).astype(jnp.int32)
    return jax.lax.bitcast_convert_type(i, jnp.float32)


def _tanh_body(x):
    x = jnp.clip(x, -4.97, 4.97)
    x2 = x * x
    num = (((36.0 * x2 + 6930.0) * x2 + 270270.0) * x2 + 2027025.0) * x
    den = (((x2 + 630.0) * x2 + 51975.0) * x2 + 945945.0) * x2 + 2027025.0
    return num / den


def _sigmoid_body(x):
    return 0.5 * (_tanh_body(0.5 * x) + 1.0)


_BODIES = {"exp": _exp_body, "tanh": _tanh_body, "sigmoid": _sigmoid_body}


def _elementwise_kernel(x_ref, o_ref, *, fn: str):
    # Compute in f32 regardless of the tile dtype: the Schraudolph exp
    # puns f32 bit patterns, and the polynomial coefficients are tuned
    # for f32 — bf16 tiles cast on entry and round once on exit.
    v = x_ref[...].astype(jnp.float32)
    o_ref[...] = _BODIES[fn](v).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fn", "interpret", "block"))
def fast_act_2d(x: jnp.ndarray, fn: str, interpret: bool = True,
                block=None) -> jnp.ndarray:
    """Apply a fast activation to a 2D f32/bf16 array via Pallas (the
    output dtype matches the input; internals are f32 either way).

    The wrapper pads to tile multiples (compile-time shapes, so the pad
    is free to fuse) and slices back.  ``block=(rows, cols)`` overrides
    the default tile caps (the autotuner's measured geometry).  bf16
    tiles default to double the row cap: half the bytes per row means
    the same VMEM working set covers twice the rows.
    """
    m, n = x.shape
    narrow = x.dtype == jnp.bfloat16
    rows_cap, cols_cap = block if block is not None else (
        BLOCK_ROWS * (2 if narrow else 1), BLOCK_COLS)
    bm = min(rows_cap, max(16 if narrow else 8, m))
    bn = min(cols_cap, max(128, n)) if n >= 128 else n
    pm = -(-m // bm) * bm
    pn = -(-n // bn) * bn
    xp = jnp.pad(x, ((0, pm - m), (0, pn - n)))
    out = pl.pallas_call(
        functools.partial(_elementwise_kernel, fn=fn),
        grid=(pm // bm, pn // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:m, :n]
