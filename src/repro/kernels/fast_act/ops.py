"""Public jit'd wrappers for the fast activations.

``fast_act(x, fn)`` reshapes any-rank input to 2D, dispatches to the
Pallas kernel (interpret=True on CPU, compiled on TPU), and restores the
shape.  ``use_pallas=False`` falls back to the pure-jnp reference (same
math — used by the CPU-side CompiledNN back end where interpret-mode
Pallas would be needlessly slow).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .kernel import fast_act_2d

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def fast_act(x: jnp.ndarray, fn: str, use_pallas: bool = False,
             block: Optional[Tuple[int, int]] = None) -> jnp.ndarray:
    """fn in {'exp','tanh','sigmoid'} (softmax handled at a higher level
    because it needs the two-pass reduction).

    ``block`` overrides the default (rows, cols) tile of the Pallas
    kernel — the autotuner passes the measured winner here.
    """
    # bf16 rides the kernel as bf16 tiles (half the bytes, double the
    # row block); the math is f32 internally on both paths so the two
    # agree to one output rounding.
    narrow = x.dtype == jnp.bfloat16
    if not use_pallas:
        if narrow:
            return ref.FAST[fn](x.astype(jnp.float32)).astype(jnp.bfloat16)
        return ref.FAST[fn](x)
    shape = x.shape
    if x.ndim == 0:
        x2 = x.reshape(1, 1)
    elif x.ndim == 1:
        x2 = x.reshape(1, -1)
    else:
        x2 = x.reshape(-1, shape[-1])
    if not narrow:
        x2 = x2.astype(jnp.float32)
    y = fast_act_2d(x2, fn, interpret=not _ON_TPU, block=block)
    return y.reshape(shape)


def fast_softmax(x: jnp.ndarray, axis: int = -1, use_pallas: bool = False) -> jnp.ndarray:
    """Max-subtracted softmax built on the fast exp (paper §3.4)."""
    if not use_pallas:
        return ref.fast_softmax(x, axis=axis)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = fast_act(x - m, "exp", use_pallas=True)
    return e / jnp.sum(e, axis=axis, keepdims=True)
