"""Pallas TPU kernel: tiled matmul with a fused epilogue.

This is the paper's P3+P5 combination rendered for the MXU:

* the matmul accumulates (bm × bn) tiles in a VMEM f32 scratch across
  the K grid dimension;
* on the *last* K step the epilogue — bias add, activation, optional
  folded-BN affine — is applied to the accumulator tile **while it is
  still in VMEM**, and only then stored to HBM.  That is exactly the
  paper's "the activation function is applied before writing the result
  of the operation into memory.  This avoids an additional loop with
  load and store operations" — with VMEM playing the role of the XMM
  register file.

Weights arrive in whatever layout the compile-time layout pass chose
(P5): (K, N) "io" or transposed (N, K) "oi"; the kernel body contracts
accordingly, so no runtime transpose ever appears in the lowered HLO.

Block sizes are MXU-aligned (multiples of (8,128) for f32); the wrapper
in ops.py pads operands at trace time (shapes are static — the pads are
compile-time constants, the paper's "statically known properties").
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fast_act.kernel import _BODIES as _FAST_BODIES

DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 256


def _apply_epilogue(acc, bias_ref, fn: Optional[str], fast: bool,
                    affine_refs, attrs):
    y = acc
    if bias_ref is not None:
        y = y + bias_ref[...]
    if fn and fn != "linear":
        if fn == "relu":
            y = jnp.maximum(y, 0.0)
        elif fn == "relu6":
            y = jnp.clip(y, 0.0, 6.0)
        elif fn == "leaky_relu":
            alpha = attrs.get("alpha", 0.01)
            y = jnp.where(y >= 0, y, alpha * y)
        elif fn == "hard_sigmoid":
            y = jnp.clip(y * 0.2 + 0.5, 0.0, 1.0)
        elif fn == "elu":
            y = jnp.where(y >= 0, y, jnp.expm1(y))
        elif fn == "tanh":
            y = _FAST_BODIES["tanh"](y) if fast else jnp.tanh(y)
        elif fn == "sigmoid":
            y = _FAST_BODIES["sigmoid"](y) if fast else jax.nn.sigmoid(y)
        else:  # pragma: no cover
            raise NotImplementedError(fn)
    if affine_refs is not None:
        s_ref, o_ref = affine_refs
        y = y * s_ref[...] + o_ref[...]
    return y


def _matmul_kernel(*refs, nk: int, fn: Optional[str], fast: bool,
                   has_bias: bool, has_affine: bool, w_layout: str, attrs):
    if has_bias and has_affine:
        x_ref, w_ref, b_ref, s_ref, off_ref, o_ref, acc_ref = refs
        affine = (s_ref, off_ref)
    elif has_bias:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
        affine = None
    elif has_affine:
        x_ref, w_ref, s_ref, off_ref, o_ref, acc_ref = refs
        b_ref = None
        affine = (s_ref, off_ref)
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
        b_ref = None
        affine = None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if w_layout == "io":  # (K, N)
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )
    else:  # "oi": (N, K) — contract K on both, no transpose materialized
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...],
            w_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = _apply_epilogue(
            acc_ref[...], b_ref, fn, fast, affine, attrs
        ).astype(o_ref.dtype)


def fused_matmul_p(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    block: Tuple[int, int, int] = (DEFAULT_BM, DEFAULT_BK, DEFAULT_BN),
    interpret: bool = True,
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """Raw pallas_call: operands must already be tile-aligned.

    x: (M, K) f32 or bf16;  w: (K, N) or (N, K) per w_layout, same
    dtype as x; bias/scale/offset: (1, N) or None.  Accumulation is
    always f32 (``preferred_element_type``); returns (M, N) f32.
    """
    m, k = x.shape
    n = w.shape[1] if w_layout == "io" else w.shape[0]
    bm, bk, bn = block
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, block)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))]
    if w_layout == "io":
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
    else:
        in_specs.append(pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)))
    operands = [x, w]
    has_bias = bias is not None
    has_affine = scale is not None
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(bias.reshape(1, n))
    if has_affine:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.extend([scale.reshape(1, n), offset.reshape(1, n)])

    kernel = functools.partial(
        _matmul_kernel,
        nk=nk,
        fn=fn,
        fast=fast,
        has_bias=has_bias,
        has_affine=has_affine,
        w_layout=w_layout,
        attrs=attrs or {},
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pl.pallas_tpu.VMEM((bm, bn), jnp.float32)]
        if hasattr(pl, "pallas_tpu")
        else [_vmem_scratch((bm, bn))],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(*operands)


def _q8_matmul_kernel(*refs, nk: int, fn: Optional[str], fast: bool,
                      has_bias: bool, has_affine: bool, w_layout: str,
                      attrs):
    """Int8 body: i32 VMEM accumulation of int8 tiles, then — on the
    last K step, while the tile is still in VMEM — one f32 dequant
    multiply by the fused ``s_x * s_w`` vector followed by the standard
    epilogue.  The i32 sum is exact, so blocking order cannot perturb
    the result and the lax reference is bit-identical."""
    if has_bias and has_affine:
        x_ref, w_ref, deq_ref, b_ref, s_ref, off_ref, o_ref, acc_ref = refs
        affine = (s_ref, off_ref)
    elif has_bias:
        x_ref, w_ref, deq_ref, b_ref, o_ref, acc_ref = refs
        affine = None
    elif has_affine:
        x_ref, w_ref, deq_ref, s_ref, off_ref, o_ref, acc_ref = refs
        b_ref = None
        affine = (s_ref, off_ref)
    else:
        x_ref, w_ref, deq_ref, o_ref, acc_ref = refs
        b_ref = None
        affine = None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if w_layout == "io":  # (K, N)
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.int32
        )
    else:  # "oi": (N, K)
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...],
            w_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * deq_ref[...]
        o_ref[...] = _apply_epilogue(
            y, b_ref, fn, fast, affine, attrs
        ).astype(o_ref.dtype)


def fused_matmul_q8_p(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    deq: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    block: Tuple[int, int, int] = (DEFAULT_BM, DEFAULT_BK, DEFAULT_BN),
    interpret: bool = True,
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """Raw int8 pallas_call: operands must already be quantized and
    tile-aligned to the itemsize-1 granule (sublane 32).

    xq: (M, K) int8;  wq: (K, N) or (N, K) int8 per w_layout;
    deq: (N,) f32 fused dequant scales (``s_x * s_w``); bias/scale/
    offset: (N,) f32 or None.  Accumulates in an i32 VMEM scratch and
    returns (M, N) f32.
    """
    m, k = xq.shape
    n = wq.shape[1] if w_layout == "io" else wq.shape[0]
    bm, bk, bn = block
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, block)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))]
    if w_layout == "io":
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
    else:
        in_specs.append(pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)))
    in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    operands = [xq, wq, deq.reshape(1, n)]
    has_bias = bias is not None
    has_affine = scale is not None
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(bias.reshape(1, n))
    if has_affine:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.extend([scale.reshape(1, n), offset.reshape(1, n)])

    kernel = functools.partial(
        _q8_matmul_kernel,
        nk=nk,
        fn=fn,
        fast=fast,
        has_bias=has_bias,
        has_affine=has_affine,
        w_layout=w_layout,
        attrs=attrs or {},
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pl.pallas_tpu.VMEM((bm, bn), jnp.int32)]
        if hasattr(pl, "pallas_tpu")
        else [_vmem_scratch((bm, bn), jnp.int32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(*operands)


def _vmem_scratch(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except Exception:  # pragma: no cover - older pallas versions
        return None
