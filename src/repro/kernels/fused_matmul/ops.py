"""Jit'd public wrapper around the fused matmul kernel.

Handles padding to tile multiples (compile-time, from static shapes) and
falls back to the jnp reference when Pallas is not requested (the CPU
CompiledNN back end) — the *semantics* are identical by construction and
by test (tests/test_kernels.py sweeps shapes × epilogues).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .kernel import fused_matmul_p, fused_matmul_q8_p
from ..tiles import pick_block
from ..qmath import dequant_scales, quantize_q8

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())

#: Compat alias: block choice moved to kernels.tiles so the compile-time
#: kernel selector reasons about exactly the blocks used here.
_pick_block = pick_block


def _pad_to(a: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = -(-a.shape[0] // m0) * m0 - a.shape[0]
    p1 = -(-a.shape[1] // m1) * m1 - a.shape[1]
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


def fused_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    use_pallas: bool = False,
    block: Optional[Tuple[int, int, int]] = None,
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """y = epilogue(x @ W (+ bias)) with W in 'io' (K,N) or 'oi' (N,K).

    x may be any rank; the contraction is over the last axis.

    ``block`` overrides the heuristic ``pick_block`` geometry — the
    autotuner passes the measured winner here so the kernel tiles
    exactly the way the micro-benchmark did.
    """
    shape = x.shape
    k = shape[-1]
    # bf16 operands stay bf16 on the Pallas path (the MXU multiplies
    # narrow inputs exactly into the f32 accumulator, so numerics match
    # an upcast) — this is what makes the dtype-parametrized VMEM model
    # in kernels/tiles.py true: a bf16 tile really is half the bytes.
    # Everything else computes in f32, as before.
    compute = jnp.bfloat16 if x.dtype == jnp.bfloat16 else jnp.float32
    x2 = x.reshape(-1, k).astype(compute)
    n = w.shape[1] if w_layout == "io" else w.shape[0]
    if not use_pallas:
        y = ref.fused_matmul_ref(
            x2.astype(jnp.float32), w, bias, scale, offset, fn=fn, fast=fast,
            w_layout=w_layout, attrs=attrs,
        )
        return y.reshape(shape[:-1] + (n,))

    m = x2.shape[0]
    itemsize = jnp.dtype(compute).itemsize
    bm, bk, bn = block if block is not None else _pick_block(m, k, n, itemsize)
    xp = _pad_to(x2, bm, bk)
    wp = _pad_to(w, bk if w_layout == "io" else bn, bn if w_layout == "io" else bk)
    pn = wp.shape[1] if w_layout == "io" else wp.shape[0]

    def pad_vec(v):
        if v is None:
            return None
        return jnp.pad(v.astype(jnp.float32), (0, pn - v.shape[0]))

    y = fused_matmul_p(
        xp,
        wp.astype(compute),
        pad_vec(bias),
        pad_vec(scale),
        pad_vec(offset),
        fn=fn,
        fast=fast,
        w_layout=w_layout,
        block=(bm, bk, bn),
        interpret=not _ON_TPU,
        attrs=attrs,
    )
    return y[:m, :n].reshape(shape[:-1] + (n,))


def fused_matmul_q8(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    x_scale: float,
    w_scales: jnp.ndarray,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    use_pallas: bool = False,
    block: Optional[Tuple[int, int, int]] = None,
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """Int8 fused matmul: quantize both f32 operands with the
    calibrated scales (``x_scale`` per-tensor, ``w_scales`` per output
    channel), contract int8×int8 into an exact i32 accumulator, dequant
    with one fused f32 multiply, then the standard epilogue.

    With static weights the weight quantization constant-folds at trace
    time (``embed_weights``) — the compiled program holds int8 weights,
    the paper's specialize-to-static-properties thesis applied to dtype.
    The non-pallas path is the reference ``lax.dot_general`` int8
    lowering — bit-identical to the Pallas kernel because the i32 sum
    is exact under any blocking.
    """
    shape = x.shape
    k = shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    n = w.shape[1] if w_layout == "io" else w.shape[0]
    w_scales = jnp.asarray(w_scales, dtype=jnp.float32)
    xq = quantize_q8(x2, jnp.float32(x_scale))
    wq = quantize_q8(
        w.astype(jnp.float32),
        w_scales[None, :] if w_layout == "io" else w_scales[:, None])
    deq = dequant_scales(x_scale, w_scales)
    if not use_pallas:
        y = ref.fused_matmul_q8_ref(
            xq, wq, deq, bias, scale, offset, fn=fn, fast=fast,
            w_layout=w_layout, attrs=attrs,
        )
        return y.reshape(shape[:-1] + (n,))

    m = x2.shape[0]
    bm, bk, bn = block if block is not None else _pick_block(m, k, n, 1)
    xp = _pad_to(xq, bm, bk)
    wp = _pad_to(wq, bk if w_layout == "io" else bn,
                 bn if w_layout == "io" else bk)
    pn = wp.shape[1] if w_layout == "io" else wp.shape[0]

    def pad_vec(v):
        if v is None:
            return None
        return jnp.pad(v.astype(jnp.float32), (0, pn - v.shape[0]))

    y = fused_matmul_q8_p(
        xp,
        wp,
        pad_vec(deq),
        pad_vec(bias),
        pad_vec(scale),
        pad_vec(offset),
        fn=fn,
        fast=fast,
        w_layout=w_layout,
        block=(bm, bk, bn),
        interpret=not _ON_TPU,
        attrs=attrs,
    )
    return y[:m, :n].reshape(shape[:-1] + (n,))
