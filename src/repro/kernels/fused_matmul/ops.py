"""Jit'd public wrapper around the fused matmul kernel.

Handles padding to tile multiples (compile-time, from static shapes) and
falls back to the jnp reference when Pallas is not requested (the CPU
CompiledNN back end) — the *semantics* are identical by construction and
by test (tests/test_kernels.py sweeps shapes × epilogues).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .kernel import fused_matmul_p
from ..tiles import pick_block

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())

#: Compat alias: block choice moved to kernels.tiles so the compile-time
#: kernel selector reasons about exactly the blocks used here.
_pick_block = pick_block


def _pad_to(a: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = -(-a.shape[0] // m0) * m0 - a.shape[0]
    p1 = -(-a.shape[1] // m1) * m1 - a.shape[1]
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


def fused_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    use_pallas: bool = False,
    block: Optional[Tuple[int, int, int]] = None,
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """y = epilogue(x @ W (+ bias)) with W in 'io' (K,N) or 'oi' (N,K).

    x may be any rank; the contraction is over the last axis.

    ``block`` overrides the heuristic ``pick_block`` geometry — the
    autotuner passes the measured winner here so the kernel tiles
    exactly the way the micro-benchmark did.
    """
    shape = x.shape
    k = shape[-1]
    # bf16 operands stay bf16 on the Pallas path (the MXU multiplies
    # narrow inputs exactly into the f32 accumulator, so numerics match
    # an upcast) — this is what makes the dtype-parametrized VMEM model
    # in kernels/tiles.py true: a bf16 tile really is half the bytes.
    # Everything else computes in f32, as before.
    compute = jnp.bfloat16 if x.dtype == jnp.bfloat16 else jnp.float32
    x2 = x.reshape(-1, k).astype(compute)
    n = w.shape[1] if w_layout == "io" else w.shape[0]
    if not use_pallas:
        y = ref.fused_matmul_ref(
            x2.astype(jnp.float32), w, bias, scale, offset, fn=fn, fast=fast,
            w_layout=w_layout, attrs=attrs,
        )
        return y.reshape(shape[:-1] + (n,))

    m = x2.shape[0]
    itemsize = jnp.dtype(compute).itemsize
    bm, bk, bn = block if block is not None else _pick_block(m, k, n, itemsize)
    xp = _pad_to(x2, bm, bk)
    wp = _pad_to(w, bk if w_layout == "io" else bn, bn if w_layout == "io" else bk)
    pn = wp.shape[1] if w_layout == "io" else wp.shape[0]

    def pad_vec(v):
        if v is None:
            return None
        return jnp.pad(v.astype(jnp.float32), (0, pn - v.shape[0]))

    y = fused_matmul_p(
        xp,
        wp.astype(compute),
        pad_vec(bias),
        pad_vec(scale),
        pad_vec(offset),
        fn=fn,
        fast=fast,
        w_layout=w_layout,
        block=(bm, bk, bn),
        interpret=not _ON_TPU,
        attrs=attrs,
    )
    return y[:m, :n].reshape(shape[:-1] + (n,))
