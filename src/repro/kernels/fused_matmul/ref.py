"""Pure-jnp oracle for the fused matmul + epilogue kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..fast_act import ref as fast_ref


def fused_matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """Pure-lax matmul + bias + activation + post-affine epilogue — the
    reference the fused Pallas kernel must match bit-for-bit."""
    attrs = attrs or {}
    if w_layout == "oi":
        y = x @ w.T
    else:
        y = x @ w
    if bias is not None:
        y = y + bias
    if fn and fn != "linear":
        if fn == "relu":
            y = jnp.maximum(y, 0.0)
        elif fn == "relu6":
            y = jnp.clip(y, 0.0, 6.0)
        elif fn == "leaky_relu":
            y = jnp.where(y >= 0, y, attrs.get("alpha", 0.01) * y)
        elif fn == "hard_sigmoid":
            y = jnp.clip(y * 0.2 + 0.5, 0.0, 1.0)
        elif fn == "elu":
            y = jnp.where(y >= 0, y, jnp.expm1(y))
        elif fn == "tanh":
            y = fast_ref.cf_tanh(y) if fast else jnp.tanh(y)
        elif fn == "sigmoid":
            y = fast_ref.cf_sigmoid(y) if fast else jax.nn.sigmoid(y)
        else:
            raise NotImplementedError(fn)
    if scale is not None:
        y = y * scale + offset
    return y


def _epilogue_chain(y, bias, scale, offset, fn, fast, attrs):
    """The f32 epilogue chain alone (bias → activation → affine) —
    shared by the int8 path, which produces ``y`` by dequantizing an
    exact i32 accumulator instead of an f32 matmul."""
    attrs = attrs or {}
    if bias is not None:
        y = y + bias
    if fn and fn != "linear":
        if fn == "relu":
            y = jnp.maximum(y, 0.0)
        elif fn == "relu6":
            y = jnp.clip(y, 0.0, 6.0)
        elif fn == "leaky_relu":
            y = jnp.where(y >= 0, y, attrs.get("alpha", 0.01) * y)
        elif fn == "hard_sigmoid":
            y = jnp.clip(y * 0.2 + 0.5, 0.0, 1.0)
        elif fn == "elu":
            y = jnp.where(y >= 0, y, jnp.expm1(y))
        elif fn == "tanh":
            y = fast_ref.cf_tanh(y) if fast else jnp.tanh(y)
        elif fn == "sigmoid":
            y = fast_ref.cf_sigmoid(y) if fast else jax.nn.sigmoid(y)
        else:
            raise NotImplementedError(fn)
    if scale is not None:
        y = y * scale + offset
    return y


def fused_matmul_q8_ref(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    deq: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    """Reference int8 matmul: exact i32 accumulation of already
    quantized operands, one f32 dequant multiply (``deq`` = per-channel
    ``s_x * s_w``), then the standard f32 epilogue chain.

    Because the i32 sum is exact (no rounding, any blocking order) and
    the dequant is a single f32 multiply, this is bit-identical to the
    Pallas q8 kernel by construction — the lax lowering every
    non-pallas target uses IS the golden semantics.
    """
    dims = ((( (xq.ndim - 1),), ((1,) if w_layout == "oi" else (0,))),
            ((), ()))
    acc = jax.lax.dot_general(xq, wq, dimension_numbers=dims,
                              preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * deq
    return _epilogue_chain(y, bias, scale, offset, fn, fast, attrs)
