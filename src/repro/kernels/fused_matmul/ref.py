"""Pure-jnp oracle for the fused matmul + epilogue kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..fast_act import ref as fast_ref


def fused_matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    scale: Optional[jnp.ndarray] = None,
    offset: Optional[jnp.ndarray] = None,
    *,
    fn: Optional[str] = None,
    fast: bool = False,
    w_layout: str = "io",
    attrs: Optional[dict] = None,
) -> jnp.ndarray:
    attrs = attrs or {}
    if w_layout == "oi":
        y = x @ w.T
    else:
        y = x @ w
    if bias is not None:
        y = y + bias
    if fn and fn != "linear":
        if fn == "relu":
            y = jnp.maximum(y, 0.0)
        elif fn == "relu6":
            y = jnp.clip(y, 0.0, 6.0)
        elif fn == "leaky_relu":
            y = jnp.where(y >= 0, y, attrs.get("alpha", 0.01) * y)
        elif fn == "hard_sigmoid":
            y = jnp.clip(y * 0.2 + 0.5, 0.0, 1.0)
        elif fn == "elu":
            y = jnp.where(y >= 0, y, jnp.expm1(y))
        elif fn == "tanh":
            y = fast_ref.cf_tanh(y) if fast else jnp.tanh(y)
        elif fn == "sigmoid":
            y = fast_ref.cf_sigmoid(y) if fast else jax.nn.sigmoid(y)
        else:
            raise NotImplementedError(fn)
    if scale is not None:
        y = y * scale + offset
    return y
