"""TPU tile geometry shared by the kernels and the kernel selector.

One source of truth for the hardware granules the Pallas kernels tile
against, so the compile-time selector (``repro.core.selection``) and the
profile-guided autotuner (``repro.autotune``) reason about exactly the
blocks the kernels will use.

Granules are dtype-dependent on TPU: the lane (minor) dim is always 128
wide, but the sublane granule is ``32 / itemsize`` rows (f32 → 8,
bf16 → 16, int8 → 32) because the register file packs narrower elements
deeper.  The VMEM working-set math is parametrized the same way — a
bf16 operand tile holds twice the elements of an f32 tile in the same
bytes, so the K-dim block cap scales up instead of leaving half the
budget idle.
"""

from __future__ import annotations

from typing import List, Tuple

#: MXU/VPU lane width (minor-most dim granule, all dtypes).
LANE = 128
#: Sublane granule for f32 (second-minor dim).  Dtype-aware callers use
#: :func:`sublane_for` instead.
SUBLANE = 8
#: Per-core VMEM the block working set must fit well under (~16 MiB on
#: current TPUs; the budget is the full size — callers compare their
#: resident tiles against it).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def ceil_to(n: int, align: int) -> int:
    """Round ``n`` up to a multiple of ``align`` (the one copy of the
    granule-rounding convention)."""
    return -(-n // align) * align


def sublane_for(itemsize: int = 4) -> int:
    """Sublane granule for a dtype of ``itemsize`` bytes: 32/itemsize
    rows (f32 → 8, bf16 → 16, int8 → 32), never below the f32 granule."""
    return max(SUBLANE, 32 // max(1, itemsize))


def block_vmem_bytes(bm: int, bk: int, bn: int, itemsize: int = 4,
                     acc_itemsize: int = 4) -> int:
    """Resident bytes of one fused-matmul block: x(bm,bk) + w(bk,bn)
    tiles in the operand dtype, plus the accumulator and output tiles.

    ``acc_itemsize`` is the accumulator/output element width — 4 for
    the f32 kernels *and* for the int8 kernel (i32 scratch, f32 out);
    it is a parameter rather than a constant so a future f16-out or
    i64-accumulate variant budgets correctly instead of inheriting the
    f32 assumption.
    """
    return itemsize * (bm * bk + bk * bn) + acc_itemsize * 2 * (bm * bn)


def pick_block(m: int, k: int, n: int, itemsize: int = 4
               ) -> Tuple[int, int, int]:
    """VMEM-aware block choice for the fused matmul: x(bm,bk) + w(bk,bn)
    + acc/out(bm,bn) must fit well under VMEM; keep MXU-aligned.

    The K cap scales with the operand dtype — 512 for f32, 1024 for
    bf16 — so narrow dtypes stream twice the reduction depth through
    the same VMEM bytes instead of leaving the budget idle.
    """
    sub = sublane_for(itemsize)
    bm = min(256, ceil_to(m, sub))
    bn = min(256, ceil_to(n, LANE))
    bk = min(512 * 4 // max(1, itemsize), ceil_to(k, LANE))
    return bm, bk, bn


#: Candidate caps the autotuner sweeps around :func:`pick_block`.  Small
#: on purpose: the grid is multiplied by every (shape, batch) tactic key
#: and each candidate costs a compile + a micro-benchmark.
_BM_CANDIDATES = (64, 128, 256)
_BK_CANDIDATES = (256, 512, 1024)
_BN_CANDIDATES = (128, 256)


def enumerate_blocks(m: int, k: int, n: int, itemsize: int = 4,
                     max_candidates: int = 8) -> List[Tuple[int, int, int]]:
    """Block-geometry candidates for the autotuner: the heuristic's
    :func:`pick_block` choice first (so the prior is always measured),
    then a small cap grid around it, clipped to the padded problem
    dims, deduplicated, and filtered to blocks whose working set fits
    VMEM."""
    sub = sublane_for(itemsize)
    m_cap, k_cap, n_cap = ceil_to(m, sub), ceil_to(k, LANE), ceil_to(n, LANE)
    blocks = [pick_block(m, k, n, itemsize)]
    for bm in _BM_CANDIDATES:
        for bk in _BK_CANDIDATES:
            for bn in _BN_CANDIDATES:
                b = (min(bm, m_cap), min(bk, k_cap), min(bn, n_cap))
                if b in blocks:
                    continue
                if block_vmem_bytes(*b, itemsize=itemsize) > VMEM_BUDGET_BYTES:
                    continue
                blocks.append(b)
    return blocks[:max_candidates]
