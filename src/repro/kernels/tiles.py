"""TPU tile geometry shared by the kernels and the kernel selector.

One source of truth for the hardware granules the Pallas kernels tile
against, so the compile-time selector (``repro.core.selection``) reasons
about exactly the blocks the kernels will use.
"""

from __future__ import annotations

from typing import Tuple

#: MXU/VPU lane width (minor-most dim granule for f32).
LANE = 128
#: Sublane granule for f32 (second-minor dim).
SUBLANE = 8
#: Per-core VMEM the block working set must fit well under (~16 MiB on
#: current TPUs; the budget is the full size — callers compare their
#: resident tiles against it).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def ceil_to(n: int, align: int) -> int:
    """Round ``n`` up to a multiple of ``align`` (the one copy of the
    granule-rounding convention)."""
    return -(-n // align) * align


def pick_block(m: int, k: int, n: int) -> Tuple[int, int, int]:
    """VMEM-aware block choice for the fused matmul: x(bm,bk) + w(bk,bn)
    + acc/out(bm,bn) in f32 must fit well under VMEM; keep MXU-aligned."""
    bm = min(256, ceil_to(m, SUBLANE))
    bn = min(256, ceil_to(n, LANE))
    bk = min(512, ceil_to(k, LANE))
    return bm, bk, bn
