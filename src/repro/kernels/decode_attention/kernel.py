"""Pallas TPU kernel: single-token GQA decode attention.

The paper identifies the matrix-*vector* product as "the most important
operation" (§3.3) and engineers its register schedule.  The LLM-decode
analogue is attention against a long KV cache with a single new query
token: a chain of GEMV-shaped contractions that is memory-bound on the
KV stream.  The TPU rendition:

* grid (batch, kv_head, S/bs): each instance owns the G = H/Hkv query
  heads of one KV head — the GQA group is the register-batch (§3.3);
* K/V stream through VMEM in (bs × D) tiles; the online-softmax state
  (m, l, acc) lives in VMEM scratch across the S dimension — the
  accumulator never round-trips to HBM (the paper's "results are
  written to the destination addresses" only once per batch);
* optional Schraudolph exp epilogue (`fast=True`) ties in §3.4.

Padding rows of the KV cache (beyond `length`) are masked with -inf
before the online max/sum.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..fast_act.kernel import _exp_body

DEFAULT_BS = 512  # KV rows per tile


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, ns: int, bs: int,
                   scale: float, fast: bool):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]         # (G, D)
    k = k_ref[0, :, 0, :]   # (bs, D)
    v = v_ref[0, :, 0, :]   # (bs, D)

    scores = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale               # (G, bs)

    # Mask rows beyond the valid context length of this batch element.
    length = len_ref[pl.program_id(0)]
    pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < length, scores, -jnp.inf)

    m_prev = m_ref[...]                      # (G, 1)
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    exp = _exp_body if fast else jnp.exp
    # exp(-inf) under the Schraudolph body is exp(clip(-inf,-87,88)) ≈ 0.
    p = exp(scores - m_new)                  # (G, bs)
    p = jnp.where(pos < length, p, 0.0)
    alpha = exp(m_prev - m_new)              # (G, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s_idx == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def decode_attention_p(
    q: jnp.ndarray,          # (B, H, D) f32
    k_cache: jnp.ndarray,    # (B, S, Hkv, D) f32
    v_cache: jnp.ndarray,    # (B, S, Hkv, D) f32
    lengths: jnp.ndarray,    # (B,) int32
    *,
    scale: Optional[float] = None,
    fast: bool = False,
    bs: int = DEFAULT_BS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas single-token decode attention over a padded KV cache:
    one grid step per (batch, kv-head), KV streamed in ``bs``-row
    tiles, rows past each sequence's length masked in-kernel."""
    b, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = h // hkv
    assert g * hkv == h, (h, hkv)
    bs = min(bs, s)
    if s % bs:
        # Pad the KV stream to a tile multiple; padded rows sit beyond
        # every valid length and are masked inside the kernel.
        pad = bs - s % bs
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s += pad
    ns = s // bs
    scale = scale if scale is not None else d ** -0.5

    qg = q.reshape(b, hkv, g, d)
    kernel = functools.partial(
        _decode_kernel, ns=ns, bs=bs, scale=scale, fast=fast
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, hi, si, lens: (bi, si, hi, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, hi, si, lens: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
