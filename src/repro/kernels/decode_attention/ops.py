"""Jit'd public wrapper for decode attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .kernel import decode_attention_p

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: Optional[jnp.ndarray] = None,
    *,
    scale: Optional[float] = None,
    fast: bool = False,
    use_pallas: bool = False,
    bs: Optional[int] = None,
) -> jnp.ndarray:
    """``bs`` overrides the default KV-tile depth of the Pallas kernel
    (the autotuner's measured geometry)."""
    b, _, _ = q.shape
    s = k_cache.shape[1]
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    if not use_pallas:
        return ref.decode_attention_ref(
            q, k_cache, v_cache, lengths, scale=scale, fast=fast
        )
    kw = {} if bs is None else {"bs": int(bs)}
    return decode_attention_p(
        q.astype(jnp.float32),
        k_cache.astype(jnp.float32),
        v_cache.astype(jnp.float32),
        lengths.astype(jnp.int32),
        scale=scale,
        fast=fast,
        interpret=not _ON_TPU,
        **kw,
    )
