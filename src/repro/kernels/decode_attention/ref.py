"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..fast_act import ref as fast_ref


def decode_attention_ref(
    q: jnp.ndarray,          # (B, H, D)
    k_cache: jnp.ndarray,    # (B, S, Hkv, D)
    v_cache: jnp.ndarray,    # (B, S, Hkv, D)
    lengths: Optional[jnp.ndarray] = None,  # (B,) int32 valid-context lengths
    *,
    scale: Optional[float] = None,
    fast: bool = False,
) -> jnp.ndarray:
    """Pure-lax grouped-query decode attention — the golden reference
    the Pallas kernel is tested against (length-masked, f32)."""
    b, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    # scores: (B, Hkv, G, S)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache) * scale
    if lengths is not None:
        mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
        scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = fast_ref.schraudolph_exp(scores - m) if fast else jnp.exp(scores - m)
    if lengths is not None:
        e = jnp.where(mask, e, 0.0)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(b, h, d)
