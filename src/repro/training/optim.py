"""Optimizer: AdamW (from scratch — no optax in this environment) plus
the LR schedules the assigned archs use (cosine and MiniCPM's WSD).

Optimizer states shard exactly like their parameters; since params carry
"fsdp" (data-axis) sharding on their fan-in dim, the m/v moments are
ZeRO-sharded for free — GSPMD inserts the reduce-scatter/all-gather pair
around the update (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1         # WSD: final fraction spent decaying
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """LR at `step` (traced).  WSD = warmup/stable/decay (MiniCPM)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = cfg.total_steps
    if cfg.schedule == "constant":
        frac = jnp.float32(1.0)
    elif cfg.schedule == "wsd":
        decay_start = t * (1.0 - cfg.decay_frac)
        # stable at 1.0, then linear decay to min_lr_frac
        prog = jnp.clip((step - decay_start) / jnp.maximum(t - decay_start, 1),
                        0.0, 1.0)
        frac = 1.0 - (1.0 - cfg.min_lr_frac) * prog
    else:  # cosine
        prog = jnp.clip(step / t, 0.0, 1.0)
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * frac


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_axes(param_axes) -> Dict[str, Any]:
    """Moment tensors shard like their params (ZeRO via fsdp axes)."""
    return {"m": param_axes, "v": param_axes, "step": ()}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptConfig, params, grads, opt):
    """One AdamW step; returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (new_p, {"m": new_m, "v": new_v, "step": step},
            {"lr": lr, "grad_norm": gnorm})
