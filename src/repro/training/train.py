"""Train-step factory: microbatched grad accumulation, gradient
compression with error feedback, AdamW update.

The whole step is ONE jitted program (the paper's "nothing about the
network structure is interpreted at call time" applied to training):
the microbatch loop is a ``lax.scan``, the optimizer update follows in
the same XLA program, and GSPMD schedules the ZeRO collectives around
it.  ``donate_argnums`` hands the old params/opt-state buffers back to
XLA — the training-loop analogue of the paper's in-place tensor reuse.

Gradient compression: cross-microbatch gradients are carried in bf16
with an f32 error-feedback accumulator (the round-off is fed back into
the next microbatch's gradient before quantization), so the persistent
accumulator traffic is half-width while the update stays unbiased in
expectation.  At 1000+ node scale the same trick applies to the
cross-pod reduce; GSPMD owns that collective, so the expressible site
is the accumulator (noted in DESIGN.md §What-changed).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model
from . import optim


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: optim.OptConfig = dataclasses.field(default_factory=optim.OptConfig)
    microbatches: int = 1           # grad-accumulation steps
    compress_grads: bool = False    # bf16 accumulator + error feedback
    cast_params: bool = False       # §Perf: compute layers on a bf16 copy
                                    # (f32 masters; halves the ZeRO
                                    # all-gather bytes per layer)
    pregather_params: bool = False  # gather the bf16 copy ONCE per step
                                    # (ZeRO-1 layout).  Affordable up to
                                    # ~30B params/16-way TP; keep off for
                                    # 671B (the copy itself is 84 GB/dev)


def init_state(model: Model, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": optim.adamw_init(params)}


def state_axes(model: Model) -> Dict[str, Any]:
    axes = model.param_axes()
    return {"params": axes, "opt": optim.opt_state_axes(axes)}


def make_train_step(model: Model, tc: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        if tc.cast_params:
            # ZeRO-1 layout: masters/moments stay fsdp-sharded, but the
            # bf16 COMPUTE copy is gathered once per step (the explicit
            # un-fsdp constraint below) — without it GSPMD re-gathers
            # every layer's weights in every microbatch.  Gradients flow
            # back through the cast, arriving f32 for the optimizer.
            from ..distributed import sharding as shd
            axes = state_axes(model)["params"]
            is_ax = lambda x: isinstance(x, tuple)
            flat_p, treedef = jax.tree.flatten(params)
            flat_a = jax.tree.flatten(axes, is_leaf=is_ax)[0]

            def cast(p, ax):
                if p.dtype == jnp.float32 and p.ndim >= 2:
                    p = p.astype(jnp.bfloat16)
                if not tc.pregather_params:
                    return p
                ax2 = tuple(None if a == "fsdp" else a for a in ax)
                return shd.logical(p, *ax2)

            params = jax.tree.unflatten(
                treedef, [cast(p, a) for p, a in zip(flat_p, flat_a)])
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def single(state, batch):
        loss, grads = grad_fn(state["params"], batch)
        params, opt, m = optim.adamw_update(tc.opt, state["params"],
                                            grads, state["opt"])
        return ({"params": params, "opt": opt},
                {"loss": loss, **m})

    def microbatched(state, batch):
        n = tc.microbatches

        def split(x):
            b = x.shape[0]
            assert b % n == 0, (b, n)
            return x.reshape(n, b // n, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        gdtype = jnp.bfloat16 if tc.compress_grads else jnp.float32
        acc0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, gdtype), state["params"])
        err0 = (jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            if tc.compress_grads else None)

        def body(carry, mb):
            acc, err, loss_sum = carry
            loss, grads = grad_fn(state["params"], mb)
            if tc.compress_grads:
                # quantize with error feedback: e <- (g+e) - bf16(g+e)
                corrected = jax.tree.map(
                    lambda g, e: g.astype(jnp.float32) + e, grads, err)
                q = jax.tree.map(lambda c: c.astype(jnp.bfloat16), corrected)
                err = jax.tree.map(
                    lambda c, qq: c - qq.astype(jnp.float32), corrected, q)
                acc = jax.tree.map(jnp.add, acc, q)
            else:
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, err, loss_sum + loss), None

        (acc, _, loss_sum), _ = jax.lax.scan(
            body, (acc0, err0, jnp.float32(0.0)), micro)
        grads = jax.tree.map(lambda a: a.astype(jnp.float32) / n, acc)
        params, opt, m = optim.adamw_update(tc.opt, state["params"],
                                            grads, state["opt"])
        return ({"params": params, "opt": opt},
                {"loss": loss_sum / n, **m})

    return single if tc.microbatches == 1 else microbatched


def make_jitted_train_step(model: Model, tc: TrainConfig, mesh=None,
                           donate: bool = True):
    """jit + shard the train step for `mesh` (None -> single device)."""
    from ..distributed import sharding as shd

    step_fn = make_train_step(model, tc)
    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    with shd.use_mesh(mesh):
        st_axes = state_axes(model)
        in_state = jax.tree.map(
            lambda ax: shd.named_sharding(mesh, *ax), st_axes,
            is_leaf=lambda x: isinstance(x, tuple))
        batch_sharding = shd.named_sharding(mesh, "batch")
    return jax.jit(
        step_fn,
        in_shardings=(in_state, batch_sharding),
        out_shardings=(in_state, None),
        donate_argnums=(0,) if donate else (),
    )
