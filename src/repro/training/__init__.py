from .optim import OptConfig, adamw_init, adamw_update, schedule_lr
from .train import TrainConfig, init_state, make_train_step, make_jitted_train_step, state_axes
