"""Serving CLI — a thin driver over ``repro.serve``.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --smoke --requests 16 --slots 8 --max-new 16

Compiles the model through ``repro.compile(target="engine")``, builds
the continuous-batching scheduler, drains a synthetic request queue and
prints the scheduler's metrics summary (TTFT, tok/s, batch occupancy) —
the serving analogue of the paper's Table 1 timing.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def parse_sharding_rules(text):
    """CLI spelling of ``CompileOptions.sharding_rules``: comma-separated
    ``logical=axis`` pairs, ``+`` joining multiple mesh axes and an
    empty right-hand side deleting the rule (forces replication) —
    e.g. ``"batch=pod+data,kv_seq=model,seq="``."""
    rules = []
    for part in str(text).split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad sharding rule {part!r}; expected logical=axis "
                f"(e.g. 'kv_seq=model,batch=pod+data,seq=')")
        name, axes = part.split("=", 1)
        axes = tuple(a.strip() for a in axes.split("+") if a.strip())
        rules.append((name.strip(), axes or None))
    return tuple(rules)


def main(argv=None) -> int:
    """CLI entry: compile ``--arch`` for the engine target, serve a
    synthetic queue, print (or ``--json``-dump) the metrics summary."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--admission", default="fcfs",
                    choices=("fcfs", "shortest", "deadline"))
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="first-token SLO (ms) attached to every synthetic "
                         "request; summary() then reports slo_violations")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: prompts advance this many "
                         "tokens per step, interleaved with decodes "
                         "(must divide --max-len)")
    ap.add_argument("--prefix-cache", type=int, default=0,
                    help="shared prompt-head KV snapshots to keep "
                         "(requires --prefill-chunk); 0 = off")
    ap.add_argument("--precision", default="exact",
                    choices=("exact", "fast", "f32", "bf16"),
                    help="compiled precision; bf16 = weight-only "
                         "storage cast for the engine target (int8/"
                         "mixed need the graph pipeline and are "
                         "rejected by the engine target) — the active "
                         "precision + decision counts land in "
                         "summary()['precision']")
    ap.add_argument("--calibrate", type=int, default=None, metavar="N",
                    help="calibration batches, forwarded to "
                         "CompileOptions for graph-routed precisions")
    ap.add_argument("--no-fold", action="store_true")
    ap.add_argument("--buckets", action="store_true", default=None,
                    help="shape-polymorphic serving: decode at the best "
                         "warm batch bucket, prefill per length bucket, "
                         "background compile of cold buckets")
    ap.add_argument("--no-buckets", dest="buckets", action="store_false",
                    help="fixed-shape serving (the default)")
    ap.add_argument("--mesh", default=None,
                    help="serve over a device mesh, e.g. 'data=4,model=2': "
                         "batch rows shard over data, the decode KV cache "
                         "over model (the kv_seq rule); the mesh is a "
                         "compile input (CompileOptions.mesh), so the "
                         "scheduler inherits it from the executable")
    ap.add_argument("--sharding-rules", default=None,
                    help="logical-axis rule overrides, e.g. "
                         "'kv_seq=model,batch=pod+data,seq=' (empty "
                         "right-hand side forces replication)")
    ap.add_argument("--json", action="store_true",
                    help="print the metrics summary as JSON")
    args = ap.parse_args(argv)

    import repro
    from repro.configs import get_config
    from repro.serve import Request

    cfg = get_config(args.arch, smoke=args.smoke)

    policy = None
    if args.buckets:
        policy = repro.BucketPolicy.default(max_batch=args.slots,
                                            max_len=args.max_len)
    # The mesh rides the compile options (one mesh spelling everywhere:
    # CLI -> MeshSpec -> CompileOptions -> SchedulerOptions default).
    mesh = repro.MeshSpec.parse(args.mesh) if args.mesh else None
    rules = (parse_sharding_rules(args.sharding_rules)
             if args.sharding_rules else None)

    t0 = time.perf_counter()
    exe = repro.compile(cfg, repro.CompileOptions(
        target="engine", precision=args.precision,
        calibrate=args.calibrate, mesh=mesh, sharding_rules=rules))
    sched = repro.serve(exe, repro.SchedulerOptions(
        slots=args.slots, max_len=args.max_len, admission=args.admission,
        fold=not args.no_fold, buckets=policy,
        prefill_chunk=args.prefill_chunk,
        prefix_cache=args.prefix_cache))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        sched.submit(Request(uid=i,
                             prompt=rng.integers(0, cfg.vocab, plen),
                             max_new_tokens=args.max_new,
                             slo_ms=args.slo_ms))
    t_build = time.perf_counter() - t0
    # progress goes to stderr so that --json leaves stdout parseable
    print(f"[serve] scheduler up in {t_build:.2f}s "
          f"(norm folds: {sched.fold_report['folds']})",
          file=sys.stderr if args.json else sys.stdout, flush=True)

    done = sched.run()
    summary = sched.summary()
    sched.shutdown()
    if args.json:
        print(json.dumps(summary, indent=2), flush=True)
    else:
        print(f"[serve] {summary['completed']} completions, "
              f"{summary['total_new_tokens']} tokens "
              f"({(summary['tokens_per_s'] or 0):.1f} tok/s, "
              f"mean TTFT {(summary['mean_ttft'] or 0) * 1e3:.0f}ms, "
              f"occupancy {(summary['mean_batch_occupancy'] or 0):.2f}"
              f"/{args.slots})", flush=True)
        if "precision" in summary:
            pr = summary["precision"]
            print(f"[serve] precision {pr['precision']}"
                  + (f", decisions {pr['decisions']}"
                     if pr.get("decisions") else ""), flush=True)
        if "runtime" in summary:
            rt = summary["runtime"]
            print(f"[serve] buckets: {rt['bucket_hits']} hits, "
                  f"{rt['bucket_misses']} misses, "
                  f"{rt['background_compiles']} background compiles, "
                  f"{rt['compile_stalls']} stalls, "
                  f"pad waste {rt['pad_waste_frac']:.1%}", flush=True)
        if "sharding" in summary:
            sh = summary["sharding"]
            per = {a: f"{v['count']}x/{v['bytes'] / 1e3:.1f}KB"
                   for a, v in sh["collectives"]["per_axis"].items()}
            print(f"[serve] mesh {sh['mesh']} ({sh['devices']} devices): "
                  f"collectives {per or 'none'}, "
                  f"faults {len(summary.get('faults', []))}", flush=True)
        for c in sorted(done, key=lambda c: c.uid)[:4]:
            print(f"  uid={c.uid} reason={c.finish_reason} "
                  f"tokens={c.tokens[:8]}...", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
