"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --smoke --requests 16 --slots 4 --max-new 16

Builds the engine (compile-at-load, norm-fold, slot-level continuous
batching) and drains a synthetic request queue, reporting per-phase
latency stats — the serving analogue of the paper's Table 1 timing.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--no-fold", action="store_true")
    args = ap.parse_args(argv)

    import repro
    from repro.configs import get_config
    from repro.inference import Request

    cfg = get_config(args.arch, smoke=args.smoke)

    t0 = time.perf_counter()
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    eng = exe.serve(slots=args.slots, max_len=args.max_len,
                    fold=not args.no_fold)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, plen),
                           max_new_tokens=args.max_new))
    t_build = time.perf_counter() - t0
    print(f"[serve] engine up in {t_build:.2f}s "
          f"(norm folds: {eng.fold_report['folds']})", flush=True)

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(c.tokens) for c in done)
    print(f"[serve] {len(done)} completions, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s)",
          flush=True)
    for c in sorted(done, key=lambda c: c.uid)[:4]:
        print(f"  uid={c.uid} tokens={c.tokens[:8]}...", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
