"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests must see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod stacks 2 pods -> 512 chips.
    The "pod" axis composes with "data" for the batch dimension (pure DP
    across pods), so the only cross-pod collective is the gradient
    reduce — the realistic 2-pod deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1×N (data, model) mesh — used by
    tests/examples on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
CHIP_HBM_BYTES = 16 * 2 ** 30     # 16 GiB
