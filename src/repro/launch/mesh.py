"""Production mesh builders, described as :class:`repro.MeshSpec`s.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests must see the real single device.

The specs here are the same objects ``CompileOptions(mesh=...)`` and
``SchedulerOptions(mesh=...)`` take (see :mod:`repro.dist.mesh`), so the
launch scripts, the serve CLI and the compiler all speak one mesh
spelling — ``MeshSpec.build()`` late-binds to real devices and raises a
typed :class:`repro.MeshUnavailableError` naming the unfillable axes
when the device set is too small.
"""

from __future__ import annotations

from ..dist.mesh import MeshSpec


def production_mesh_spec(*, multi_pod: bool = False) -> MeshSpec:
    """16×16 = 256 chips per pod; multi_pod stacks 2 pods -> 512 chips.
    The "pod" axis composes with "data" for the batch dimension (pure DP
    across pods), so the only cross-pod collective is the gradient
    reduce — the realistic 2-pod deployment."""
    if multi_pod:
        return MeshSpec(axes=(("pod", 2), ("data", 16), ("model", 16)))
    return MeshSpec(axes=(("data", 16), ("model", 16)))


def host_mesh_spec() -> MeshSpec:
    """Whatever devices exist, as a 1×N (data, model) mesh — used by
    tests/examples on CPU."""
    import jax

    return MeshSpec(axes=(("data", 1), ("model", len(jax.devices()))))


def make_production_mesh(*, multi_pod: bool = False):
    """The live ``jax.sharding.Mesh`` of :func:`production_mesh_spec`
    (back-compat shim — new code should carry the spec and ``build()``
    at the last moment)."""
    return production_mesh_spec(multi_pod=multi_pod).build()


def make_host_mesh():
    """The live ``jax.sharding.Mesh`` of :func:`host_mesh_spec`."""
    return host_mesh_spec().build()


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
CHIP_HBM_BYTES = 16 * 2 ** 30     # 16 GiB
