"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt /tmp/ck

Wires together every substrate layer: config registry → model → jitted
train step (microbatching, grad compression) → synthetic data pipeline →
async checkpointer (resume-aware) → straggler watchdog → metrics log.
On real hardware the same driver runs under a production mesh; on CPU it
uses whatever devices exist (tests/examples use --smoke configs).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    """CLI entry: run the training loop for ``--arch`` with optional
    microbatching, grad compression and checkpointing."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt", default=None, help="checkpoint directory")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--deadline-s", type=float, default=300.0,
                    help="straggler watchdog per-step deadline")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "none"],
                    help="'host': 1×N mesh over local devices")
    args = ap.parse_args(argv)

    from repro.checkpoint import Checkpointer, install_sigterm_hook
    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticTokens
    from repro.distributed import StragglerWatchdog
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.models import get_model
    from repro.training import (OptConfig, TrainConfig, init_state,
                                make_jitted_train_step, state_axes)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    tc = TrainConfig(
        opt=OptConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 20),
                      schedule=cfg.lr_schedule),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads)

    mesh = make_host_mesh() if args.mesh == "host" else None
    step_fn = make_jitted_train_step(model, tc, mesh=mesh, donate=True)

    data = SyntheticTokens(DataConfig(
        vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq))

    ck: Optional[Checkpointer] = Checkpointer(args.ckpt) if args.ckpt \
        else None
    start = 0
    with shd.use_mesh(mesh):
        state = init_state(model, jax.random.PRNGKey(0))
        if ck is not None:
            latest = ck.latest_step()
            if latest is not None:
                state = ck.restore(latest, state)
                start = latest + 1
                print(f"[train] resumed from step {latest}", flush=True)

        if ck is not None:
            install_sigterm_hook(
                lambda: ck.save(int(state["opt"]["step"]), state,
                                blocking=True))

        wd = StragglerWatchdog(
            args.deadline_s,
            on_timeout=lambda s, el: print(
                f"[watchdog] step {s} exceeded {el:.1f}s", flush=True))

        t_start = time.time()
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            with wd.step(i):
                state, metrics = step_fn(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(json.dumps({
                    "step": i,
                    "loss": round(float(metrics["loss"]), 4),
                    "lr": float(metrics["lr"]),
                    "grad_norm": round(float(metrics["grad_norm"]), 3),
                    "elapsed_s": round(time.time() - t_start, 1),
                }), flush=True)
            if ck is not None and i > 0 and i % args.ckpt_every == 0:
                ck.save(i, state)
        if ck is not None:
            ck.save(args.steps - 1, state, blocking=True)
    print("[train] done", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
