"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE — a
scanned 48-layer model reports ~1/48th of its real FLOPs, and a
collective inside the layer loop is counted once instead of 48 times.
Since every decoder stack here scans over layers (HLO size O(1) in
depth — required to compile 61-layer 671B programs), the dry-run needs
its own analyzer.  Two sources are combined:

* **pre-optimization HLO** (``lowered.as_text("hlo")``, global shapes,
  fully-typed params, simple loop conditions) → exact matmul/conv FLOPs
  with every op weighted by the product of its enclosing while trip
  counts.  Global FLOPs / chips = per-device (up to partition padding,
  which is reported separately by the memory analysis).
* **post-optimization HLO** (``compiled.as_text()``, per-device shapes,
  fused) → collective bytes (result-buffer bytes of all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute ×
  trip counts) and an HBM-traffic proxy (result bytes of top-level
  (post-fusion) ops × trip counts).

Trip counts are recovered from each while condition's s32[] constant
(jax lowers scans to ``compare(iv, constant), direction=LT``; after
optimization the compare may be fused but the constant stays in the
condition computation).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z0-9\-]+)\(")
_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*[\({]")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALLED_RE = {
    "to_apply": re.compile(r"to_apply=%?([\w\.\-]+)"),
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    # lax.cond branches: each taken a fraction of the time; weighting
    # them 1/n_branches matches the causal-skip usage exactly (half the
    # (q,kv) chunk pairs are above the diagonal).
    "branch_t": re.compile(r"true_computation=%?([\w\.\-]+)"),
    "branch_f": re.compile(r"false_computation=%?([\w\.\-]+)"),
}
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _tshape_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        n = _DTYPE_BYTES.get(m.group(1), 0)
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    """One HLO instruction: name, result type string, opcode, raw line."""
    name: str
    type_str: str          # full result type (may be a tuple)
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    """A named HLO computation: its ops and param name -> type map."""
    name: str
    ops: List[Op]
    params: Dict[str, str]           # param name -> type string


def parse_hlo(text: str) -> Dict[str, Computation]:
    """Parse HLO text into ``{computation name: Computation}`` (line
    grammar only — headers end with ``{``, ops contain ``\" = \"``)."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("HloModule"):
            continue
        # Computation headers: "name {", "%name (a: t[..]) -> t[..] {",
        # "ENTRY %name (...) -> ... {"  — never contain " = ".
        if s.endswith("{") and " = " not in s:
            m = _HDR_RE.match(s)
            if m:
                params = {}
                if ") -> " in s:
                    params = dict(_PARAM_RE.findall(s[: s.rfind(") -> ")]))
                cur = Computation(m.group(1), [], params)
                comps[cur.name] = cur
                continue
        if cur is None or " = " not in s:
            continue
        m = _OP_RE.match(s)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), s))
    return comps


# ---------------------------------------------------------------------------
# Execution multipliers (product of enclosing while trip counts)
# ---------------------------------------------------------------------------
def _trip_count(cond: Computation) -> Optional[int]:
    consts = [int(m.group(1)) for op in cond.ops
              for m in [_CONST_RE.search(op.line)] if m]
    if len(consts) == 1:
        return consts[0]
    if consts:
        # multiple constants: prefer the one inside a compare op
        for op in cond.ops:
            if "compare(" in op.line:
                m = _CONST_RE.search(op.line)
                if m:
                    return int(m.group(1))
        return max(consts)
    return None


def _called(line: str) -> List[Tuple[str, str]]:
    out = []
    for kind, rx in _CALLED_RE.items():
        m = rx.search(line)
        if m:
            out.append((kind, m.group(1)))
    m = _BRANCHES_RE.search(line)
    if m:
        for b in m.group(1).split(","):
            out.append(("branch", b.strip().lstrip("%")))
    return out


def _multipliers(comps: Dict[str, Computation],
                 shard_scale: float = 1.0) -> Tuple[Dict[str, float], int]:
    """shard_scale: multiplier applied on edges INTO shard_map bodies
    (``xla.sdy.manual_computation_body*``).  Pre-optimization HLO mixes
    GLOBAL shapes (GSPMD-auto ops) with PER-SHARD shapes inside manual
    computations; scaling the latter by the device count keeps both in
    global units so a single /chips at the end is correct."""
    called_names = set()
    for c in comps.values():
        for op in c.ops:
            for _, n in _called(op.line):
                called_names.add(n)
    mult = {n: 1.0 for n in comps if n not in called_names}
    unresolved = 0
    changed, guard = True, 0
    while changed and guard < 10_000:
        changed, guard = False, guard + 1
        for cname, comp in comps.items():
            m = mult.get(cname)
            if m is None:
                continue
            for op in comp.ops:
                called = _called(op.line)
                n_branches = sum(1 for k, _ in called
                                 if k.startswith("branch"))
                for kind, target in called:
                    if target not in comps:
                        continue
                    factor = 1.0
                    if kind in ("body", "condition"):
                        condname = _CALLED_RE["condition"].search(op.line)
                        tc = None
                        if condname and condname.group(1) in comps:
                            tc = _trip_count(comps[condname.group(1)])
                        if tc is None:
                            tc, unresolved = 1, unresolved + 1
                        factor = float(tc)
                    elif kind.startswith("branch") and n_branches > 1:
                        factor = 1.0 / n_branches
                    if "manual_computation_body" in target:
                        factor *= shard_scale
                    new = m * factor
                    if mult.get(target, 0.0) < new:
                        mult[target] = new
                        changed = True
    return mult, unresolved


# ---------------------------------------------------------------------------
# FLOPs from the pre-optimization module (typed, global shapes)
# ---------------------------------------------------------------------------
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _symbol_table(comp: Computation) -> Dict[str, str]:
    tab = dict(comp.params)
    for op in comp.ops:
        tab[op.name] = op.type_str
    return tab


def _first_operands(line: str) -> List[str]:
    idx = line.find("(")
    depth, end = 1, len(line)
    inner_start = idx + 1
    for i in range(inner_start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = line[inner_start:end]
    return [o.strip().split(" ")[-1].lstrip("%") for o in inner.split(",")
            if o.strip()]


def _resolve_dims(name: str, tab: Dict[str, str],
                  comp: Computation) -> Optional[List[int]]:
    t = tab.get(name)
    if t is None:
        return None
    # plain array type
    m = _TYPE_RE.search(t)
    if m and not t.startswith("("):
        return [int(x) for x in m.group(2).split(",") if x]
    return None


def _dot_flops(op: Op, tab: Dict[str, str], comp: Computation) -> float:
    result_elems = _elems(_TYPE_RE.search(op.type_str).group(2)) \
        if _TYPE_RE.search(op.type_str) else 0
    k = 1
    mc = _LHS_CONTRACT_RE.search(op.line)
    operands = _first_operands(op.line)
    if mc and operands:
        lhs_dims = _resolve_dims(operands[0], tab, comp)
        if lhs_dims:
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    k *= lhs_dims[int(ci)]
    return 2.0 * result_elems * k


def _conv_flops(op: Op, tab: Dict[str, str], comp: Computation) -> float:
    result_elems = _elems(_TYPE_RE.search(op.type_str).group(2)) \
        if _TYPE_RE.search(op.type_str) else 0
    operands = _first_operands(op.line)
    k = 1
    if len(operands) >= 2:
        rhs = _resolve_dims(operands[1], tab, comp)
        if rhs and len(rhs) >= 2:
            for d in rhs[:-1]:       # HWIO kernel: all but output feature
                k *= d
    return 2.0 * result_elems * k


def flops_from_pre(text: str, chips: int = 1) -> Tuple[float, int]:
    """(total FLOPs with loop multipliers, unresolved whiles) from the
    pre-optimization module (GLOBAL shapes; shard_map bodies are
    per-shard and get scaled up by `chips`)."""
    comps = parse_hlo(text)
    mult, unresolved = _multipliers(comps, shard_scale=float(chips))
    total = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        tab = _symbol_table(comp)
        for op in comp.ops:
            if op.opcode == "dot":
                total += m * _dot_flops(op, tab, comp)
            elif op.opcode == "convolution":
                total += m * _conv_flops(op, tab, comp)
    return total, unresolved


# ---------------------------------------------------------------------------
# Bytes + collectives from the post-optimization module (per-device)
# ---------------------------------------------------------------------------
def bytes_from_post(text: str) -> Tuple[float, Dict[str, float], int]:
    """Trip-count-weighted (hbm_bytes, collective bytes by kind,
    unresolved-while count) from post-optimization per-device HLO."""
    comps = parse_hlo(text)
    mult, unresolved = _multipliers(comps)
    coll = {k: 0.0 for k in _COLLECTIVES}
    hbm = 0.0
    # Fusions whose root is an in-place update (dynamic-update-slice /
    # scatter) alias their operand buffer — XLA writes only the updated
    # rows (e.g. a scan's per-layer KV-cache write), not the result
    # shape.  Counting their full result would claim 48× the cache per
    # decode step (measured before this fix).
    inplace_roots = set()
    for cname, comp in comps.items():
        if comp.ops and comp.ops[-1].opcode in ("dynamic-update-slice",
                                                "scatter"):
            inplace_roots.add(cname)
    skip = ("parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "partition-id", "replica-id",
            # in-place update ops alias their operand buffer (donation /
            # XLA buffer aliasing): traffic is O(update), not O(buffer).
            # The update operand is not recoverable from the optimized
            # text, so count 0 — vs the full-buffer cost of the select-
            # based alternative, which IS a real whole-buffer rewrite.
            "scatter", "dynamic-update-slice")
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        for op in comp.ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base in _COLLECTIVES:
                coll[base] += m * _tshape_bytes(op.type_str)
            if cname.startswith(("fused_", "wrapped_")):
                continue            # fusion internals don't hit HBM
            if op.opcode in skip or op.opcode.endswith("-done"):
                continue
            if op.opcode == "fusion":
                called = _CALLED_RE["calls"].search(op.line)
                if called and called.group(1) in inplace_roots:
                    continue        # aliased in-place update fusion
            hbm += m * _tshape_bytes(op.type_str)
    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    return hbm, coll, unresolved


@dataclasses.dataclass
class HloCost:
    """Per-device cost rollup combining both HLO sources (see module
    docstring); ``unresolved_whiles > 0`` flags an untrusted count."""
    flops: float                       # per-device
    collective_bytes: Dict[str, float]
    hbm_bytes: float
    unresolved_whiles: int

    def as_dict(self) -> Dict:
        """JSON-serializable form for the dry-run artifacts."""
        return {"flops": self.flops,
                "collective_bytes": self.collective_bytes,
                "hbm_bytes": self.hbm_bytes,
                "unresolved_whiles": self.unresolved_whiles}


def analyze_lowered(lowered, compiled, chips: int) -> HloCost:
    """Analyze a jax ``lowered``/``compiled`` pair: exact FLOPs from
    the pre-optimization HLO, bytes from the post-optimization HLO."""
    flops_global, unres_pre = flops_from_pre(lowered.as_text("hlo"), chips)
    hbm, coll, unres_post = bytes_from_post(compiled.as_text())
    return HloCost(flops=flops_global / max(chips, 1),
                   collective_bytes=coll, hbm_bytes=hbm,
                   unresolved_whiles=unres_pre + unres_post)
