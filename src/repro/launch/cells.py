"""Cell lowering: one (arch × shape × mesh) -> lowered/compiled XLA.

This is the machinery behind the multi-pod dry-run and the roofline
benchmarks.  Everything is ShapeDtypeStruct-abstract: no parameter,
cache, or batch tensor is ever allocated.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ArchConfig, ShapeSpec, input_specs
from ..distributed import sharding as shd
from ..models import get_model
from ..training import TrainConfig, make_train_step
from ..training.optim import adamw_init, opt_state_axes


# ---------------------------------------------------------------------------
# Abstract state/batch specs + shardings
# ---------------------------------------------------------------------------
def _specs(tree) -> Any:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _divisible_sharding(mesh, spec: jax.ShapeDtypeStruct, axes):
    """NamedSharding for one leaf, keeping a logical axis only when its
    mesh axes evenly divide the dim (jit argument shardings must divide;
    e.g. vocab=122753 or kv_heads=8 on a 16-way axis fall back to
    replicated for that dim)."""
    rules = shd.current_rules()
    names = set(mesh.axis_names)
    parts = []
    used = set()
    for dim, ax in zip(spec.shape, tuple(axes) + (None,) * len(spec.shape)):
        val = rules.get(ax) if ax else None
        if val is None:
            parts.append(None)
            continue
        cand = (val,) if isinstance(val, str) else tuple(val)
        cand = tuple(a for a in cand if a in names and a not in used)
        pick = None
        # full tuple first, then each single axis
        options = [cand] + [(a,) for a in cand] if len(cand) > 1 \
            else [cand]
        for opt in options:
            if not opt:
                continue
            size = 1
            for a in opt:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                pick = opt
                break
        if pick is None:
            parts.append(None)
        else:
            parts.append(pick[0] if len(pick) == 1 else pick)
            used.update(pick)
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(*parts))


def _shardings(mesh, axes_tree, specs_tree):
    is_ax = lambda x: isinstance(x, tuple)
    flat_ax, treedef = jax.tree.flatten(axes_tree, is_leaf=is_ax)
    flat_sp = jax.tree.leaves(specs_tree)
    assert len(flat_ax) == len(flat_sp), (len(flat_ax), len(flat_sp))
    return jax.tree.unflatten(
        treedef, [_divisible_sharding(mesh, sp, ax)
                  for ax, sp in zip(flat_ax, flat_sp)])


def _batch_sharding(mesh, specs: Dict[str, jax.ShapeDtypeStruct]):
    """Shard dim0 over (pod, data) when divisible, else replicate."""
    return {k: _divisible_sharding(mesh, v, ("batch",))
            for k, v in specs.items()}


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D; decode counts one
    token per sequence."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens      # forward only
    return 2.0 * n * shape.global_batch  # decode: 1 token/seq forward


# ---------------------------------------------------------------------------
# Cell -> lowered
# ---------------------------------------------------------------------------
def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh,
               train_cfg: Optional[TrainConfig] = None,
               rules: Optional[Dict[str, Any]] = None):
    """Lower one cell on `mesh`; returns jax's Lowered object.

    `rules` overrides logical-axis mappings — e.g. {"fsdp": None} turns
    off ZeRO param sharding for serving cells (TP-resident weights, no
    per-layer all-gather: the paper's compile-time layout choice made at
    mesh scale)."""
    model = get_model(cfg)
    with shd.use_mesh(mesh, rules=rules):
        p_axes = model.param_axes()
        param_specs = _specs(jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0))))
        param_shardings = _shardings(mesh, p_axes, param_specs)
        b_specs = input_specs(cfg, shape)
        b_shardings = _batch_sharding(mesh, b_specs)

        if shape.kind == "train":
            tc = train_cfg or TrainConfig()
            step = make_train_step(model, tc)
            opt_specs = _specs(jax.eval_shape(
                lambda: adamw_init(param_specs)))
            state_specs = {"params": param_specs, "opt": opt_specs}
            state_shardings = {"params": param_shardings,
                               "opt": _shardings(
                                   mesh, opt_state_axes(p_axes),
                                   opt_specs)}
            fn = jax.jit(step,
                         in_shardings=(state_shardings, b_shardings),
                         out_shardings=(state_shardings, None),
                         donate_argnums=(0,))
            return fn.lower(state_specs, b_specs)

        cache_specs = _specs(jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)))
        c_axes = model.cache_axes()
        # caches are flat dicts of arrays; axes leaves are tuples
        cache_shardings = {k: _divisible_sharding(mesh, cache_specs[k],
                                                  c_axes[k])
                           for k in cache_specs}

        if shape.kind == "prefill":
            fn = jax.jit(
                lambda p, b, c: model.prefill(p, b, c),
                in_shardings=(param_shardings, b_shardings,
                              cache_shardings),
                out_shardings=(None, cache_shardings))
            return fn.lower(param_specs, b_specs, cache_specs)

        # decode: serve_step — one new token against a seq_len cache
        fn = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t),
            in_shardings=(param_shardings, cache_shardings,
                          b_shardings["tokens"]),
            out_shardings=(None, cache_shardings),
            donate_argnums=(1,))
        return fn.lower(param_specs, cache_specs, b_specs["tokens"])


def _cache_sharding(mesh, spec, axes):
    """NamedSharding for one cache leaf; drop batch sharding when the
    request batch doesn't divide the batch axes (long_500k B=1)."""
    n_batch = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_batch *= mesh.shape[a]
    fixed = []
    for dim, ax in zip(spec.shape, axes):
        if ax == "batch" and dim % n_batch != 0:
            fixed.append(None)
        else:
            fixed.append(ax)
    return shd.named_sharding(mesh, *fixed)


# ---------------------------------------------------------------------------
# Compiled-artifact analysis
# ---------------------------------------------------------------------------
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _nbytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype)
    if n is None:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the (post-SPMD) HLO.
    These are per-device program bytes."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result = <type> opname(<operands>) — take operand section
        for op in _COLLECTIVES:
            marker = f" {op}("
            # start-fusion variants: all-gather-start(, all-reduce-start(
            alt = f" {op}-start("
            idx = stripped.find(marker)
            if idx < 0:
                idx = stripped.find(alt)
            if idx < 0:
                continue
            operands = stripped[idx:]
            operands = operands[operands.find("(") + 1:]
            depth = 1
            end = 0
            for i, ch in enumerate(operands):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = operands[:end]
            for m in _SHAPE_RE.finditer(operands):
                out[op] += _nbytes(m.group(1), m.group(2))
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def analyze(lowered, compiled, cfg: ArchConfig, shape: ShapeSpec,
            mesh) -> Dict[str, Any]:
    """The roofline terms for one compiled cell (per §Roofline).

    ``cost_analysis`` counts while bodies ONCE (a scanned L-layer stack
    reports ~1/L of its FLOPs), so the primary numbers come from the
    trip-count-aware HLO analyzer; XLA's raw values are kept alongside
    for reference.
    """
    from .hlo_analysis import analyze_lowered
    from .mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

    chips = int(np.prod(list(mesh.shape.values())))
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    hc = analyze_lowered(lowered, compiled, chips)

    flops_dev = hc.flops
    bytes_dev = hc.hbm_bytes
    coll = hc.collective_bytes

    mem = compiled.memory_analysis()
    mem_stats = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_stats[k] = int(v)

    # All quantities are per-device (the HLO module is the post-SPMD
    # per-device program), so dividing by per-chip peaks equals
    # global/(chips×peak).
    compute_t = flops_dev / PEAK_FLOPS_BF16
    memory_t = bytes_dev / HBM_BW
    collective_t = coll["total"] / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    bottleneck = max(terms, key=terms.get)

    mflops = model_flops(cfg, shape)
    hlo_flops_global = flops_dev * chips
    return {
        "arch": cfg.name, "shape": shape.name,
        "mesh": dict(mesh.shape), "chips": chips,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "unresolved_whiles": hc.unresolved_whiles,
        "xla_raw_flops": float(cost.get("flops", 0.0)),
        "xla_raw_bytes": float(cost.get("bytes accessed", 0.0)),
        "memory_analysis": mem_stats,
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (
            (mflops / chips / PEAK_FLOPS_BF16) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    }
