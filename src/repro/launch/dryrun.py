"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The ``XLA_FLAGS`` line below MUST run before any other import — jax
locks the device count at first init, and the production meshes need
512 placeholder devices (2 pods × 16 × 16).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Each cell writes a JSON artifact under benchmarks/artifacts/dryrun/
(memory analysis, cost analysis, collective bytes, roofline terms) that
EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline.py read.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str, overrides: dict = None) -> dict:
    """Lower + compile one (arch, shape, mesh) cell and write its
    JSON artifact (memory/cost/collective analysis) under *out_dir*."""
    from repro.configs import SHAPES, cell_supported, get_config
    from repro.launch import cells
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": why}
        _write(out_dir, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    # Baseline train step: 8 microbatches (per-device-per-microbatch
    # batch 2 on single-pod) — fits the 16 GiB HBM with headroom.
    from repro.training import TrainConfig
    lowered = cells.lower_cell(cfg, shape, mesh,
                               TrainConfig(microbatches=8))
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    print(compiled.memory_analysis())
    cost = compiled.cost_analysis()
    print({k: cost[k] for k in ("flops", "bytes accessed")
           if k in cost})

    rec = cells.analyze(lowered, compiled, cfg, shape, mesh)
    rec.update({"status": "ok", "mesh_kind": mesh_kind,
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2)})
    _write(out_dir, rec)
    return rec


def _write(out_dir: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec.get('mesh_kind', rec.get('mesh'))}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> int:
    """CLI entry: run one cell (``--arch/--shape/--mesh``) or sweep
    ``--all`` supported cells, returning the number of failures."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES, SHAPES

    cells_list = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells_list.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells_list = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells_list:
        tag = f"{arch} × {shape} × {args.mesh}"
        try:
            rec = run_cell(arch, shape, args.mesh, args.out)
            status = rec["status"]
            extra = (f" bottleneck={rec.get('bottleneck')}"
                     f" rf={rec.get('roofline_fraction', 0):.3f}"
                     if status == "ok" else f" ({rec.get('reason')})")
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"[dryrun] {tag}: FAILED", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
