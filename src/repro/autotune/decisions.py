"""Graph-level decision tuning — measure what the *passes* guess.

PR 5's kernel autotuner measures per-node *lowering* choices; this
module extends the same measure-once-remember-forever machinery to the
decisions the pass pipeline makes structurally:

* **fusion** — ``fuse_activation`` fuses every legal producer→activation
  pair; per site that is a guess (XLA sometimes schedules the unfused
  pair better on CPU).  Candidate choices: ``"fuse"`` / ``"no_fuse"``.
* **layout** — ``optimize_layout`` picks the dense kernel's storage
  layout (``"oi"`` contraction-major vs ``"io"``) from a row-count
  heuristic.  Candidate choices: ``"oi"`` / ``"io"``.
* **pipeline** — whole-pipeline variants from
  :func:`repro.core.passes.manager.pipeline_candidates`
  (``PassManager.default().without(...)`` registry surgery), measured on
  the fully lowered graph.

Each site is keyed by a **graph-region digest** — a canonical hash of
the affected subgraph's structure, shapes and dtypes that is invariant
to node naming and insertion order (see :func:`region_digest`) — so a
measured winner transfers to any model containing the same region, and
winners persist in the same fingerprinted
:class:`~repro.autotune.cache.TacticCache` the kernel tuner uses:
``CompileOptions(autotune="cached")`` replays every decision
cross-process with zero measurement.

Decisions are *applied* through tuning-site hooks the passes expose
(``tune.fuse`` / ``tune.layout`` node attrs, honored by
``fuse_activation`` and ``optimize_layout``); with ``autotune="off"``
no attr is ever written and the pipeline is bit-identical to the
heuristic compiler.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import Graph, ACTIVATIONS
from ..core.passes.fuse_activation import FUSABLE_PRODUCERS, TUNE_FUSE_ATTR
from ..core.passes.layout import TUNE_LAYOUT_ATTR
from ..core.passes.manager import PassManager, pipeline_candidates
from ..core.selection import select_kernels
from .cache import TacticCache, environment_fingerprint, tactic_key
from .measure import Deadline, bench_min_us
from .tuner import MEASURE_REPS, MEASURE_WARMUP

#: Bump when the digest canonicalization or the decision semantics
#: change — old cache entries must miss, not replay a stale meaning.
GRAPH_DECISION_VERSION = 1

#: Fraction of ``autotune_budget_ms`` graph-level tuning may spend;
#: the remainder is reserved for the per-node kernel tuner so a slow
#: pipeline-variant measurement can never starve kernel tactics.
GRAPH_BUDGET_FRACTION = 0.5


# ---------------------------------------------------------------------------
# region digest
# ---------------------------------------------------------------------------
def _node_struct_hash(node, graph: Graph, specs, internal: Dict[str, str]
                      ) -> str:
    """Canonical hash of one node: op, attrs (minus ``tune.*``), param
    roles with shapes/dtypes, epilogue, and inputs identified either by
    the producing region-node's hash (internal) or by shape+dtype
    (external) — never by tensor or node *name*."""
    ins = []
    for t in node.inputs:
        if t in internal:
            ins.append(["ref", internal[t]])
        else:
            s = specs[t]
            ins.append(["ext", list(s.shape), s.dtype])
    attrs = {k: v for k, v in sorted(node.attrs.items())
             if not k.startswith("tune.")}
    params = sorted(
        (role, list(graph.params[p].shape), str(graph.params[p].dtype))
        for role, p in node.params.items())
    payload = json.dumps(
        [node.op, attrs, params, node.epilogue,
         dict(sorted(node.epilogue_attrs.items())), ins],
        sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()


def region_digest(graph: Graph, node_names: Sequence[str]) -> str:
    """Digest of the subgraph induced by ``node_names``.

    Invariant to node/tensor naming and to node insertion order (each
    node hashes to a pure function of its content and its region-internal
    producers' hashes; the digest is over the *sorted* hash set), but
    sensitive to any structure, shape or dtype edit — exactly the
    identity a transferred tuning decision is valid for.
    """
    names = set(node_names)
    region = [n for n in graph.toposort() if n.name in names]
    if len(region) != len(names):
        missing = names - {n.name for n in region}
        raise KeyError(f"region names not in graph: {sorted(missing)}")
    specs = graph.infer_shapes()
    internal: Dict[str, str] = {}
    hashes: List[str] = []
    for node in region:
        h = _node_struct_hash(node, graph, specs, internal)
        internal[node.output] = h
        hashes.append(h)
    payload = json.dumps([f"v{GRAPH_DECISION_VERSION}", sorted(hashes)])
    return hashlib.sha256(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# tuning sites
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DecisionSite:
    """One graph-level tuning site: the decision kind, the node carrying
    the decision attr (``""`` for the whole-graph pipeline site), the
    region it is keyed by, and the candidate choice labels."""

    kind: str                     # "fusion" | "layout" | "pipeline"
    node: str
    region: Tuple[str, ...]
    digest: str
    choices: Tuple[str, ...]


def enumerate_sites(graph: Graph, *, passes: Optional[Sequence[str]] = None
                    ) -> List[DecisionSite]:
    """The tunable graph-level decisions of ``graph``, cheapest first.

    Fusion sites mirror ``fuse_activation``'s legality conditions on
    the *input* graph (direct producer→activation adjacency, single
    consumer); layout sites are every dense node; the single pipeline
    site is only emitted when the caller did not pin an explicit pass
    list (an explicit ``CompileOptions.passes`` is a user decision, not
    a tunable one).
    """
    sites: List[DecisionSite] = []
    for node in graph.nodes:
        if node.op == "dense":
            sites.append(DecisionSite(
                "layout", node.name, (node.name,),
                region_digest(graph, (node.name,)), ("io", "oi")))
    for act in graph.nodes:
        if act.op != "activation" or not ACTIVATIONS.get(
                act.attrs.get("fn"), False):
            continue
        src = graph.producer(act.inputs[0])
        if src is None or src.op not in FUSABLE_PRODUCERS:
            continue
        if src.epilogue not in (None, "linear"):
            continue
        if len(graph.consumers(src.output)) != 1:
            continue
        region = (src.name, act.name)
        sites.append(DecisionSite(
            "fusion", act.name, region,
            region_digest(graph, region), ("fuse", "no_fuse")))
    if passes is None and len(graph.nodes) > 1:
        variants = pipeline_candidates()
        sites.append(DecisionSite(
            "pipeline", "", tuple(n.name for n in graph.nodes),
            region_digest(graph, [n.name for n in graph.nodes]),
            tuple(variants)))
    return sites


def extract_region(graph: Graph, node_names: Sequence[str]) -> Graph:
    """A standalone mini-graph of just the named nodes: external inputs
    become graph inputs (shape+dtype from inference), referenced params
    are copied, and every region output not consumed inside the region
    becomes a graph output.  This is what decision candidates are
    measured on — the region's real shapes, isolated from the rest of
    the model."""
    names = set(node_names)
    region = [n for n in graph.toposort() if n.name in names]
    specs = graph.infer_shapes()
    produced = {n.output for n in region}
    mini = Graph()
    for node in region:
        for t in node.inputs:
            if t not in produced and t not in mini.inputs:
                mini.add_input(t, specs[t].shape, specs[t].dtype)
    for node in region:
        for p in node.params.values():
            if p not in mini.params:
                mini.add_param(p, graph.params[p])
        mini.add_node(node.op, node.name, list(node.inputs),
                      output=node.output, attrs=dict(node.attrs),
                      params=dict(node.params))
    consumed = {t for n in region for t in n.inputs}
    outs = [n.output for n in region if n.output not in consumed]
    mini.set_outputs(outs or [region[-1].output])
    return mini


# ---------------------------------------------------------------------------
# applying decisions
# ---------------------------------------------------------------------------
def apply_choice(graph: Graph, site: DecisionSite, choice: str) -> None:
    """Write the decision attr the pass hooks read.  Pipeline choices
    are not attrs (the caller swaps the pass list instead)."""
    if site.kind == "pipeline":
        return
    node = next(n for n in graph.nodes if n.name == site.node)
    if site.kind == "fusion":
        node.attrs[TUNE_FUSE_ATTR] = (choice == "fuse")
    elif site.kind == "layout":
        node.attrs[TUNE_LAYOUT_ATTR] = choice
    else:
        raise ValueError(f"unknown decision kind {site.kind!r}")


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------
def _compiled_probe(graph: Graph, pipeline, *, target: str, precision: str,
                    batch_size: int):
    """(jitted fn, args) running ``graph`` through ``pipeline`` and the
    real lowering/selection stack on seeded synthetic inputs — the same
    program shape the decision will produce in the executable."""
    import jax
    import jax.numpy as jnp

    from ..core.lowering import execute_graph

    g2, _ = PassManager(pipeline).run(graph)
    selection = select_kernels(g2, batch_size=batch_size, target=target,
                               precision=precision)
    params = {k: jnp.asarray(v) for k, v in g2.params.items()}
    input_names = list(g2.inputs)

    def program(*args):
        env = dict(zip(input_names, args))
        return execute_graph(g2, env, params, precision=precision,
                             target=target, batch_size=batch_size,
                             selection=selection)

    rng = np.random.default_rng(0)
    args = []
    for n in input_names:
        spec = g2.inputs[n]
        a = rng.standard_normal((batch_size,) + spec.shape).astype(np.float32)
        args.append(jnp.asarray(a).astype(spec.dtype))
    return jax.jit(program), args


def _measure_site(site: DecisionSite, graph: Graph, *, target: str,
                  precision: str, passes: Optional[Sequence[str]],
                  batch_size: int, deadline: Deadline) -> Optional[dict]:
    """Benchmark every choice at ``site``; returns a cache entry for the
    winner or None if the budget ran out / every candidate failed."""
    measured: Dict[str, float] = {}
    best: Optional[Tuple[str, float]] = None
    variants = pipeline_candidates() if site.kind == "pipeline" else None
    default_pipeline = (tuple(passes) if passes is not None
                        else PassManager.default().pipeline)
    for choice in site.choices:
        if deadline.expired():
            break
        try:
            if site.kind == "pipeline":
                mini = graph.copy()
                pipeline = variants[choice]
            else:
                mini = extract_region(graph, site.region)
                apply_choice(mini, site, choice)
                pipeline = default_pipeline
            fn, args = _compiled_probe(mini, pipeline, target=target,
                                       precision=precision,
                                       batch_size=batch_size)
        except Exception:
            continue        # an unbuildable candidate is not a winner
        us = bench_min_us(fn, args, reps=MEASURE_REPS,
                          warmup=MEASURE_WARMUP, deadline=deadline)
        if us is None:
            continue
        measured[choice] = us
        if best is None or us < best[1]:
            best = (choice, us)
    if best is None:
        return None
    winner, us = best
    return {
        "kind": site.kind,
        "winner": winner,
        "best_us": us,
        "measured_us": {k: round(v, 3) for k, v in measured.items()},
        "fingerprint": environment_fingerprint(),
    }


def _site_desc(site: DecisionSite, *, target: str, precision: str,
               batch_size: int) -> dict:
    """The tactic-cache key descriptor for one decision site.  Pipeline
    sites mix in the variant *contents* (pass lists), so renaming or
    re-composing a variant misses cleanly instead of replaying the old
    meaning under a reused label."""
    desc = {
        "graph_decision": site.kind,
        "v": GRAPH_DECISION_VERSION,
        "digest": site.digest,
        "target": target,
        "precision": precision,
        "batch": batch_size,
        "choices": list(site.choices),
    }
    if site.kind == "pipeline":
        desc["variants"] = {k: list(v)
                            for k, v in pipeline_candidates().items()}
    return desc


# ---------------------------------------------------------------------------
# the tuning pass
# ---------------------------------------------------------------------------
def tune_graph_decisions(
    graph: Graph,
    *,
    target: str,
    precision: str,
    passes: Optional[Sequence[str]],
    mode: str,
    budget_ms: Optional[float],
    cache: Optional[TacticCache],
    batch_size: int = 1,
) -> Tuple[Graph, Optional[Tuple[str, ...]], dict]:
    """Tune the graph-level decisions of ``graph``.

    Returns ``(decided_graph, pipeline, report)`` where ``decided_graph``
    is a copy with winning decision attrs applied, ``pipeline`` is the
    chosen pass list (``None`` = the caller's default), and ``report``
    records every site with its winner, source and per-candidate µs
    (plus the raw cache ``entries`` for capture bundles).

    ``mode="cached"`` consults the tactic cache only — deterministic,
    zero measurement, what replay uses.  ``mode="full"`` additionally
    measures unknown sites within ``budget_ms * GRAPH_BUDGET_FRACTION``
    (decisions are measured at ``batch_size``; they apply to every batch
    specialization of the executable, since the pass pipeline runs once
    per compile, not once per batch).

    Sites without a valid cache entry or measurement keep the pass
    heuristics — like the kernel tuner, tuning can only ever *change* a
    decision on the strength of a measurement.
    """
    if mode not in ("cached", "full"):
        raise ValueError(f"autotune mode must be 'cached' or 'full' here, "
                         f"got {mode!r}")
    sites = enumerate_sites(graph, passes=passes)
    graph_budget = (budget_ms * GRAPH_BUDGET_FRACTION
                    if (mode == "full" and budget_ms is not None) else
                    (None if mode == "full" else 0.0))
    deadline = Deadline(graph_budget)
    fingerprint = environment_fingerprint()
    decided = graph.copy()
    pipeline: Optional[Tuple[str, ...]] = (tuple(passes)
                                           if passes is not None else None)
    entries: Dict[str, dict] = {}
    site_rows: List[dict] = []
    for site in sites:
        desc = _site_desc(site, target=target, precision=precision,
                          batch_size=batch_size)
        key = tactic_key(desc, fingerprint)
        entry = cache.load(key, fingerprint) if cache is not None else None
        source = "cached" if entry is not None else None
        if entry is None and mode == "full" and not deadline.expired():
            # Pipeline variants are measured on the whole graph *with*
            # the site decisions chosen so far applied — the program the
            # winning pipeline will actually compile.
            basis = decided if site.kind == "pipeline" else graph
            entry = _measure_site(site, basis, target=target,
                                  precision=precision, passes=passes,
                                  batch_size=batch_size, deadline=deadline)
            if entry is not None:
                source = "measured"
                if cache is not None:
                    cache.store(key, entry)
        row = {"kind": site.kind, "node": site.node, "digest": site.digest,
               "choices": list(site.choices)}
        if entry is not None and entry.get("winner") in site.choices:
            entries[key] = entry
            row.update(winner=entry["winner"], source=source,
                       best_us=entry.get("best_us"),
                       measured_us=dict(entry.get("measured_us", {})))
            if site.kind == "pipeline":
                if entry["winner"] != "default":
                    pipeline = tuple(pipeline_candidates()[entry["winner"]])
            else:
                apply_choice(decided, site, entry["winner"])
        else:
            row.update(winner=None, source="heuristic")
        site_rows.append(row)
    report = {
        "mode": mode,
        "budget_ms": graph_budget,
        "spent_ms": round(deadline.spent_ms(), 3),
        "sites": site_rows,
        "pipeline": list(pipeline) if pipeline is not None else None,
        "cache": cache.stats() if cache is not None else None,
        "entries": entries,
    }
    return decided, pipeline, report
