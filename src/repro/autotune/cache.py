"""Persistent on-disk tactic cache — measure once, remember forever.

The executable cache (``repro.api.cache``) amortizes *XLA compilation*
across processes; this cache amortizes *measurement*.  A tactic entry
records, for one ``(op, shapes, dtype, batch, target, precision)`` key,
which kernel implementation (and block geometry) won the micro-benchmark
and what every candidate measured, so a second process compiling the
same shapes gets the measured winner without re-benchmarking.

Keys are fingerprinted like the executable cache's: the digest mixes in
the jax version, the backend platform, and the effective Pallas
lowering-rule fingerprint, so editing a kernel or upgrading jax misses
cleanly instead of serving a stale winner.  The fingerprint is *also*
stored inside each entry and re-validated on load — a file copied
between environments degrades to a heuristic fallback, never a wrong
tactic.  Entries are plain JSON (human-inspectable: ``cat`` one to see
why a kernel won); any parse/validation failure drops the entry and
falls back to the heuristic — never to an error.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Mapping, Optional

import jax

from ..api.cache import resolve_cache_dir

TACTIC_FORMAT_VERSION = 1

#: Subdirectory of the shared cache root (``$REPRO_CACHE_DIR`` or the
#: explicit ``CompileOptions.cache_dir``) holding tactic entries.
TACTICS_SUBDIR = "tactics"


def environment_fingerprint() -> str:
    """Everything environmental that invalidates a measurement: jax
    version, backend platform, and the Pallas lowering-rule set (editing
    a kernel body changes what a "pallas.*" tactic means)."""
    from ..core.lowering import lowering_fingerprint

    h = hashlib.sha256()
    for p in (f"v{TACTIC_FORMAT_VERSION}", jax.__version__,
              jax.default_backend(), lowering_fingerprint("pallas")):
        h.update(p.encode())
        h.update(b"\x00")
    return h.hexdigest()


def tactic_key(desc: Mapping[str, Any], fingerprint: Optional[str] = None
               ) -> str:
    """Digest of a tactic descriptor (the per-shape identity of one
    kernel decision) plus the environment fingerprint."""
    fp = fingerprint if fingerprint is not None else environment_fingerprint()
    payload = json.dumps(desc, sort_keys=True, default=str)
    return hashlib.sha256(f"{fp}\x00{payload}".encode()).hexdigest()


class TacticCache:
    """JSON-per-entry directory cache of measured tactic winners."""

    def __init__(self, root: str) -> None:
        self.root = root
        self.hits = 0
        self.misses = 0
        self.stores = 0
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def load(self, key: str, fingerprint: Optional[str] = None
             ) -> Optional[Dict[str, Any]]:
        """Return a validated tactic entry, or None on miss/corruption/
        staleness (corrupt files are removed so they stop costing a
        parse on every compile)."""
        path = self._path(key)
        if not os.path.exists(path):
            self.misses += 1
            return None
        try:
            with open(path) as f:
                entry = json.load(f)
            if not isinstance(entry, dict):
                raise ValueError("tactic entry is not an object")
            if not isinstance(entry.get("winner"), str):
                raise ValueError("tactic entry has no winner")
            fp = (fingerprint if fingerprint is not None
                  else environment_fingerprint())
            if entry.get("fingerprint") != fp:
                # Stale (copied from another environment / edited
                # kernels): ignore but keep the file — it may be valid
                # for the environment that wrote it.
                self.misses += 1
                return None
            if entry.get("block") is not None:
                entry["block"] = tuple(int(b) for b in entry["block"])
            self.hits += 1
            return entry
        except Exception:
            try:
                os.remove(path)
            except OSError:
                pass
            self.misses += 1
            return None

    def store(self, key: str, entry: Dict[str, Any]) -> bool:
        """Write ``entry`` under ``key``; atomic via rename so two
        processes tuning the same shapes never interleave bytes."""
        try:
            blob = json.dumps(entry, indent=2, sort_keys=True, default=str)
        except (TypeError, ValueError):
            return False
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
            self.stores += 1
            return True
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass
            return False

    def stats(self) -> dict:
        """Hit/miss/store counters for this process plus the cache dir."""
        return {"dir": self.root, "hits": self.hits, "misses": self.misses,
                "stores": self.stores}


def open_tactic_cache(explicit_dir: Optional[str]) -> Optional[TacticCache]:
    """Tactic cache under ``<cache root>/tactics``; same resolution as
    the executable cache (explicit option, else ``$REPRO_CACHE_DIR``,
    else disabled)."""
    root = resolve_cache_dir(explicit_dir)
    if not root:
        return None
    try:
        return TacticCache(os.path.join(root, TACTICS_SUBDIR))
    except OSError:
        return None
