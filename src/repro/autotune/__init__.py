"""repro.autotune — profile-guided autotuning, kernel- and graph-level.

The static selector (``repro.core.selection``) encodes "statically known
properties of the network" as hand-written heuristics; this package
replaces the guess with a measurement where one is available.  Two
layers share one machinery:

* **kernel tactics** (:mod:`~repro.autotune.tuner`) — per ``(op,
  shapes, dtype, batch, target)`` key, enumerate candidate lowerings ×
  block geometries, benchmark with the min-of-reps estimator, record
  the winner.
* **graph decisions** (:mod:`~repro.autotune.decisions`) — per
  graph-region digest, measure the choices the passes otherwise guess:
  fusion on/off per site, dense kernel layout, whole pass-pipeline
  variants.

Both persist winners in the same fingerprinted on-disk tactic cache
(:mod:`~repro.autotune.cache`) — measure once, remember forever; a
second process with ``CompileOptions(autotune="cached")`` replays every
decision without measuring.  Driven by
``CompileOptions(autotune="off"|"cached"|"full", autotune_budget_ms=…)``.
"""

from .cache import (TACTICS_SUBDIR, TacticCache, environment_fingerprint,
                    open_tactic_cache, tactic_key)
from .decisions import (DecisionSite, GRAPH_BUDGET_FRACTION, enumerate_sites,
                        extract_region, region_digest, tune_graph_decisions)
from .measure import Deadline, bench_min_us
from .tactics import NodeTactics, Tactic, candidates_for_node
from .tuner import AUTOTUNE_MODES, tune_selection

__all__ = [
    "DecisionSite",
    "GRAPH_BUDGET_FRACTION",
    "enumerate_sites",
    "extract_region",
    "region_digest",
    "tune_graph_decisions",
    "AUTOTUNE_MODES",
    "Deadline",
    "NodeTactics",
    "TACTICS_SUBDIR",
    "Tactic",
    "TacticCache",
    "bench_min_us",
    "candidates_for_node",
    "environment_fingerprint",
    "open_tactic_cache",
    "tactic_key",
    "tune_selection",
]
