"""repro.autotune — profile-guided kernel autotuning.

The static selector (``repro.core.selection``) encodes "statically known
properties of the network" as hand-written heuristics; this package
replaces the guess with a measurement where one is available.  Per
``(op, shapes, dtype, batch, target)`` key it enumerates candidate
tactics (registered kernel lowerings × block geometries), benchmarks
them with the min-of-reps estimator, and records the winner in a
persistent on-disk tactic cache — measure once, remember forever.

Driven by ``CompileOptions(autotune="off"|"cached"|"full",
autotune_budget_ms=…)``; see :mod:`repro.autotune.tuner` for the pass
and :mod:`repro.autotune.cache` for the cache/fingerprint contract.
"""

from .cache import (TACTICS_SUBDIR, TacticCache, environment_fingerprint,
                    open_tactic_cache, tactic_key)
from .measure import Deadline, bench_min_us
from .tactics import NodeTactics, Tactic, candidates_for_node
from .tuner import AUTOTUNE_MODES, tune_selection

__all__ = [
    "AUTOTUNE_MODES",
    "Deadline",
    "NodeTactics",
    "TACTICS_SUBDIR",
    "Tactic",
    "TacticCache",
    "bench_min_us",
    "candidates_for_node",
    "environment_fingerprint",
    "open_tactic_cache",
    "tactic_key",
    "tune_selection",
]
