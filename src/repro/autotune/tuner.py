"""The profile-guided tuning pass: heuristic prior → measured winners.

``tune_selection`` takes the static selector's per-node decisions (the
*prior*) and refines them:

* ``mode="cached"`` — consult the persistent tactic cache only; nodes
  without a valid entry keep the heuristic choice.  Zero measurement,
  deterministic, safe for production compiles.
* ``mode="full"`` — additionally micro-benchmark the candidate set for
  any node the cache has no entry for, within ``budget_ms`` of wall
  clock (jit compiles of candidates count against the budget), and
  record each winner in the cache for every future process.

Identical shapes share one measurement within a pass (a 40-layer MLP
with one repeated dense geometry measures it once), and the tuned
:class:`~repro.core.selection.KernelChoice` records ``source=
"measured"``, the winning block geometry, and every candidate's µs so
``cost_summary()`` can answer "why this kernel, and by how much".
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from ..core.selection import KernelChoice
from .cache import TacticCache, environment_fingerprint, tactic_key
from .measure import Deadline, bench_min_us
from .tactics import Tactic, candidates_for_node

#: Micro-benchmark reps per candidate (min-of-reps estimator).
MEASURE_REPS = 5
MEASURE_WARMUP = 1

AUTOTUNE_MODES = ("off", "cached", "full")


def _measure_candidates(node_tactics, deadline: Deadline
                        ) -> Optional[dict]:
    """Benchmark every candidate; return a cache entry for the winner,
    or None if the budget ran out before any candidate finished."""
    measured: Dict[str, float] = {}
    best: Optional[Tuple[Tactic, float]] = None
    for tactic, fn, args in node_tactics.make_candidates():
        # Once over budget, stop *before* the next candidate's jit
        # compile — otherwise an expired deadline would still pay for
        # compiling the whole candidate set just to discard it.
        if deadline.expired():
            break
        us = bench_min_us(fn, args, reps=MEASURE_REPS,
                          warmup=MEASURE_WARMUP, deadline=deadline)
        if us is None:
            continue
        measured[tactic.label] = us
        if best is None or us < best[1]:
            best = (tactic, us)
    if best is None:
        return None
    tactic, us = best
    return {
        "winner": tactic.kernel,
        "winner_label": tactic.label,
        "block": list(tactic.block) if tactic.block else None,
        "best_us": us,
        "measured_us": {k: round(v, 3) for k, v in measured.items()},
        "desc": node_tactics.desc,
        "fingerprint": environment_fingerprint(),
    }


def _measured_choice(node, op: str, entry: dict, prior: KernelChoice
                     ) -> KernelChoice:
    n_cands = len(entry.get("measured_us", {}))
    best_us = entry.get("best_us")
    reason = (f"measured {entry.get('winner_label', entry['winner'])}"
              + (f" = {best_us:.1f}us" if isinstance(best_us, (int, float))
                 else "")
              + f" (best of {n_cands} tactics; "
              f"heuristic prior: {prior.kernel})")
    block = entry.get("block")
    return KernelChoice(
        node.name, op, entry["winner"], reason,
        source="measured",
        block=tuple(block) if block else None,
        measured_us=dict(entry.get("measured_us", {})))


def tune_selection(
    graph,
    selection: Dict[str, KernelChoice],
    *,
    batch_size: int,
    precision: str,
    mode: str,
    budget_ms: Optional[float],
    cache: Optional[TacticCache],
) -> Tuple[Dict[str, KernelChoice], dict]:
    """Refine ``selection`` with cached/measured tactics.

    Returns ``(tuned_selection, report)``; on any per-node failure the
    heuristic choice survives untouched — autotuning can only ever
    *change* a decision on the strength of a measurement.
    """
    if mode not in ("cached", "full"):
        raise ValueError(f"autotune mode must be 'cached' or 'full' here, "
                         f"got {mode!r}")
    deadline = Deadline(budget_ms if mode == "full" else None)
    fingerprint = environment_fingerprint()
    memo: Dict[str, dict] = {}
    tuned: Dict[str, KernelChoice] = dict(selection)
    measured_nodes, cached_nodes, heuristic_nodes = [], [], []

    specs = graph.infer_shapes()
    for node in graph.nodes:
        prior = selection.get(node.name)
        if prior is None:
            continue
        nt = candidates_for_node(node, graph, specs,
                                 batch_size=batch_size, precision=precision)
        if nt is None:        # single legal implementation: nothing to tune
            continue
        key = tactic_key(nt.desc, fingerprint)
        entry = memo.get(key)
        from_memo = entry is not None
        if entry is None and cache is not None:
            entry = cache.load(key, fingerprint)
            if entry is not None:
                memo[key] = entry
        if entry is None and mode == "full" and not deadline.expired():
            entry = _measure_candidates(nt, deadline)
            if entry is not None:
                memo[key] = entry
                if cache is not None:
                    cache.store(key, entry)
                measured_nodes.append(node.name)
        elif entry is not None and not from_memo and cache is not None:
            cached_nodes.append(node.name)
        if entry is not None:
            tuned[node.name] = _measured_choice(node, prior.op, entry, prior)
            if from_memo and node.name not in measured_nodes:
                cached_nodes.append(node.name)
        else:
            heuristic_nodes.append(node.name)

    report = {
        "mode": mode,
        "budget_ms": budget_ms,
        "spent_ms": round(deadline.spent_ms(), 3),
        "measured_nodes": measured_nodes,
        "cached_nodes": cached_nodes,
        "heuristic_nodes": heuristic_nodes,
        "cache": cache.stats() if cache is not None else None,
        # Raw cache entries by tactic key — capture bundles persist these
        # so replay can seed a fresh cache and reproduce the selection
        # with mode="cached".  Stripped from cost_summary().
        "entries": dict(memo),
    }
    return tuned, report
