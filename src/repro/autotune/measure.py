"""Micro-benchmark primitive for the autotuner.

Same estimator as ``benchmarks/table1.py``: the **minimum** of per-rep
wall times, which is robust to scheduler hiccups and GC pauses that
dominate sub-millisecond means on shared machines — and the perf gate
already depends on that estimator being stable, so tactic decisions use
the same lens CI judges them through.

Every candidate costs a jit compile before its first rep; the compile is
excluded from the timing but *counted against the tuning deadline*, so
``autotune_budget_ms`` bounds real wall-clock, not just steady-state
reps.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import jax


def now_ms() -> float:
    """Monotonic wall clock in milliseconds."""
    return time.perf_counter() * 1e3


class Deadline:
    """Wall-clock budget shared across every candidate of a tuning
    pass.  ``None`` budget = unlimited."""

    def __init__(self, budget_ms: Optional[float]) -> None:
        self.start_ms = now_ms()
        self.budget_ms = budget_ms

    def spent_ms(self) -> float:
        """Milliseconds elapsed since the deadline was created."""
        return now_ms() - self.start_ms

    def expired(self) -> bool:
        """True once the budget is spent (never with a None budget)."""
        return (self.budget_ms is not None
                and self.spent_ms() >= self.budget_ms)


def bench_min_us(fn: Callable, args: Sequence, *, reps: int = 5,
                 warmup: int = 1,
                 deadline: Optional[Deadline] = None) -> Optional[float]:
    """Min-of-reps wall time of ``fn(*args)`` in microseconds.

    Returns None if the candidate fails to run (e.g. a Pallas geometry
    the backend rejects) or the deadline expires before a single timed
    rep completes — the caller treats None as "not a viable tactic".
    """
    try:
        for _ in range(max(1, warmup)):   # first call pays the compile
            jax.block_until_ready(fn(*args))
            if deadline is not None and deadline.expired():
                return None
        best = None
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best = dt
            if deadline is not None and deadline.expired():
                break
        return best * 1e6 if best is not None else None
    except Exception:
        return None
