"""Tactic enumeration: what the autotuner can choose between, per op.

A *tactic* is one concrete implementation of a node: a kernel name (the
same names the static selector uses — ``"pallas.fused_matmul"``,
``"lax.dot"``, …) plus an optional block geometry.  For every node the
static selector has an opinion about, :func:`candidates_for_node` builds
the tactic key (the per-shape identity the cache is keyed by) and a list
of runnable candidates:

* ``dense`` — the stock lax reference vs. the fused Pallas matmul at
  each geometry from :func:`repro.kernels.tiles.enumerate_blocks`
  (TensorRT-style: the heuristic's block is just candidate #0);
* ``activation`` under ``precision="fast"`` — the jnp fast reference
  vs. the Pallas fast-act kernel at a few row-block heights (exact
  precision has exactly one legal implementation, so there is nothing
  to tune);
* ``decode_attention`` — the jnp reference vs. the Pallas online-softmax
  kernel at a few KV tile depths.

Candidates are *measured on synthetic data shaped exactly like the
node's operands* — deterministic seed, so a tactic key measures the
same problem in every process.  Candidates never differ in semantics
beyond what the static selector already allows (the fast-act kernel is
only a candidate where fast precision already applies), so autotuning
changes performance, not numerics classes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.tiles import LANE, enumerate_blocks
from ..kernels.fused_matmul.ops import fused_matmul, fused_matmul_q8
from ..kernels.fast_act.ops import fast_act
from ..kernels.fast_act import ref as fast_ref
from ..kernels.decode_attention.ops import decode_attention
from ..kernels.decode_attention import ref as attn_ref

#: Row-block heights swept for the fast-act kernel (cols are always one
#: 128-wide lane tile).
FAST_ACT_ROW_BLOCKS = (128, 256, 512)
#: KV-tile depths swept for decode attention.
DECODE_BS_CANDIDATES = (128, 256, 512, 1024)


@dataclasses.dataclass(frozen=True)
class Tactic:
    """One implementation choice: kernel name + optional geometry."""

    kernel: str
    block: Optional[Tuple[int, ...]] = None

    @property
    def label(self) -> str:
        """Human-readable name, e.g. ``matmul_tiled[128x128]``."""
        if self.block is None:
            return self.kernel
        return f"{self.kernel}[{'x'.join(str(b) for b in self.block)}]"


#: A runnable candidate: the tactic plus a jitted callable and its args.
Candidate = Tuple[Tactic, Callable, Sequence]


@dataclasses.dataclass(frozen=True)
class NodeTactics:
    """Everything the tuner needs for one node: the cache-key
    descriptor and a lazy candidate builder (array allocation + jit
    wrapping deferred until the budget says we actually measure)."""

    desc: Dict
    make_candidates: Callable[[], List[Candidate]]


def _rng_array(rng, shape, dtype="float32"):
    # Cast through jnp (numpy has no bfloat16): candidates must be
    # measured on the dtype the tactic key describes, or a bf16 key
    # would record the timings of a different (f32) problem.
    a = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(a).astype(dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------
def _dense_tactics(node, graph, in_spec, batch_size: int,
                   precision: str) -> NodeTactics:
    rows = max(1, in_spec.size // max(1, in_spec.shape[-1]))
    m = batch_size * rows
    kshape = graph.params[node.params["kernel"]].shape
    layout = node.attrs.get("kernel_layout", "io")
    # Measure the physical problem the kernel runs (post-layout padding),
    # not the logical one — geometry legality depends on the real W.
    k, n = (kshape[1], kshape[0]) if layout == "oi" else (kshape[0], kshape[-1])
    fn = node.epilogue if node.epilogue not in (None, "linear", "softmax") else None
    has_bias = "bias" in node.params
    has_affine = node.epilogue_attrs.get("post_affine") is not None
    fast = precision == "fast"
    # quant.* annotations define the numerics class of this site, so
    # they join the tactic key (an int8 site must never share timings —
    # or a winner — with the f32 version of the same shape) and pick
    # which kernel family the candidates come from.
    qm = node.attrs.get("quant.mode") or ""
    itemsize = ({"int8": 1, "bf16": 2}.get(qm)
                or int(np.dtype(in_spec.dtype).itemsize))
    desc = {"op": "dense", "m": m, "k": k, "n": n, "dtype": in_spec.dtype,
            "batch": batch_size, "target": "pallas", "epilogue": fn or "",
            "has_bias": has_bias, "has_affine": has_affine,
            "w_layout": layout, "fast": fast, "quant": qm}

    def make() -> List[Candidate]:
        rng = np.random.default_rng(0)
        x = _rng_array(rng, (m, k), in_spec.dtype)
        w = _rng_array(rng, (n, k) if layout == "oi" else (k, n),
                       in_spec.dtype)
        b = _rng_array(rng, (n,)) if has_bias else None
        s = _rng_array(rng, (n,)) if has_affine else None
        o = _rng_array(rng, (n,)) if has_affine else None

        if qm == "int8":
            # Measure with the node's calibrated scales: dequantized
            # magnitudes (and therefore any clamp behavior) match the
            # real site, and the tactic cache entry describes the same
            # compiled program the lowering will emit.
            ws = np.asarray(node.attrs["quant.w_scale"], dtype=np.float32)
            if ws.shape[0] < n:
                ws = np.pad(ws, (0, n - ws.shape[0]), constant_values=1.0)

            def runner(use_pallas: bool, block):
                return jax.jit(functools.partial(
                    fused_matmul_q8,
                    x_scale=node.attrs["quant.x_scale"], w_scales=ws,
                    fn=fn, fast=fast, w_layout=layout,
                    use_pallas=use_pallas, block=block))

            pallas_kernel = "pallas.fused_matmul_q8"
        else:
            if qm == "bf16":
                x = x.astype(jnp.bfloat16)
                w = w.astype(jnp.bfloat16)

            def runner(use_pallas: bool, block):
                return jax.jit(functools.partial(
                    fused_matmul, fn=fn, fast=fast, w_layout=layout,
                    use_pallas=use_pallas, block=block))

            pallas_kernel = "pallas.fused_matmul"

        cands: List[Candidate] = [
            (Tactic("lax.dot"), runner(False, None), (x, w, b, s, o))]
        for blk in enumerate_blocks(m, k, n, itemsize):
            cands.append((Tactic(pallas_kernel, blk),
                          runner(True, blk), (x, w, b, s, o)))
        return cands

    return NodeTactics(desc, make)


# ---------------------------------------------------------------------------
# activation (fast precision only — exact has one implementation)
# ---------------------------------------------------------------------------
def _activation_tactics(node, in_spec, batch_size: int) -> Optional[NodeTactics]:
    fn = node.attrs["fn"]
    if fn not in ("tanh", "sigmoid"):
        return None
    shape = (batch_size,) + tuple(in_spec.shape)
    desc = {"op": "activation", "fn": fn, "shape": list(shape),
            "dtype": in_spec.dtype, "batch": batch_size, "target": "pallas",
            "fast": True}

    def make() -> List[Candidate]:
        rng = np.random.default_rng(0)
        x = _rng_array(rng, shape, in_spec.dtype)
        cands: List[Candidate] = [
            (Tactic("jnp.act"), jax.jit(fast_ref.FAST[fn]), (x,))]
        minor = shape[-1] if shape else 1
        for rows in FAST_ACT_ROW_BLOCKS:
            blk = (rows, min(LANE, minor))
            cands.append((
                Tactic("pallas.fast_act", blk),
                jax.jit(functools.partial(fast_act, fn=fn, use_pallas=True,
                                          block=blk)),
                (x,)))
        return cands

    return NodeTactics(desc, make)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
def _decode_attention_tactics(node, specs, batch_size: int,
                              precision: str) -> NodeTactics:
    q_spec = specs[node.inputs[0]]
    kv_spec = specs[node.inputs[1]]
    h, d = q_spec.shape
    s, hkv, _ = kv_spec.shape
    fast = precision == "fast"
    scale = node.attrs.get("scale")
    desc = {"op": "decode_attention", "h": h, "d": d, "s": s, "hkv": hkv,
            "dtype": q_spec.dtype, "batch": batch_size, "target": "pallas",
            "fast": fast}

    def make() -> List[Candidate]:
        rng = np.random.default_rng(0)
        q = _rng_array(rng, (batch_size, h, d), q_spec.dtype)
        kc = _rng_array(rng, (batch_size, s, hkv, d), q_spec.dtype)
        vc = _rng_array(rng, (batch_size, s, hkv, d), q_spec.dtype)
        lengths = jnp.full((batch_size,), s, jnp.int32)

        cands: List[Candidate] = [(
            Tactic("jnp.ref"),
            jax.jit(functools.partial(attn_ref.decode_attention_ref,
                                      scale=scale, fast=fast)),
            (q, kc, vc, lengths))]
        if d % LANE == 0:
            seen = set()
            for bs in DECODE_BS_CANDIDATES:
                eff = min(bs, s)
                if eff in seen:
                    continue
                seen.add(eff)
                cands.append((
                    Tactic("pallas.decode_attention", (eff,)),
                    jax.jit(functools.partial(decode_attention, scale=scale,
                                              fast=fast, use_pallas=True,
                                              bs=eff)),
                    (q, kc, vc, lengths)))
        return cands

    return NodeTactics(desc, make)


# ---------------------------------------------------------------------------
def candidates_for_node(node, graph, specs, *, batch_size: int,
                        precision: str) -> Optional[NodeTactics]:
    """The tunable candidate set for one node, or None when the node has
    a single legal implementation (nothing to measure)."""
    in_spec = specs[node.inputs[0]] if node.inputs else None
    if node.op == "dense":
        return _dense_tactics(node, graph, in_spec, batch_size, precision)
    if node.op == "activation" and precision == "fast":
        return _activation_tactics(node, in_spec, batch_size)
    if node.op == "decode_attention":
        return _decode_attention_tactics(node, specs, batch_size, precision)
    return None
