"""EngineCache — warm executables per bucket, compiled off the hot path.

The runtime half of shape-polymorphic serving.  An :class:`EngineCache`
maps :class:`~repro.runtime.buckets.Bucket` → a *warm* compiled entry
(whatever the injected ``build`` callable returns — an AOT-compiled
program, a specialized callable, an Executable).  Dispatch never
compiles on the request path:

* **hit** — the exact bucket is warm: run it.
* **miss** — the bucket is cold: enqueue a background compile (a daemon
  worker thread builds it and atomically swaps it in) and serve the
  request *now* on the nearest warm larger bucket (more padding, same
  semantics).  The next dispatch of that bucket after the swap is a hit.
* **stall** — nothing warm covers the shape: the only case that builds
  synchronously on the request path.  ``warm_up()`` at construction
  exists precisely so this never happens in steady state; the counter
  makes it observable (the serve bench asserts it stays zero).

Thread-safe: ``get`` may be called from the serving loop while the
worker compiles.  The swap is a dict assignment under a lock — readers
either see the old state (fallback) or the new one (hit), never a
half-built entry.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

from .buckets import Bucket, BucketPolicy

#: Worker modes: ``"thread"`` (default) compiles cold buckets on a
#: daemon thread; ``"sync"`` compiles inline at miss (every miss is a
#: stall — the pre-bucketing behavior, for comparison); ``"manual"``
#: queues compiles until :meth:`EngineCache.drain` (deterministic tests).
WORKER_MODES = ("thread", "sync", "manual")


class EngineCache:
    """In-process bucket → warm-executable cache with async warm-up."""

    def __init__(self, policy: BucketPolicy,
                 build: Callable[[Bucket], Any], *,
                 worker: str = "thread",
                 clock: Callable[[], float] = time.perf_counter) -> None:
        if worker not in WORKER_MODES:
            raise ValueError(f"worker must be one of {WORKER_MODES}, "
                             f"got {worker!r}")
        self.policy = policy
        self._build = build
        self._worker_mode = worker
        self._clock = clock
        self._lock = threading.Lock()
        self._entries: Dict[Bucket, Any] = {}
        self._inflight: set = set()          # queued or compiling
        self._queue: "queue.Queue[Optional[Bucket]]" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        # counters (read under the lock via stats())
        self.bucket_hits = 0
        self.bucket_misses = 0
        self.background_compiles = 0
        self.compile_stalls = 0
        self.fallback_serves = 0
        self.compile_ms = 0.0
        self._pad_elems = 0
        self._total_elems = 0

    # -- worker --------------------------------------------------------
    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._worker_loop, daemon=True,
                name="repro-engine-cache")
            self._thread.start()

    def _worker_loop(self) -> None:
        while True:
            bucket = self._queue.get()
            if bucket is None:                    # shutdown sentinel
                return
            self._compile(bucket, background=True)

    def _compile(self, bucket: Bucket, *, background: bool) -> Any:
        """Build ``bucket`` and atomically swap it in.  Build failures
        drop the in-flight mark so a later dispatch can retry (or
        stall-compile with the error surfaced on the caller)."""
        t0 = self._clock()
        try:
            entry = self._build(bucket)
        except Exception:
            with self._lock:
                self._inflight.discard(bucket)
            if not background:
                raise
            return None
        with self._lock:
            self._entries[bucket] = entry
            self._inflight.discard(bucket)
            if background:
                self.background_compiles += 1
            self.compile_ms += (self._clock() - t0) * 1e3
        return entry

    def _schedule(self, bucket: Bucket) -> None:
        """Queue a background compile of ``bucket`` exactly once."""
        if self._worker_mode == "sync":
            return          # sync mode never compiles off the call path
        with self._lock:
            if (self._closed or bucket in self._entries
                    or bucket in self._inflight):
                return
            self._inflight.add(bucket)
        if self._worker_mode == "thread":
            self._ensure_thread()
        self._queue.put(bucket)

    def drain(self, max_items: Optional[int] = None) -> int:
        """Compile queued buckets on the calling thread (``"manual"``
        worker mode — tests control exactly when swap-in happens).
        Returns the number of buckets compiled."""
        n = 0
        while max_items is None or n < max_items:
            try:
                bucket = self._queue.get_nowait()
            except queue.Empty:
                return n
            if bucket is None:
                continue
            self._compile(bucket, background=True)
            n += 1
        return n

    def wait_warm(self, timeout: float = 120.0) -> bool:
        """Block until no compile is queued or in flight (steady state).
        In ``"manual"`` mode this drains inline."""
        if self._worker_mode == "manual":
            self.drain()
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if not self._inflight and self._queue.empty():
                    return True
            time.sleep(0.005)
        return False

    def shutdown(self) -> None:
        """Stop accepting work and join the warm-up thread (idempotent)."""
        with self._lock:
            self._closed = True
        if self._thread is not None and self._thread.is_alive():
            self._queue.put(None)
            self._thread.join(timeout=10.0)

    # -- warm-up -------------------------------------------------------
    def warm_up(self, buckets: Optional[Iterable[Bucket]] = None, *,
                block: bool = False) -> None:
        """Compile ``buckets`` (default: every bucket the policy
        enumerates).  ``block=True`` compiles synchronously — server
        start, where a stall is load time, not latency; otherwise the
        background worker fills them in while traffic is served on
        whatever is already warm."""
        todo = tuple(buckets) if buckets is not None \
            else self.policy.enumerate_buckets()
        for b in todo:
            if block:
                with self._lock:
                    have = b in self._entries
                    if not have:
                        self._inflight.add(b)
                if not have:
                    self._compile(b, background=False)
            else:
                self._schedule(b)

    def peek(self, bucket: Bucket) -> Any:
        """The warm entry for ``bucket`` (None if cold) without touching
        the dispatch counters."""
        with self._lock:
            return self._entries.get(bucket)

    def put(self, bucket: Bucket, entry: Any) -> None:
        """Swap a pre-built entry in (pre-warming from a persistent
        cache at construction)."""
        with self._lock:
            self._entries[bucket] = entry
            self._inflight.discard(bucket)

    # -- dispatch ------------------------------------------------------
    def _nearest_warm(self, want: Bucket) -> Optional[Bucket]:
        """Smallest warm bucket ≥ ``want`` in every dimension (minimal
        padded area, batch as tiebreak)."""
        best: Optional[Bucket] = None
        for b in self._entries:
            if b.batch < want.batch:
                continue
            if want.length is not None:
                if b.length is None or b.length < want.length:
                    continue
            elif b.length is not None:
                continue
            area = b.batch * (b.length or 1)
            if best is None or area < best.batch * (best.length or 1) \
                    or (area == best.batch * (best.length or 1)
                        and b.batch < best.batch):
                best = b
        return best

    def get(self, batch: int, length: Optional[int] = None
            ) -> Tuple[Any, Bucket, bool]:
        """Resolve ``(batch, length)`` to a warm entry.

        Returns ``(entry, bucket, exact)`` where ``bucket`` is the shape
        the entry was compiled for (pad inputs up to it) and ``exact``
        says whether it is the policy's own bucket for the shape.  Never
        compiles on this path unless *nothing* warm covers the shape
        (counted in ``compile_stalls``).
        """
        want = self.policy.bucket_for(batch, length)
        with self._lock:
            entry = self._entries.get(want)
            if entry is not None:
                self.bucket_hits += 1
                self._account(batch, length, want)
                return entry, want, True
            self.bucket_misses += 1
        self._schedule(want)
        with self._lock:
            fb = self._nearest_warm(want)
            if fb is not None:
                self.fallback_serves += 1
                self._account(batch, length, fb)
                return self._entries[fb], fb, False
        # Nothing warm covers the shape: the one stall path.
        if self._worker_mode == "sync":
            entry = self._compile(want, background=False)
        else:
            # The background worker may already be compiling `want`;
            # waiting on it would still stall the request path, so it
            # counts the same.  Compile our own copy only if needed.
            entry = self._compile(want, background=False) \
                if self._claim(want) else self._await(want)
        with self._lock:
            self.compile_stalls += 1
            self._account(batch, length, want)
        return entry, want, True

    def _claim(self, bucket: Bucket) -> bool:
        with self._lock:
            if bucket in self._entries:
                return False
            if bucket in self._inflight:
                return False
            self._inflight.add(bucket)
            return True

    def _await(self, bucket: Bucket, timeout: float = 600.0) -> Any:
        """The bucket is being built elsewhere (worker thread) or queued;
        in ``"manual"`` mode drain inline, otherwise poll for the swap."""
        if self._worker_mode == "manual":
            self.drain()
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if bucket in self._entries:
                    return self._entries[bucket]
                if bucket not in self._inflight:
                    break                      # failed in the worker
            time.sleep(0.002)
        return self._compile(bucket, background=False)

    def _account(self, batch: int, length: Optional[int],
                 bucket: Bucket) -> None:
        real = batch * (length if length is not None else 1)
        full = bucket.batch * (bucket.length or 1)
        self._pad_elems += full - real
        self._total_elems += full

    # -- introspection -------------------------------------------------
    @staticmethod
    def _order(b: Bucket) -> Tuple[int, int]:
        return (b.batch, b.length or 0)

    def warm_buckets(self) -> Tuple[Bucket, ...]:
        """Buckets whose programs are compiled and resident, sorted."""
        with self._lock:
            return tuple(sorted(self._entries, key=self._order))

    @property
    def pad_waste_frac(self) -> float:
        """Fraction of served elements that were bucket padding."""
        with self._lock:
            if self._total_elems == 0:
                return 0.0
            return self._pad_elems / self._total_elems

    def reset_counters(self) -> None:
        """Zero the dispatch/compile counters (warm entries are kept).
        Benchmarks call this between a warm-up wave and the measured
        steady-state wave so ``stats()`` reflects only the latter."""
        with self._lock:
            self.bucket_hits = 0
            self.bucket_misses = 0
            self.background_compiles = 0
            self.compile_stalls = 0
            self.fallback_serves = 0
            self.compile_ms = 0.0
            self._pad_elems = 0
            self._total_elems = 0

    def stats(self) -> dict:
        """Serving counters: bucket hits/misses, stalls, background
        compiles, compile time, warm set, and padding waste."""
        with self._lock:
            total = self._total_elems
            return {
                "bucket_hits": self.bucket_hits,
                "bucket_misses": self.bucket_misses,
                "fallback_serves": self.fallback_serves,
                "background_compiles": self.background_compiles,
                "compile_stalls": self.compile_stalls,
                "compile_ms": round(self.compile_ms, 3),
                "warm_buckets": [str(b) for b in
                                 sorted(self._entries, key=self._order)],
                "pad_elems": self._pad_elems,
                "total_elems": total,
                "pad_waste_frac": (self._pad_elems / total) if total else 0.0,
            }
