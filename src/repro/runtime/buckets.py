"""Shape buckets — the policy half of shape-polymorphic serving.

The paper's thesis is specializing compiled code to statically known
properties; the price is one program per shape.  A :class:`BucketPolicy`
bounds that price: live shapes are rounded up to a small, deterministic
set of *buckets* (powers-of-two batch sizes × configurable sequence
lengths), so the number of programs is fixed up front while any shape
inside the covered range still runs on specialized code — padded to the
bucket, with the waste accounted per dispatch.

The policy is pure arithmetic: no jax, no threads, no caches.  The
runtime half (which bucket is *warm*, what compiles in the background)
lives in :mod:`repro.runtime.engine_cache`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple


class Bucket(NamedTuple):
    """One specialization point: a batch size and an optional sequence
    length (``length=None`` for batch-only bucketing, e.g. fixed-shape
    graph executables or single-token decode)."""

    batch: int
    length: Optional[int] = None

    def __str__(self) -> str:
        if self.length is None:
            return f"b{self.batch}"
        return f"b{self.batch}xl{self.length}"


def _ascending(values: Sequence[int], what: str) -> Tuple[int, ...]:
    out = tuple(sorted({int(v) for v in values}))
    if any(v <= 0 for v in out):
        raise ValueError(f"{what} must be positive: {tuple(values)}")
    return out


def powers_of_two(lo: int, hi: int) -> Tuple[int, ...]:
    """Powers of two in ``[lo, hi]``, always including ``hi`` itself so
    the largest bucket covers the full range even when ``hi`` is not a
    power of two."""
    if hi < lo:
        raise ValueError(f"empty bucket range [{lo}, {hi}]")
    out = []
    v = 1
    while v < lo:
        v *= 2
    while v < hi:
        out.append(v)
        v *= 2
    out.append(hi)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Deterministic shape→bucket rounding.

    batch_buckets: ascending batch sizes to specialize for.
    len_buckets:   ascending sequence lengths; empty = batch-only
                   bucketing (``bucket_for`` returns ``length=None``
                   buckets and ignores any length argument).

    A shape maps to the smallest bucket ≥ it in every dimension.  A
    shape *above* the largest bucket gets an exact (unbucketed) bucket
    of its own shape — deterministic, never an error, but each distinct
    overflow shape is its own specialization (the pre-bucketing
    behavior), so size the largest bucket to the traffic you expect.
    """

    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    len_buckets: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "batch_buckets",
                           _ascending(self.batch_buckets, "batch_buckets"))
        object.__setattr__(self, "len_buckets",
                           _ascending(self.len_buckets, "len_buckets"))
        if not self.batch_buckets:
            raise ValueError("batch_buckets must not be empty")

    # ------------------------------------------------------------------
    @classmethod
    def default(cls, max_batch: int, max_len: Optional[int] = None,
                min_len: int = 16) -> "BucketPolicy":
        """Powers-of-two batch buckets up to ``max_batch``; length
        buckets doubling from ``min_len`` up to ``max_len`` (omitted =
        batch-only)."""
        lens: Tuple[int, ...] = ()
        if max_len is not None:
            lens = powers_of_two(min(min_len, max_len), max_len)
        return cls(batch_buckets=powers_of_two(1, max_batch),
                   len_buckets=lens)

    # ------------------------------------------------------------------
    def bucket_for(self, batch: int, length: Optional[int] = None) -> Bucket:
        """Smallest bucket ≥ ``(batch, length)`` in every dimension."""
        if batch <= 0:
            raise ValueError(f"batch must be positive, got {batch}")
        b = next((c for c in self.batch_buckets if c >= batch), batch)
        if not self.len_buckets or length is None:
            return Bucket(b, None)
        if length <= 0:
            raise ValueError(f"length must be positive, got {length}")
        l = next((c for c in self.len_buckets if c >= length), length)
        return Bucket(b, l)

    def enumerate_buckets(self) -> Tuple[Bucket, ...]:
        """Every bucket the policy can round to, deterministically
        ordered (batch-major ascending) — the warm-up worklist."""
        if not self.len_buckets:
            return tuple(Bucket(b, None) for b in self.batch_buckets)
        return tuple(Bucket(b, l)
                     for b in self.batch_buckets
                     for l in self.len_buckets)

    def covers(self, bucket: Bucket) -> bool:
        """True if ``bucket`` is one of the policy's own buckets (not an
        overflow shape)."""
        return bucket in self.enumerate_buckets()

    # ------------------------------------------------------------------
    @staticmethod
    def pad_waste(batch: int, length: Optional[int], bucket: Bucket) -> float:
        """Fraction of the bucket's elements that are padding for a
        ``(batch, length)`` dispatch: ``1 - real/bucket``."""
        real = batch * (length if length is not None else 1)
        full = bucket.batch * (bucket.length if bucket.length is not None
                               else 1)
        if full <= 0:
            return 0.0
        return max(0.0, 1.0 - real / full)

    # ------------------------------------------------------------------
    def clip(self, max_batch: Optional[int] = None,
             max_len: Optional[int] = None) -> "BucketPolicy":
        """Derive a policy whose buckets never exceed the given caps —
        the cap itself becomes the largest bucket (a serving scheduler
        clips to its slot count and cache capacity)."""
        bb = self.batch_buckets
        if max_batch is not None:
            bb = tuple(b for b in bb if b < max_batch) + (max_batch,)
        lb = self.len_buckets
        if lb and max_len is not None:
            lb = tuple(l for l in lb if l < max_len) + (max_len,)
        return BucketPolicy(batch_buckets=bb, len_buckets=lb)

    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-friendly form; invert with ``from_dict``."""
        return {"batch_buckets": list(self.batch_buckets),
                "len_buckets": list(self.len_buckets)}

    @classmethod
    def from_dict(cls, d: dict) -> "BucketPolicy":
        """Rebuild a policy from ``to_dict`` output."""
        return cls(batch_buckets=tuple(d.get("batch_buckets") or ()),
                   len_buckets=tuple(d.get("len_buckets") or ()))
