"""BucketedExecutable — shape-polymorphic dispatch over one compiled model.

``repro.compile(graph, CompileOptions(target="jit", buckets=policy))``
returns one of these instead of a bare :class:`JitExecutable`: a single
:class:`~repro.core.graph.Signature`, a single source graph, but one
specialized program *per batch bucket*, dispatched by the input's batch
dimension at call time.  A call whose batch is not a bucket pads up to
the chosen bucket and slices the outputs back — numerically identical
to calling the bucket's program on the padded input directly.

Compilation never blocks a dispatch that a warm bucket can cover: cold
buckets compile on the :class:`~repro.runtime.engine_cache.EngineCache`
background worker while the call is served on the nearest warm larger
bucket.  At construction the cache pre-warms from the persistent
on-disk executable cache: every bucket whose key is already on disk is
loaded immediately (an XLA deserialization, not a compile), so a second
process starts with the first process's buckets warm.

Serialization is a *manifest*: the source graph (the portable,
backend-independent artifact) plus the per-bucket persistent-cache keys,
so the machine-code level stays in the on-disk executable cache where
it belongs and ``repro.deserialize`` re-wraps with the same policy.
"""

from __future__ import annotations

import json
from typing import Optional

import jax.numpy as jnp

from ..api.executable import Executable, pack
from .buckets import Bucket, BucketPolicy
from .engine_cache import EngineCache


class BucketedExecutable(Executable):
    """Dispatch-by-shape wrapper over a :class:`JitExecutable`."""

    def __init__(self, inner, policy: BucketPolicy, *,
                 worker: str = "thread", prewarm: bool = True) -> None:
        if policy.len_buckets:
            raise ValueError(
                "graph executables have fixed per-example shapes; "
                "BucketPolicy.len_buckets applies to serving "
                "(SchedulerOptions), not CompileOptions")
        self.inner = inner
        self.policy = policy
        self.options = inner.options
        self.signature = inner.signature
        self.source = inner.source
        self._cache = EngineCache(
            policy, build=lambda b: inner.ensure_compiled(b.batch),
            worker=worker)
        if prewarm:
            self.prewarm_from_disk()

    # ------------------------------------------------------------------
    @property
    def compile_time(self):
        """Inner executable's accumulated compile time (read-through)."""
        return self.inner.compile_time

    @compile_time.setter
    def compile_time(self, value):
        """No-op: the Executable base class assigns this attribute, but
        the inner executable owns the real counter."""
        pass

    def prewarm_from_disk(self) -> int:
        """Load every bucket whose executable is already in the
        persistent on-disk cache (PR 1).  Deserialization, not
        compilation — cheap enough to do synchronously at construction.
        Returns the number of buckets warmed."""
        n = 0
        for bucket in self.policy.enumerate_buckets():
            if self.inner.has_disk_entry(bucket.batch):
                self._cache.warm_up([bucket], block=True)
                n += 1
        return n

    def warm_up(self, *, block: bool = False) -> None:
        """Compile every bucket (background by default)."""
        self._cache.warm_up(block=block)

    def wait_warm(self, timeout: float = 120.0) -> bool:
        """Block until background warm-up finishes; False on timeout."""
        return self._cache.wait_warm(timeout)

    def ensure_compiled(self, batch_size: int = 1):
        """Blocking compile of the bucket covering ``batch_size``;
        returns the bucket's program (inputs must be padded to the
        bucket batch by the caller — ``__call__`` does this)."""
        bucket = self.policy.bucket_for(batch_size)
        self._cache.warm_up([bucket], block=True)
        return self._cache.peek(bucket)

    # ------------------------------------------------------------------
    def __call__(self, *pos, **inputs):
        args = self.inner._gather_inputs(pos, inputs)
        batch = args[0].shape[0]
        fn, bucket, _ = self._cache.get(batch)
        if bucket.batch != batch:
            args = [
                jnp.concatenate(
                    [a, jnp.zeros((bucket.batch - batch,) + a.shape[1:],
                                  a.dtype)])
                for a in args
            ]
        out = fn(*args)
        if bucket.batch != batch:
            out = {k: v[:batch] for k, v in out.items()}
        return {pub: out[opt] for pub, opt in
                zip(self.inner.source.output_names,
                    self.inner.graph.outputs)}

    # ------------------------------------------------------------------
    def cost_summary(self):
        """Inner compile facts plus a ``runtime`` section (bucket policy
        + engine-cache counters)."""
        out = self.inner.cost_summary()
        out["runtime"] = {"policy": self.policy.to_dict(),
                          **self._cache.stats()}
        return out

    def cache_info(self) -> dict:
        """Disk-cache counters of the wrapped executable."""
        return self.inner.cache_info()

    def runtime_stats(self) -> dict:
        """Engine-cache counters: hits, misses, stalls, pad waste."""
        return self._cache.stats()

    def serialize(self) -> bytes:
        """Manifest container: the graph body plus per-bucket artifact
        keys into the persistent executable cache."""
        from ..frontends.container import save_model
        import io
        buf = io.BytesIO()
        save_model(self.inner.source, buf)
        artifacts = {
            str(b): self.inner.disk_key(b.batch)
            for b in self.policy.enumerate_buckets()
        }
        return pack("bucketed", self.options, buf.getvalue(),
                    extra={"signature": self.signature.to_dict(),
                           "policy": self.policy.to_dict(),
                           "artifacts": artifacts})

    def shutdown(self) -> None:
        """Stop the background warm-up worker (idempotent)."""
        self._cache.shutdown()

    def __repr__(self) -> str:
        warm = ", ".join(str(b) for b in self._cache.warm_buckets())
        return (f"BucketedExecutable(target={self.options.target!r}, "
                f"buckets={json.dumps(self.policy.to_dict())}, "
                f"warm=[{warm}])")
