"""repro.runtime — shape-polymorphic serving over compiled executables.

The missing layer between ``repro.compile`` (one program per exact
shape) and ``repro.serve`` (live traffic whose shapes move every step):

* :class:`BucketPolicy` / :class:`Bucket` — deterministic shape→bucket
  rounding (powers-of-two batch buckets × configurable length buckets)
  with per-dispatch pad-waste accounting;
* :class:`EngineCache` — an in-process bucket → warm-executable cache:
  cold buckets compile on a background worker and atomically swap in
  while requests are served on the nearest warm larger bucket — never a
  compile stall on the request path;
* :class:`BucketedExecutable` — what ``repro.compile(graph,
  CompileOptions(buckets=policy))`` returns: one signature, one source
  graph, per-bucket specialized programs dispatched by input shape,
  pre-warmed from the persistent on-disk executable cache.

The serving scheduler (:mod:`repro.serve`) builds on the same pieces:
``SchedulerOptions(buckets=policy)`` buckets prefill by prompt length
and sizes each decode step's rebatch to the best warm batch bucket.
"""

from .buckets import Bucket, BucketPolicy, powers_of_two
from .engine_cache import EngineCache, WORKER_MODES


def __getattr__(name):
    # Lazy: bucketed.py pulls in jax and repro.api; CompileOptions
    # imports BucketPolicy from here, so the eager surface must stay
    # import-cycle-free (and jax-free, like `import repro` itself).
    if name == "BucketedExecutable":
        from .bucketed import BucketedExecutable
        return BucketedExecutable
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Bucket",
    "BucketPolicy",
    "BucketedExecutable",
    "EngineCache",
    "WORKER_MODES",
    "powers_of_two",
]
