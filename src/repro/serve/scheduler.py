"""Continuous-batching request scheduler over a compiled model.

The step loop rebatches every decode step:

    1. admit — while capacity is free and requests wait, pick one (FCFS,
       shortest-prompt, or earliest-deadline), prefill it — whole-prompt
       at admission, or one ``prefill_chunk`` per step interleaved with
       decode so long prompts never block in-flight decodes — splice its
       cache row into the batched cache and sample its first token;
    2. decode — ONE batched decode step advances every active slot.
       Every per-bucket decode program takes the FULL batched cache with
       ``donate_argnums``: the KV write-back happens inside the compiled
       program on the donated buffer, so steady-state decode performs no
       new device allocations (the framework-scale version of the
       paper's in-place memory planning);
    3. sample + evict — per-slot sampling, EOS / length retirement frees
       slots for the next iteration's admissions.

Requests whose prompts share a common head (the "system prompt"
scenario) prefill that head once: with ``prefix_cache`` enabled the
scheduler snapshots the head's KV rows at a chunk boundary and later
requests splice a copy, prefilling only their tail — bit-identical to
unshared prefill (see :mod:`repro.serve.prefix`).

``submit`` is thread-safe and non-blocking, so a producer can feed the
queue while another thread (or an asyncio executor) drives ``step`` /
``run`` — the scheduler itself never blocks waiting for requests.

Per-request metrics (TTFT, decode tok/s, queue depth at submit,
deadline/SLO outcome) and aggregate counters (batch occupancy, total
throughput, ``slo_violations``) come from an injected clock, so tests
assert exact numbers.
"""

from __future__ import annotations

import dataclasses
import math
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.mesh import MeshUnavailableError, ensure_mesh_available
from .metrics import RequestMetrics, SchedulerMetrics
from .options import SchedulerOptions
from .prefix import PrefixCache, common_prefix_len
from .slots import SlotManager, SlotState

# replica_groups spellings in post-optimization HLO: explicit
# ``{{0,2},{1,3}}`` lists and the iota form ``[2,2]<=[4]`` (G groups
# of S devices — the second dim is the group size).
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    """Devices per replica group of one collective op (0 if unknown)."""
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 0


def _axis_collectives(texts: List[str], spec) -> dict:
    """Per-mesh-axis collective counts and bytes-moved estimates from
    compiled post-optimization HLO.

    Bytes are each collective's result-buffer size weighted by its
    enclosing ``while`` trip counts (the scanned-layers multiplier —
    see :mod:`repro.launch.hlo_analysis`); each op is attributed to the
    mesh axes whose size matches its replica-group size, split evenly
    when several axes share a size.
    """
    from ..launch import hlo_analysis as H
    per_axis = {n: {"count": 0, "bytes": 0.0}
                for n, s in spec.axes if s > 1}
    counts: Dict[str, int] = {}
    total = 0.0
    for text in texts:
        comps = H.parse_hlo(text)
        mult, _ = H._multipliers(comps)
        for cname, comp in comps.items():
            m = mult.get(cname, 1.0)
            for op in comp.ops:
                base = op.opcode[:-6] if op.opcode.endswith("-start") \
                    else op.opcode
                if base not in H._COLLECTIVES:
                    continue
                moved = m * H._tshape_bytes(op.type_str)
                counts[base] = counts.get(base, 0) + 1
                total += moved
                k = _group_size(op.line)
                axes = [a for a, s in spec.axes if s > 1 and s == k] \
                    or list(per_axis)
                for a in axes:
                    per_axis[a]["count"] += 1
                    per_axis[a]["bytes"] += moved / max(len(axes), 1)
    return {"counts": counts, "per_axis": per_axis,
            "total_bytes": int(total)}


@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens, budget, sampling knobs,
    optional extra model inputs and an optional first-token SLO."""

    uid: int
    prompt: np.ndarray            # (s,) int32
    max_new_tokens: int = 32
    eos_id: int = -1              # -1 = never
    temperature: float = 0.0      # 0 = greedy
    #: Additional named model inputs consumed at prefill (the model
    #: signature's non-token inputs: ``frames`` for audio families,
    #: ``patches`` for VLMs).  Arrays may carry the leading batch dim
    #: (of 1) or omit it.  Missing extras are zero-filled; names the
    #: model does not declare are rejected at ``submit``.
    inputs: Optional[Dict[str, np.ndarray]] = None
    #: First-token SLO in milliseconds (relative to submit).  Sets the
    #: request's absolute deadline on the scheduler clock; the
    #: ``"deadline"`` admission policy schedules earliest-deadline-first
    #: and ``summary()`` counts ``slo_violations``.  None = no SLO.
    slo_ms: Optional[float] = None


@dataclasses.dataclass
class _PrefillTask:
    """One in-flight chunked prefill: a request whose prompt is being
    fed through the chunk program ``prefill_chunk`` tokens per step.
    Counts against slot capacity so a free slot is guaranteed when the
    final chunk lands and the task activates."""

    req: Request
    prompt: np.ndarray                    # (plen,) int32
    cache: Any                            # single-row cache, filled in place
    offset: int = 0                       # tokens prefilled so far
    logits: Any = None                    # last-token logits, latest chunk
    snapshot_at: Optional[int] = None     # chunk boundary to snapshot
    snapshot_key: Optional[bytes] = None  # pending PrefixCache key


@dataclasses.dataclass
class Completion:
    """Finished request: generated tokens and why generation stopped."""

    uid: int
    tokens: List[int]
    finish_reason: str = "length"   # "eos" | "length"


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when ``SchedulerOptions.max_queue`` is hit."""


class TemperatureSampler:
    """Default sampler: greedy at temperature 0, categorical otherwise.

    The sampler protocol is ``sample(logits, temperature, *, uid, index)
    -> int`` with ``logits`` of shape (1, vocab) and ``index`` the
    number of tokens already generated for that request — tests inject
    fakes that script tokens per request.
    """

    def __init__(self, seed: int = 0) -> None:
        self.key = jax.random.PRNGKey(seed)

    def __call__(self, logits: jnp.ndarray, temperature: float, *,
                 uid: int, index: int) -> int:
        if temperature <= 0.0:
            return int(jnp.argmax(logits, axis=-1)[0])
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(
            sub, logits / temperature, axis=-1)[0])


class Scheduler:
    """Drive a compiled model under concurrent multi-request load."""

    def __init__(self, model, params, options: SchedulerOptions, *,
                 sampler: Optional[Callable] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 engine_worker: str = "thread",
                 device_source: Optional[Callable] = None,
                 precision_info: Optional[dict] = None) -> None:
        self.model = model
        self.cfg = model.cfg
        self.options = options
        # Audit record from the compiled executable (repro.serve fills
        # it in): active precision + per-site decision counts, surfaced
        # verbatim in summary()["precision"].
        self.precision_info = dict(precision_info) if precision_info \
            else None
        if options.fold:
            from ..inference.fold_norms import fold_norms
            params, self.fold_report = fold_norms(self.cfg, params)
        else:
            self.fold_report = {"folds": 0}
        self.params = params
        self.sampler = sampler or TemperatureSampler(options.seed)
        self.clock = clock

        # data×model-parallel serving (repro.dist): bind the mesh before
        # any program builds so placements are committed up front.
        # ``device_source`` is the fault-injection seam: tests shrink the
        # visible device set and the step loop raises a typed
        # MeshUnavailableError (recorded in ``summary()["faults"]``).
        self.mesh = None
        self._faults: List[dict] = []
        self._device_source = device_source or jax.devices
        if options.mesh is not None:
            ensure_mesh_available(options.mesh, self._device_source())
            self.mesh = options.mesh.build(self._device_source())
            from jax.sharding import NamedSharding, PartitionSpec
            # params replicate; the batched KV cache shards (see
            # _leaf_sharding) — the data×model split the mesh names.
            self.params = jax.device_put(
                self.params, jax.tree.map(
                    lambda _: NamedSharding(self.mesh, PartitionSpec()),
                    self.params))

        self.slot_manager = SlotManager(
            model, options.slots, options.max_len,
            shard=self._leaf_sharding if self.mesh is not None else None)
        self._lock = threading.Lock()
        self._queue: List[Request] = []
        self.done: List[Completion] = []
        self._pending: List[Completion] = []  # finished, not yet popped
        self.generated: Dict[int, List[int]] = {}
        self.request_metrics: Dict[int, RequestMetrics] = {}
        self.metrics = SchedulerMetrics()
        self.last_token = np.zeros((options.slots, 1), np.int32)

        # compiled programs (donated cache: in-place buffer reuse)
        def decode_body(p, c, t):
            logits, c = model.decode_step(p, self._compute_view(c), t)
            return logits, self._constrain_cache(c)

        self._decode = jax.jit(decode_body, donate_argnums=(1,))
        self._prefill = jax.jit(lambda p, b, c: model.prefill(p, b, c))

        # shape-polymorphic serving (repro.runtime): warm programs per
        # bucket, background compiles.  None = fixed-shape (PR-5) path.
        self._decode_engine = None
        self._prefill_engine = None
        # chunked prefill + shared-prefix snapshots (both optional)
        self._chunk_engine = None
        self._prefix_cache: Optional[PrefixCache] = None
        self._prefilling: List[_PrefillTask] = []
        if options.prefill_chunk is not None:
            self._init_chunking(engine_worker)
        if options.buckets is not None:
            self._init_bucketing(engine_worker)

        # Under a mesh without bucketing, AOT-compile the fixed-shape
        # decode program at construction (load time, not latency) against
        # the committed placements: steady-state sharded decode never
        # stalls on a compile, mirroring the bucketed warm-up guarantee.
        self._decode_aot = None
        if self.mesh is not None and self._decode_engine is None:
            self._decode_aot = self._decode.lower(
                self._aot_specs(self.params),
                self._aot_specs(self.slot_manager.cache,
                                shard=self._leaf_sharding),
                self._aot_specs(jax.ShapeDtypeStruct(
                    (options.slots, 1), jnp.int32))).compile()

    # -- mesh placement ------------------------------------------------
    def _rule_axes(self, logical: str):
        """Mesh axes the logical-axis rule maps to, filtered to axes
        this mesh actually has (``batch`` → data axes, ``kv_seq`` →
        the model axes of the flash-decoding KV layout)."""
        from ..dist.propagate import merged_rules
        names = set(self.mesh.axis_names)
        return tuple(a for a in merged_rules().get(logical, ())
                     if a in names)

    def _leaf_sharding(self, leaf, *, compute: bool = False):
        """NamedSharding for one batched-cache leaf.  Leaves are
        (L, B, S, ...) except the position vector (B,): the slot
        (batch) dim shards over the ``batch`` rule's axes and the KV
        sequence dim over the ``kv_seq`` rule's ("model" — the
        flash-decoding storage layout).  Dims an axis product doesn't
        divide stay replicated, so any slots/max_len runs on any mesh.

        ``compute=True`` is the decode-time view: batch sharding only.
        Like the graph-IR path, sharding here is PLACEMENT, never math —
        row parallelism over ``data`` leaves each row's reduction order
        exactly the single-device order (bit-identical tokens), while
        the model-axis seq shards are gathered whole by GSPMD (the
        per-step all-gather ``summary()["sharding"]`` reports).  The
        model axis still divides per-device KV-cache memory by its size
        between steps."""
        from jax.sharding import NamedSharding, PartitionSpec
        sizes = dict(self.mesh.shape)

        def fit(dim, axes):
            axes = [a for a in axes if sizes.get(a, 1) > 1]
            k = math.prod(sizes[a] for a in axes) if axes else 1
            if k <= 1 or dim % k:
                return None
            return axes[0] if len(axes) == 1 else tuple(axes)

        parts = [None] * leaf.ndim
        b_dim = 0 if leaf.ndim == 1 else 1
        parts[b_dim] = fit(leaf.shape[b_dim], self._rule_axes("batch"))
        if leaf.ndim >= 3 and not compute:
            parts[2] = fit(leaf.shape[2], self._rule_axes("kv_seq"))
        return NamedSharding(self.mesh, PartitionSpec(*parts))

    def _compute_view(self, cache):
        """The traced decode-time view of the stored cache: keep the
        batch (``data``) sharding, gather the model-axis KV shards
        whole (see ``_leaf_sharding``)."""
        if self.mesh is None:
            return cache
        return jax.tree.map(
            lambda l: jax.lax.with_sharding_constraint(
                l, self._leaf_sharding(l, compute=True)), cache)

    def _constrain_cache(self, cache):
        """Pin a traced cache pytree to its committed storage placement,
        so the donated decode output keeps the sharding its AOT program
        (and the next step's input spec) committed to."""
        if self.mesh is None:
            return cache
        return jax.tree.map(
            lambda l: jax.lax.with_sharding_constraint(
                l, self._leaf_sharding(l)), cache)

    def _aot_specs(self, tree, shard: Optional[Callable] = None):
        """ShapeDtypeStructs for AOT lowering.  Under a mesh every leaf
        carries its committed NamedSharding (``shard`` per leaf, else
        replicated), so the compiled programs accept exactly the arrays
        the scheduler holds — AOT programs reject committed arguments
        whose placement disagrees with their input shardings."""
        from jax.sharding import NamedSharding, PartitionSpec

        def one(a):
            if self.mesh is None:
                return jax.ShapeDtypeStruct(a.shape, a.dtype)
            s = shard(a) if shard is not None \
                else NamedSharding(self.mesh, PartitionSpec())
            return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)

        return jax.tree.map(one, tree)

    def _tokens(self) -> jnp.ndarray:
        """The last-token batch, placed for the decode program (the
        replicated spec its AOT lowering committed to)."""
        t = jnp.asarray(self.last_token)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            t = jax.device_put(
                t, NamedSharding(self.mesh, PartitionSpec()))
        return t

    def _check_mesh(self) -> None:
        """Step-loop fault check: raise (and record) a typed
        :class:`MeshUnavailableError` naming the unfillable axes when
        the visible device set shrank below what the mesh needs."""
        if self.options.mesh is None:
            return
        try:
            ensure_mesh_available(self.options.mesh, self._device_source())
        except MeshUnavailableError as e:
            self._faults.append({
                "at": self.clock(), "mesh": e.spec.describe(),
                "needed": e.needed, "available": e.available,
                "missing_axes": list(e.missing_axes)})
            raise

    # -- bucketed engines ----------------------------------------------
    def _cache_grows_with_max_len(self) -> bool:
        """False for ring caches (all-sliding-window models), whose
        capacity is the window, not ``max_len``.  Padded prefill would
        roll real tokens out of a ring, so length bucketing is only
        sound when the cache actually holds ``max_len`` positions."""
        a = jax.eval_shape(
            lambda: self.model.init_cache(1, self.options.max_len))
        b = jax.eval_shape(
            lambda: self.model.init_cache(1, self.options.max_len + 1))
        return any(x.shape != y.shape for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    def _init_bucketing(self, worker: str) -> None:
        from ..runtime.buckets import Bucket, BucketPolicy
        from ..runtime.engine_cache import EngineCache
        opts = self.options
        policy = opts.buckets.clip(max_batch=opts.slots,
                                   max_len=opts.max_len)
        cache_spec = jax.eval_shape(
            lambda: self.model.init_cache(1, opts.max_len))
        params_spec = self._aot_specs(self.params)
        len_ok = (policy.len_buckets
                  and isinstance(cache_spec, dict) and "pos" in cache_spec
                  and self._cache_grows_with_max_len())

        full_spec = self._aot_specs(
            jax.eval_shape(
                lambda: self.model.init_cache(opts.slots, opts.max_len)),
            shard=self._leaf_sharding if self.mesh is not None else None)
        tok_spec = self._aot_specs(
            jax.ShapeDtypeStruct((opts.slots, 1), jnp.int32))

        def build_decode(bucket):
            # EVERY bucket's program takes (and donates) the FULL
            # batched cache: the row slice, the decode step and the KV
            # write-back all happen inside one compiled program, so the
            # donated buffer is updated in place — no per-step slice /
            # write-back allocations at the JAX level (the pre-allocated
            # step-buffer discipline of the paper's memory planner).
            b = bucket.batch

            def step(p, c, t):
                c = self._compute_view(c)
                if b >= opts.slots:
                    logits, c = self.model.decode_step(p, c, t)
                    return logits, self._constrain_cache(c)
                sub = jax.tree.map(
                    lambda l: l[:b] if l.ndim == 1 else l[:, :b], c)
                logits, sub = self.model.decode_step(p, sub, t[:b])
                axis = lambda l: 0 if l.ndim == 1 else 1
                new_c = jax.tree.map(
                    lambda f, s: jax.lax.dynamic_update_slice_in_dim(
                        f, s, 0, axis=axis(f)), c, sub)
                return logits, self._constrain_cache(new_c)

            fn = jax.jit(step, donate_argnums=(1,))
            return fn.lower(params_spec, full_spec, tok_spec).compile()

        self._decode_engine = EngineCache(
            BucketPolicy(batch_buckets=policy.batch_buckets),
            build_decode, worker=worker, clock=self.clock)
        # the full-slots program covers every batch, so compiling it
        # synchronously here (load time, not latency) guarantees the
        # decode path never stalls; smaller buckets fill in behind it
        self._decode_engine.warm_up([Bucket(opts.slots)], block=True)
        self._decode_engine.warm_up(block=False)

        # chunked prefill supersedes padded whole-prompt prefill: every
        # prompt runs through the (single-bucket) chunk program instead
        if not len_ok or self._chunk_engine is not None:
            return

        def build_prefill(bucket):
            from ..configs.base import extra_input_specs
            b_spec = {"tokens": jax.ShapeDtypeStruct((1, bucket.length),
                                                     jnp.int32)}
            for name, (shape, dt) in extra_input_specs(self.cfg).items():
                b_spec[name] = jax.ShapeDtypeStruct(shape, dt)
            l_spec = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(self._prefill_fixup)
            return fn.lower(params_spec, self._aot_specs(b_spec),
                            self._aot_specs(cache_spec),
                            self._aot_specs(l_spec)).compile()

        self._prefill_engine = EngineCache(
            BucketPolicy(batch_buckets=(1,),
                         len_buckets=policy.len_buckets),
            build_prefill, worker=worker, clock=self.clock)
        # largest length bucket first: it covers every admissible
        # prompt, so fallback coverage arrives as early as possible
        self._prefill_engine.warm_up(
            tuple(reversed(self._prefill_engine.policy.enumerate_buckets())))

    def _init_chunking(self, worker: str) -> None:
        """Build the chunk-prefill program — one PR-6 length bucket of
        exactly ``prefill_chunk`` tokens, compiled synchronously at
        construction so the request path never stalls on it — plus the
        optional shared-prefix snapshot cache.

        Families without incremental prefill keep whole-prompt prefill
        silently (MLA latent caches, vlm/audio extra inputs, ring
        caches whose capacity is the window, not ``max_len``) —
        surfaced in ``summary()["chunked_prefill"]["enabled"]``.
        """
        from ..runtime.buckets import BucketPolicy
        from ..runtime.engine_cache import EngineCache
        opts = self.options
        supports = getattr(self.model, "supports_chunked_prefill", None)
        cache_spec = jax.eval_shape(
            lambda: self.model.init_cache(1, opts.max_len))
        if not (supports is not None and supports()
                and isinstance(cache_spec, dict) and "pos" in cache_spec
                and self._cache_grows_with_max_len()):
            return
        params_spec = self._aot_specs(self.params)

        def build_chunk(bucket):
            t_spec = self._aot_specs(
                jax.ShapeDtypeStruct((1, bucket.length), jnp.int32))
            s_spec = self._aot_specs(jax.ShapeDtypeStruct((), jnp.int32))
            # the single-row cache is donated: each chunk fills it in
            # place (PrefixCache copies before/after, never aliases it)
            fn = jax.jit(
                lambda p, t, c, s, n: self.model.prefill_chunk(
                    p, t, c, s, n),
                donate_argnums=(2,))
            return fn.lower(params_spec, t_spec, self._aot_specs(cache_spec),
                            s_spec, s_spec).compile()

        self._chunk_engine = EngineCache(
            BucketPolicy(batch_buckets=(1,),
                         len_buckets=(opts.prefill_chunk,)),
            build_chunk, worker=worker, clock=self.clock)
        self._chunk_engine.warm_up(block=True)
        if opts.prefix_cache > 0:
            self._prefix_cache = PrefixCache(opts.prefix_cache)

    def _prefill_fixup(self, p, batch, cache, length):
        """Prefill padded to the bucket, then recover the exact-length
        result: the pad positions' K/V entries are causally downstream
        of the real tokens, so after rewinding ``pos`` to the last real
        token and re-decoding it, the logits and every cache position
        the model can still attend to are bit-identical to an
        exact-length prefill.  ``length`` is traced, so ONE compiled
        program serves every prompt length up to the bucket."""
        _, cache = self.model.prefill(p, batch, cache)
        cache = dict(cache)
        cache["pos"] = jnp.full_like(cache["pos"], length - 1)
        last = jax.lax.dynamic_slice_in_dim(batch["tokens"], length - 1, 1,
                                            axis=1)
        return self.model.decode_step(p, cache, last)

    def wait_warm(self, timeout: float = 120.0) -> bool:
        """Block until every scheduled background compile has landed
        (True) or the timeout expires.  No-op without bucketing."""
        ok = True
        for eng in (self._decode_engine, self._prefill_engine,
                    self._chunk_engine):
            if eng is not None:
                ok = eng.wait_warm(timeout) and ok
        return ok

    def shutdown(self) -> None:
        """Stop the background compile workers (daemon threads — safe
        to skip, but tests join them for determinism)."""
        for eng in (self._decode_engine, self._prefill_engine,
                    self._chunk_engine):
            if eng is not None:
                eng.shutdown()

    # -- queue ---------------------------------------------------------
    def submit(self, req: Request) -> RequestMetrics:
        """Enqueue a request (thread-safe, non-blocking).

        Raises :class:`QueueFullError` under admission control and
        ``ValueError`` if the prompt alone exceeds ``max_len``.
        """
        plen = int(np.asarray(req.prompt).shape[-1])
        if plen >= self.options.max_len:
            raise ValueError(
                f"prompt of {plen} tokens does not fit max_len="
                f"{self.options.max_len} (uid={req.uid})")
        if req.inputs:
            from ..configs.base import extra_input_specs
            allowed = extra_input_specs(self.cfg)
            unknown = sorted(set(req.inputs) - set(allowed))
            if unknown:
                raise ValueError(
                    f"unknown inputs {unknown} for {self.cfg.name!r} "
                    f"(family {self.cfg.family!r}); accepted extras: "
                    f"{sorted(allowed) or 'none'} (uid={req.uid})")
            # Shapes are rejected HERE, not at admission: by admission
            # time the request is out of the queue and a raise would
            # kill the step loop with other requests in flight.
            for name, a in req.inputs.items():
                shape = allowed[name][0]
                got = tuple(np.asarray(a).shape)
                if got not in (shape, shape[1:]):
                    raise ValueError(
                        f"input {name!r}: expected {shape} (or the "
                        f"batch-less {shape[1:]}), got {got} "
                        f"(uid={req.uid})")
        with self._lock:
            if (self.options.max_queue is not None
                    and len(self._queue) >= self.options.max_queue):
                self.metrics.rejected += 1
                raise QueueFullError(
                    f"queue full ({self.options.max_queue}); "
                    f"rejecting uid={req.uid}")
            if req.uid in self.request_metrics:
                raise ValueError(f"duplicate request uid={req.uid}")
            depth = len(self._queue)
            self._queue.append(req)
            self.metrics.submitted += 1
            self.metrics.peak_queue_depth = max(
                self.metrics.peak_queue_depth, len(self._queue))
            m = RequestMetrics(uid=req.uid, prompt_tokens=plen,
                               submitted_at=self.clock(),
                               queue_depth_at_submit=depth)
            if req.slo_ms is not None:
                m.deadline = m.submitted_at + req.slo_ms / 1e3
            self.request_metrics[req.uid] = m
            return m

    def queue_depth(self) -> int:
        """Requests waiting for admission (thread-safe snapshot)."""
        with self._lock:
            return len(self._queue)

    def num_active(self) -> int:
        """Slots currently generating."""
        return self.slot_manager.num_active()

    def _blocked(self, req: Request) -> bool:
        """True while an in-flight prefill is about to snapshot a head
        this request's prompt starts with: admitting it NOW would
        re-prefill the shared head; waiting the few steps until the
        snapshot lands turns it into a prefix hit."""
        if self._prefix_cache is None:
            return False
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        for t in self._prefilling:
            h = t.snapshot_at
            if (t.snapshot_key is not None and h is not None
                    and h < len(prompt)
                    and prompt[:h].tobytes() == t.snapshot_key):
                return True
        return False

    def _pop_next(self) -> Optional[Request]:
        with self._lock:
            if not self._queue:
                return None
            cand = range(len(self._queue))
            if self._prefix_cache is not None and self._prefilling:
                cand = [j for j in cand
                        if not self._blocked(self._queue[j])]
                if not cand:
                    return None    # every waiter gains by waiting
            if self.options.admission == "shortest":
                i = min(cand,
                        key=lambda j: (len(self._queue[j].prompt), j))
            elif self.options.admission == "deadline":
                # earliest deadline first; no-SLO requests after every
                # deadline, FCFS among themselves
                def urgency(j):
                    d = self.request_metrics[self._queue[j].uid].deadline
                    return (0, d, j) if d is not None else (1, 0.0, j)
                i = min(cand, key=urgency)
            else:                                   # fcfs
                i = next(iter(cand))
            return self._queue.pop(i)

    # -- admission -----------------------------------------------------
    def _prefill_batch(self, prompt: np.ndarray,
                       extras: Optional[Dict[str, np.ndarray]] = None
                       ) -> Dict[str, jnp.ndarray]:
        """The named multi-input prefill batch: tokens plus the model
        signature's extra inputs — request-supplied where given
        (batch dim added if omitted), zero-filled otherwise."""
        from ..configs.base import extra_input_specs
        batch = {"tokens": jnp.asarray(prompt)}
        extras = extras or {}
        for name, (shape, dtype) in extra_input_specs(self.cfg).items():
            if name in extras:
                a = jnp.asarray(extras[name], dtype)
                if a.ndim == len(shape) - 1:
                    a = a[None]
                if a.shape != shape:
                    raise ValueError(
                        f"input {name!r}: expected {shape} "
                        f"(or the batch-less {shape[1:]}), got {a.shape}")
                batch[name] = a
            else:
                batch[name] = jnp.zeros(shape, dtype)
        return batch

    def _admit_free_slots(self) -> None:
        if self._chunk_engine is not None:
            self._admit_chunked()
            return
        for slot in self.slot_manager.free_slots():
            req = self._pop_next()
            if req is None:
                return
            m = self.request_metrics[req.uid]
            m.admitted_at = self.clock()
            self.metrics.admitted += 1
            if self.metrics.started_at is None:
                self.metrics.started_at = m.admitted_at

            prompt = np.asarray(req.prompt, np.int32)[None, :]
            one = self.model.init_cache(1, self.options.max_len)
            if self._prefill_engine is not None:
                plen = prompt.shape[1]
                entry, bucket, _ = self._prefill_engine.get(1, plen)
                padded = np.zeros((1, bucket.length), np.int32)
                padded[:, :plen] = prompt
                logits, one = entry(
                    self.params, self._prefill_batch(padded, req.inputs),
                    one, jnp.int32(plen))
            else:
                logits, one = self._prefill(
                    self.params, self._prefill_batch(prompt, req.inputs),
                    one)
            self._activate(slot, req, logits[:, -1], one)

    def _admit_chunked(self) -> None:
        """Chunked admission: a popped request becomes a
        :class:`_PrefillTask` (advanced one chunk per step) instead of
        being prefilled inline.  Tasks count against slot capacity so a
        slot is free when each one completes; shared heads are taken
        from / planned into the prefix cache here."""
        opts = self.options
        while (len(self._prefilling) + self.slot_manager.num_active()
               < opts.slots):
            req = self._pop_next()
            if req is None:
                return
            m = self.request_metrics[req.uid]
            m.admitted_at = self.clock()
            self.metrics.admitted += 1
            if self.metrics.started_at is None:
                self.metrics.started_at = m.admitted_at
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            task = _PrefillTask(req=req, prompt=prompt, cache=None)
            if self._prefix_cache is not None:
                hit = self._prefix_cache.take(prompt)
                if hit is not None:
                    task.offset, task.cache = hit
            if task.cache is None:
                task.cache = self.model.init_cache(1, opts.max_len)
                if self._prefix_cache is not None:
                    self._plan_snapshot(task)
            self._prefilling.append(task)

    def _plan_snapshot(self, task: _PrefillTask) -> None:
        """On a prefix miss: if waiting prompts share a head with this
        one, mark the chunk boundary where this prefill should snapshot
        it (the head is then prefilled ONCE; sharers block in the queue
        until the snapshot lands and take a copy)."""
        chunk = self.options.prefill_chunk
        plen = len(task.prompt)
        with self._lock:
            queued = [np.asarray(r.prompt, np.int32).reshape(-1)
                      for r in self._queue]
        lcp = max((common_prefix_len(task.prompt, p) for p in queued),
                  default=0)
        head = (min(lcp, plen - 1) // chunk) * chunk
        if head < max(chunk, self.options.min_prefix):
            return
        key = PrefixCache.key_for(task.prompt[:head])
        if key in self._prefix_cache or any(
                t.snapshot_key == key for t in self._prefilling):
            return
        task.snapshot_at = head
        task.snapshot_key = key

    def _advance_prefills(self) -> None:
        """Advance every in-flight chunked prefill by ONE chunk — the
        interleaving that keeps long prompts from blocking decodes —
        then activate tasks whose prompt is complete."""
        if not self._prefilling:
            return
        chunk_len = self.options.prefill_chunk
        finished = []
        for task in self._prefilling:
            n = min(chunk_len, len(task.prompt) - task.offset)
            chunk = np.zeros((1, chunk_len), np.int32)
            chunk[0, :n] = task.prompt[task.offset:task.offset + n]
            entry, _, _ = self._chunk_engine.get(1, n)
            task.logits, task.cache = entry(
                self.params, jnp.asarray(chunk), task.cache,
                jnp.int32(task.offset), jnp.int32(n))
            task.offset += n
            self.metrics.prefill_chunks += 1
            if (task.snapshot_key is not None
                    and task.offset == task.snapshot_at):
                self._prefix_cache.insert(task.snapshot_key, task.offset,
                                          task.cache)
                task.snapshot_key = None
                task.snapshot_at = None
            if task.offset >= len(task.prompt):
                finished.append(task)
        for task in finished:
            self._prefilling.remove(task)
            slot = self.slot_manager.free_slots()[0]
            self._activate(slot, task.req, task.logits[:, 0], task.cache)

    def _activate(self, slot: int, req: Request, logits: jnp.ndarray,
                  one_cache: Any) -> None:
        """Prefill is done: sample the first token from its (1, vocab)
        logits, splice the single-row cache into ``slot`` and record
        first-token metrics (including the SLO verdict)."""
        tok = self.sampler(logits, req.temperature, uid=req.uid, index=0)
        # clamp so prompt + generated tokens can never outrun the
        # per-slot cache capacity
        plen = int(np.asarray(req.prompt).shape[-1])
        budget = self.options.max_len - plen
        self.slot_manager.admit(slot, SlotState(
            uid=req.uid,
            remaining=min(req.max_new_tokens, budget) - 1,
            eos_id=req.eos_id,
            temperature=req.temperature), one_cache)
        self.last_token[slot, 0] = tok
        self.generated[req.uid] = [tok]
        m = self.request_metrics[req.uid]
        m.first_token_at = self.clock()
        m.new_tokens = 1
        self.metrics.total_new_tokens += 1
        if m.deadline is not None:
            m.slo_violated = bool(m.first_token_at > m.deadline)
            if m.slo_violated:
                self.metrics.slo_violations += 1
        if tok == req.eos_id or min(req.max_new_tokens, budget) <= 1:
            self._retire(slot, "eos" if tok == req.eos_id else "length")

    # -- retirement ----------------------------------------------------
    def _retire(self, slot: int, reason: str) -> None:
        st = self.slot_manager.evict(slot)
        m = self.request_metrics[st.uid]
        m.finished_at = self.clock()
        m.finish_reason = reason
        self.metrics.completed += 1
        c = Completion(st.uid, self.generated[st.uid],
                       finish_reason=reason)
        self.done.append(c)
        self._pending.append(c)

    # -- bucketed decode -----------------------------------------------
    def _bucketed_decode(self, k: int) -> jnp.ndarray:
        """One decode step at the best warm batch bucket for ``k``
        active slots.  Compacts actives into rows ``[0, k)`` and runs
        the bucket's program over the FULL donated cache — the row
        slice and KV write-back happen inside the compiled program, so
        the cache buffer is reused in place every step (bit-identical
        per row to decoding at the full slot count, minus the work for
        the empty rows; returned logits cover the bucket's rows)."""
        for src, dst in self.slot_manager.compact():
            self.last_token[dst, 0] = self.last_token[src, 0]
        entry, _, _ = self._decode_engine.get(k)
        logits, self.slot_manager.cache = entry(
            self.params, self.slot_manager.cache, self._tokens())
        return logits[:, 0]

    # -- the step loop -------------------------------------------------
    def step(self) -> int:
        """One scheduler iteration: admit into free slots, one batched
        decode step, sample + evict.  Returns the number of slots still
        active afterwards."""
        self._check_mesh()          # no-op unless mesh-parallel serving
        self._admit_free_slots()
        self._advance_prefills()    # no-op unless chunked prefill is on
        active = self.slot_manager.active_slots()
        if not active:
            return 0
        if self._decode_engine is not None:
            logits = self._bucketed_decode(len(active))
            active = self.slot_manager.active_slots()  # post-compaction
        else:
            decode = self._decode_aot or self._decode
            logits, self.slot_manager.cache = decode(
                self.params, self.slot_manager.cache, self._tokens())
            logits = logits[:, 0]
        self.metrics.decode_steps += 1
        self.metrics.decode_slot_steps += len(active)
        for slot in active:
            st = self.slot_manager.state(slot)
            m = self.request_metrics[st.uid]
            tok = self.sampler(logits[slot:slot + 1], st.temperature,
                               uid=st.uid, index=m.new_tokens)
            self.generated[st.uid].append(tok)
            self.last_token[slot, 0] = tok
            m.new_tokens += 1
            self.metrics.total_new_tokens += 1
            st.remaining -= 1
            if tok == st.eos_id:
                self._retire(slot, "eos")
            elif st.remaining <= 0:
                self._retire(slot, "length")
        self.metrics.last_step_at = self.clock()
        return self.slot_manager.num_active()

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        """Drain the queue; returns all completions in finish order."""
        steps = 0
        while ((self.queue_depth() or self.slot_manager.num_active()
                or self._prefilling)
               and steps < max_steps):
            self.step()
            steps += 1
        return self.done

    def pop_completions(self, *, purge: bool = False) -> List[Completion]:
        """Completions finished since the last pop (streaming drain).

        With ``purge=True`` the scheduler also forgets the popped
        requests entirely — their ``done`` entries, token lists,
        per-request metrics — and their uids become reusable.  A
        long-running server MUST drain with ``purge=True`` or
        per-request state grows without bound (aggregate
        ``SchedulerMetrics`` counters are unaffected; purged requests
        simply drop out of ``summary()``'s mean-TTFT)."""
        out, self._pending = self._pending, []
        if purge and out:
            drop = {c.uid for c in out}
            self.done = [c for c in self.done if c.uid not in drop]
            for uid in drop:
                self.generated.pop(uid, None)
                self.request_metrics.pop(uid, None)
        return out

    # -- reporting -----------------------------------------------------
    def summary(self) -> dict:
        """Aggregate metrics: counters and TTFT/queue percentiles, plus
        runtime engine stats, the active-precision audit record, and
        chunked-prefill / prefix-cache sections when those features are
        active."""
        engines = {}
        if self._decode_engine is not None:
            engines["decode"] = self._decode_engine.stats()
        if self._prefill_engine is not None:
            engines["prefill"] = self._prefill_engine.stats()
        if self._chunk_engine is not None:
            engines["chunk"] = self._chunk_engine.stats()
        out = self.metrics.summary(self.request_metrics)
        if engines:
            rt = {k: sum(e[k] for e in engines.values())
                  for k in ("bucket_hits", "bucket_misses",
                            "fallback_serves", "background_compiles",
                            "compile_stalls")}
            pad = sum(e["pad_elems"] for e in engines.values())
            total = sum(e["total_elems"] for e in engines.values())
            rt["pad_waste_frac"] = (pad / total) if total else 0.0
            rt.update(engines)
            out["runtime"] = rt
        if self.precision_info is not None:
            out["precision"] = dict(self.precision_info)
        if self.options.prefill_chunk is not None:
            out["chunked_prefill"] = {
                "enabled": self._chunk_engine is not None,
                "chunk_len": self.options.prefill_chunk,
            }
        if self._prefix_cache is not None:
            out["prefix_cache"] = self._prefix_cache.stats()
        if self.options.mesh is not None:
            out["faults"] = [dict(f) for f in self._faults]
            out["sharding"] = self._sharding_summary()
        return out

    def _sharding_summary(self) -> dict:
        """Mesh description plus per-axis collective counts and
        bytes-moved estimates, read from the compiled decode program(s)'
        post-optimization HLO (see :func:`_axis_collectives`)."""
        spec = self.options.mesh
        texts: List[str] = []
        programs = []
        if self._decode_aot is not None:
            programs.append(self._decode_aot)
        elif self._decode_engine is not None:
            programs.extend(
                self._decode_engine.peek(b)
                for b in self._decode_engine.warm_buckets())
        for prog in programs:
            try:
                texts.append(prog.as_text())
            except Exception:
                continue               # text unavailable: skip, not fail
        return {"mesh": spec.describe(), "devices": spec.size,
                "decode_programs": len(texts),
                "collectives": _axis_collectives(texts, spec)}

    # legacy Engine attribute surface, used by the deprecated shim
    @property
    def cache(self) -> Any:
        """The batched KV cache (legacy ``Engine.cache`` surface)."""
        return self.slot_manager.cache
