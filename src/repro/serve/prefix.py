"""PrefixCache — shared-prompt-head KV snapshots (the "system prompt"
scenario).

Requests that share a common prompt head would each recompute the same
KV rows at prefill.  The scheduler detects sharing (longest common
prefix against the waiting queue), prefills the head once through the
chunked-prefill program, and snapshots the single-row cache at a chunk
boundary into this LRU.  Later requests whose prompt starts with a
cached head take a COPY of the snapshot and prefill only their tail —
bit-identical to an unshared prefill, because the snapshot holds
exactly the rows a full prefill would have written for those positions.

Copy discipline (copy-on-write): the chunk programs DONATE their cache
argument, so both directions copy —

* ``insert`` copies the producer's live cache (which the producer's
  next chunk will donate-overwrite);
* ``take`` hands the consumer a fresh copy it may donate freely.

The shared snapshot itself is therefore never aliased by any compiled
program and never mutated.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def common_prefix_len(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the longest common head of two 1-D token arrays."""
    n = min(len(a), len(b))
    if n == 0:
        return 0
    eq = np.asarray(a[:n]) == np.asarray(b[:n])
    return int(n if eq.all() else np.argmin(eq))


class PrefixCache:
    """LRU of prompt-head token bytes -> (head length, KV snapshot)."""

    def __init__(self, capacity: int) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[bytes, Tuple[int, Any]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.shared_tokens = 0   # head tokens NOT recomputed, over hits

    @staticmethod
    def key_for(tokens: np.ndarray) -> bytes:
        """The cache key for a head: its int32 token bytes."""
        return np.ascontiguousarray(tokens, np.int32).tobytes()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: bytes) -> bool:
        return key in self._entries

    def insert(self, key: bytes, head_len: int, cache: Any) -> None:
        """Snapshot ``cache`` (deep-copied) under ``key``, evicting the
        least-recently-used entry beyond capacity."""
        snap = jax.tree.map(jnp.copy, cache)
        self._entries[key] = (head_len, snap)
        self._entries.move_to_end(key)
        self.inserts += 1
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def take(self, prompt: np.ndarray) -> Optional[Tuple[int, Any]]:
        """The longest cached head that is a PROPER prefix of ``prompt``
        (at least one tail token must remain to produce first-token
        logits), as ``(head_len, cache_copy)`` — or None.  Counts a hit
        or a miss; a hit refreshes LRU order."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        best_key = None
        best = None
        for key, (h, snap) in self._entries.items():
            if h >= len(prompt) or h <= (0 if best is None else best[0]):
                continue
            if prompt[:h].tobytes() == key:
                best_key, best = key, (h, snap)
        if best is None:
            self.misses += 1
            return None
        self._entries.move_to_end(best_key)
        self.hits += 1
        self.shared_tokens += best[0]
        return best[0], jax.tree.map(jnp.copy, best[1])

    def stats(self) -> dict:
        """Hit/miss/insert/eviction counters plus the total head tokens
        whose recompute the cache avoided."""
        return {
            "entries": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "shared_tokens": self.shared_tokens,
        }
