"""Slot / KV-cache manager: the memory half of continuous batching.

Owns the batched cache pytree (the paper: "input and output tensors are
owned by CompiledNN because it needs control over the actual memory
layout") and the per-slot host bookkeeping.  Admission splices a
freshly prefilled single-row cache into a free slot; eviction just
marks the slot free — the row is overwritten by the next admission, so
no memory moves on retire.

Extracted and generalized from ``inference.engine.Engine``'s
``_splice_impl`` / ``_fill_free_slots`` / ``_retire``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax


@dataclasses.dataclass
class SlotState:
    """Host-side record for one occupied decode slot."""

    uid: int
    remaining: int           # decode steps left before forced retire
    eos_id: int              # -1 = never
    temperature: float


class SlotManager:
    """Owns the batched KV cache and the per-slot request states:
    admission writes one request's rows in, retirement frees them."""

    def __init__(self, model, slots: int, max_len: int, *,
                 shard: Optional[Callable[[Any], Any]] = None) -> None:
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self._states: List[Optional[SlotState]] = [None] * slots
        # Mesh-aware serving: ``shard`` maps a cache leaf to its
        # NamedSharding.  The cache is placed once here and every
        # cache-mutating program re-constrains its output, so the
        # batched cache NEVER drifts off its placement — the AOT decode
        # programs commit to exactly these shardings.
        self._shard = shard
        if shard is not None:
            self.cache = jax.tree.map(
                lambda l: jax.device_put(l, shard(l)), self.cache)

        def constrain(tree):
            if shard is None:
                return tree
            return jax.tree.map(
                lambda l: jax.lax.with_sharding_constraint(l, shard(l)),
                tree)

        # donate the batched cache: splice writes one row in place
        self._splice = jax.jit(
            lambda c, o, slot: constrain(self._splice_impl(c, o, slot)),
            donate_argnums=(0,), static_argnums=(2,))
        # row move for compaction; src/dst are traced, so one program
        # serves every (src, dst) pair
        self._move = jax.jit(
            lambda c, s, d: constrain(self._move_impl(c, s, d)),
            donate_argnums=(0,))

    # ------------------------------------------------------------------
    @staticmethod
    def _splice_impl(cache, one_cache, slot: int):
        """Copy the single-row cache ``one_cache`` into row ``slot`` of
        every batch-indexed leaf.  Leaves are (L, B, ...) except the
        position vector (B,)."""
        def put(dst, src):
            if dst.ndim == 1:                      # pos (B,)
                return dst.at[slot].set(src[0])
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))
        return jax.tree.map(put, cache, one_cache)

    @staticmethod
    def _move_impl(cache, src, dst):
        """Copy slot row ``src`` over row ``dst`` in every leaf."""
        def mv(l):
            if l.ndim == 1:                        # pos (B,)
                return l.at[dst].set(l[src])
            return l.at[:, dst].set(l[:, src])
        return jax.tree.map(mv, cache)

    # ------------------------------------------------------------------
    def buffer_pointers(self) -> Tuple[int, ...]:
        """The device buffer address of every cache leaf — the handle
        zero-allocation tests use: across steady-state decode steps the
        donated step program must leave every pointer unchanged (the
        cache is updated in place, never reallocated)."""
        return tuple(l.unsafe_buffer_pointer()
                     for l in jax.tree.leaves(self.cache))

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        """Indices of empty slots."""
        return [s for s, st in enumerate(self._states) if st is None]

    def active_slots(self) -> List[int]:
        """Indices of occupied slots."""
        return [s for s, st in enumerate(self._states) if st is not None]

    def num_active(self) -> int:
        """Number of occupied slots."""
        return sum(st is not None for st in self._states)

    def state(self, slot: int) -> Optional[SlotState]:
        """The request state in ``slot`` (None when free)."""
        return self._states[slot]

    # ------------------------------------------------------------------
    def admit(self, slot: int, state: SlotState, one_cache: Any) -> None:
        """Occupy ``slot`` with ``state``, splicing its prefilled
        single-row cache into the batched cache."""
        if self._states[slot] is not None:
            raise RuntimeError(f"slot {slot} is occupied "
                               f"(uid={self._states[slot].uid})")
        self.cache = self._splice(self.cache, one_cache, slot)
        self._states[slot] = state

    def evict(self, slot: int) -> SlotState:
        """Free ``slot``; the cache row is left in place and simply
        overwritten by the next admission."""
        st = self._states[slot]
        if st is None:
            raise RuntimeError(f"slot {slot} is already free")
        self._states[slot] = None
        return st

    # ------------------------------------------------------------------
    def compact(self) -> List[Tuple[int, int]]:
        """Move active rows down so they occupy the prefix ``[0, k)`` —
        the invariant bucketed decode needs to slice the first ``k``
        cache rows.  Each hole below ``k`` is filled by the *highest*
        active row (one move per hole, no cascades).  Returns the
        ``(src, dst)`` moves so the caller can mirror them in host-side
        per-slot state (``last_token``)."""
        moves: List[Tuple[int, int]] = []
        while True:
            active = self.active_slots()
            k = len(active)
            hole = next((s for s in range(k)
                         if self._states[s] is None), None)
            if hole is None:
                return moves
            src = active[-1]
            self.cache = self._move(self.cache, src, hole)
            self._states[hole], self._states[src] = self._states[src], None
            moves.append((src, hole))
