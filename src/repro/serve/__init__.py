"""repro.serve — continuous-batching serving over compiled executables.

    import repro
    from repro.serve import Request

    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    sched = repro.serve(exe, repro.SchedulerOptions(slots=8))
    sched.submit(Request(uid=0, prompt=toks))
    completions = sched.run()
    print(sched.summary())

One scheduler (`Scheduler`), one options object (`SchedulerOptions`),
per-request metrics (`RequestMetrics`), and a slot/KV-cache manager
(`SlotManager`) extracted from the legacy ``inference.Engine`` — which
is now a deprecated shim over this package.

The module itself is callable — ``repro.serve(executable, options)``
delegates to :func:`repro.api.serve.serve` — so the package namespace
(``repro.serve.Scheduler``) and the API entry point share one name.
"""

import sys as _sys
import types as _types

from .metrics import RequestMetrics, SchedulerMetrics
from .options import ADMISSION_POLICIES, SchedulerOptions
from .prefix import PrefixCache
from .scheduler import (Completion, QueueFullError, Request, Scheduler,
                        TemperatureSampler)
from .slots import SlotManager, SlotState

__all__ = [
    "ADMISSION_POLICIES",
    "Completion",
    "PrefixCache",
    "QueueFullError",
    "Request",
    "RequestMetrics",
    "Scheduler",
    "SchedulerMetrics",
    "SchedulerOptions",
    "SlotManager",
    "SlotState",
    "TemperatureSampler",
]


class _CallableServeModule(_types.ModuleType):
    def __call__(self, executable, options=None, **kw):
        from ..api.serve import serve as _serve   # lazy: avoids a cycle
        return _serve(executable, options, **kw)


_sys.modules[__name__].__class__ = _CallableServeModule
