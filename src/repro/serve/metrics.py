"""Per-request and aggregate serving metrics.

Timestamps come from the scheduler's injected ``clock`` (default
``time.perf_counter``), so tests drive a fake clock and assert exact
TTFT / throughput numbers.  ``summary()`` reports tail percentiles
(p50/p99), not just means — means hide exactly the TTFT tail that
SLO-aware admission targets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


def percentile(values: List[float], q: float) -> Optional[float]:
    """The ``q``-th percentile (0..100) of ``values`` with linear
    interpolation between order statistics — the same definition as
    ``numpy.percentile``'s default, kept dependency-free so metrics
    never import numpy.  Returns None on an empty list."""
    if not values:
        return None
    v = sorted(values)
    if len(v) == 1:
        return v[0]
    pos = (len(v) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(v) - 1)
    frac = pos - lo
    return v[lo] + (v[hi] - v[lo]) * frac


@dataclasses.dataclass
class RequestMetrics:
    """Lifecycle timestamps and counters for one request."""

    uid: int
    prompt_tokens: int
    submitted_at: float
    queue_depth_at_submit: int
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    new_tokens: int = 0
    finish_reason: Optional[str] = None   # "eos" | "length" | None
    #: Absolute first-token deadline (``submitted_at + slo_ms/1e3`` on
    #: the scheduler clock); None = no SLO.
    deadline: Optional[float] = None
    #: Set at first-token time: True if the deadline was missed.
    #: None until the first token (or when there is no deadline).
    slo_violated: Optional[bool] = None

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (submit -> first sampled token)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def queue_time(self) -> Optional[float]:
        """Seconds spent waiting in the queue before admission."""
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def decode_tokens_per_s(self) -> Optional[float]:
        """Steady-state decode rate (excludes queueing and prefill)."""
        if self.finished_at is None or self.first_token_at is None:
            return None
        dt = self.finished_at - self.first_token_at
        if dt <= 0:
            return None
        # the first token comes from prefill; the rest are decode steps
        return (self.new_tokens - 1) / dt

    def to_dict(self) -> dict:
        """Plain-dict view including the derived ttft/queue_time."""
        d = dataclasses.asdict(self)
        d["ttft"] = self.ttft
        d["queue_time"] = self.queue_time
        d["decode_tokens_per_s"] = self.decode_tokens_per_s
        return d


@dataclasses.dataclass
class SchedulerMetrics:
    """Aggregate counters maintained by the scheduler step loop."""

    submitted: int = 0
    rejected: int = 0
    admitted: int = 0
    completed: int = 0
    decode_steps: int = 0
    decode_slot_steps: int = 0   # sum of active slots over decode steps
    peak_queue_depth: int = 0
    started_at: Optional[float] = None
    last_step_at: Optional[float] = None
    total_new_tokens: int = 0
    #: Requests whose first token landed after their deadline.
    slo_violations: int = 0
    #: Incremental prefill chunks executed (chunked prefill only).
    prefill_chunks: int = 0

    @property
    def mean_batch_occupancy(self) -> Optional[float]:
        """Average number of active slots per decode step — how well
        continuous batching keeps the fixed-shape decode program full."""
        if self.decode_steps == 0:
            return None
        return self.decode_slot_steps / self.decode_steps

    @property
    def tokens_per_s(self) -> Optional[float]:
        """Aggregate new-token throughput over the serving window."""
        if (self.started_at is None or self.last_step_at is None
                or self.last_step_at <= self.started_at):
            return None
        return self.total_new_tokens / (self.last_step_at - self.started_at)

    def summary(self, per_request: Dict[int, RequestMetrics]) -> dict:
        """Aggregate report: totals plus TTFT / queue-depth p50+p99."""
        ttfts = [m.ttft for m in per_request.values() if m.ttft is not None]
        depths = [float(m.queue_depth_at_submit)
                  for m in per_request.values()]
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "admitted": self.admitted,
            "completed": self.completed,
            "decode_steps": self.decode_steps,
            "mean_batch_occupancy": self.mean_batch_occupancy,
            "peak_queue_depth": self.peak_queue_depth,
            "total_new_tokens": self.total_new_tokens,
            "tokens_per_s": self.tokens_per_s,
            "mean_ttft": (sum(ttfts) / len(ttfts)) if ttfts else None,
            "ttft_p50": percentile(ttfts, 50.0),
            "ttft_p99": percentile(ttfts, 99.0),
            "queue_depth_p50": percentile(depths, 50.0),
            "queue_depth_p99": percentile(depths, 99.0),
            "slo_violations": self.slo_violations,
            "prefill_chunks": self.prefill_chunks,
        }
