"""SchedulerOptions — the one options surface for ``repro.serve``.

The serving twin of ``CompileOptions``: a frozen, hashable dataclass
holding every scheduling choice, so a serving configuration can be
logged, compared and embedded in benchmark artifacts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..dist.mesh import MeshSpec
from ..runtime.buckets import BucketPolicy

ADMISSION_POLICIES = ("fcfs", "shortest", "deadline")


@dataclasses.dataclass(frozen=True)
class SchedulerOptions:
    """Every serving-time choice, in one place.

    slots:        number of concurrent decode slots (the fixed batch the
                  decode program is specialized for; continuous batching
                  rebatches at slot granularity every step).
    max_len:      KV-cache capacity per slot.  A request whose prompt
                  alone exceeds it is rejected at submit; ``max_new_tokens``
                  is clamped so the cache can never overflow.
    admission:    queue discipline used when a slot frees up —
                  ``"fcfs"`` (arrival order), ``"shortest"`` (shortest
                  prompt first, minimizes mean TTFT under bursty load)
                  or ``"deadline"`` (earliest-deadline-first over each
                  request's ``slo_ms``; requests without an SLO sort
                  after every deadline, FCFS among themselves — the
                  policy that minimizes ``slo_violations`` under a
                  mixed interactive/batch trace).
    max_queue:    admission control: ``submit`` raises
                  :class:`QueueFullError` once this many requests are
                  waiting.  ``None`` = unbounded.
    fold:         run ``fold_norms`` on the params at scheduler build
                  (compile-time weight rewriting, paper §3.5).
    seed:         PRNG seed for the default temperature sampler.
    buckets:      a :class:`repro.runtime.BucketPolicy` enabling
                  shape-polymorphic serving: each step decodes at the
                  smallest warm batch bucket covering the active slots
                  (cache rows sliced, outputs written back) and prefill
                  runs one program per length bucket instead of one per
                  prompt length; cold buckets compile on a background
                  worker.  Buckets are clipped to ``slots``/``max_len``.
                  ``None`` (default) = fixed-shape serving, bit-identical
                  to the pre-bucketing scheduler.
    prefill_chunk: chunk size (tokens) for incremental prefill.  Long
                  prompts are prefilled ``prefill_chunk`` tokens per
                  scheduler step, interleaved with decode steps, so a
                  long prompt never blocks in-flight decodes (tokens
                  stay bit-identical — see ``models.prefill_chunk``).
                  Must divide ``max_len``.  ``None`` (default) =
                  whole-prompt prefill at admission.  Auto-disabled
                  (surfaced in ``summary()["chunked_prefill"]``) for
                  model families without incremental prefill: MLA
                  latent caches, vlm/audio extra inputs, ring caches.
    prefix_cache: capacity (entries) of the shared-prompt-head KV
                  cache.  When > 0, requests whose prompts share a
                  common head (the "system prompt" scenario) prefill
                  that head ONCE: the head's KV rows are snapshotted at
                  a chunk boundary and later requests splice a copy and
                  prefill only their tail (copy-on-write — the shared
                  snapshot is never mutated).  Requires
                  ``prefill_chunk``.  ``0`` (default) = off.
    min_prefix:   minimum shared-head length (tokens) worth caching;
                  the effective floor is ``max(min_prefix,
                  prefill_chunk)`` since snapshots land on chunk
                  boundaries.
    mesh:         a :class:`repro.MeshSpec` (or any spelling
                  ``MeshSpec.coerce`` accepts, e.g. ``"data=2,model=2"``)
                  enabling data×model-parallel serving: the batched KV
                  cache shards its slot (batch) dim over the ``data``
                  axes and its sequence dim over the ``model`` axes (the
                  ``kv_seq`` rule), params are replicated, and the
                  decode program is AOT-compiled against those placings
                  so steady-state decode never stalls on a compile.
                  The step loop re-checks device availability every
                  iteration and surfaces shrink faults as typed
                  :class:`repro.MeshUnavailableError` entries in
                  ``summary()["faults"]``.  ``repro.serve`` defaults
                  this from the executable's own
                  ``CompileOptions.mesh``.  ``None`` = single-device
                  serving (bit-identical tokens to a 1×1 mesh).
    """

    slots: int = 4
    max_len: int = 256
    admission: str = "fcfs"
    max_queue: Optional[int] = None
    fold: bool = True
    seed: int = 0
    buckets: Optional[BucketPolicy] = None
    prefill_chunk: Optional[int] = None
    prefix_cache: int = 0
    min_prefix: int = 0
    mesh: Optional[MeshSpec] = None

    def __post_init__(self) -> None:
        if self.slots <= 0:
            raise ValueError(f"slots must be positive, got {self.slots}")
        if self.max_len <= 1:
            raise ValueError(f"max_len must be > 1, got {self.max_len}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of "
                             f"{ADMISSION_POLICIES}, got {self.admission!r}")
        if self.max_queue is not None and self.max_queue <= 0:
            raise ValueError(f"max_queue must be positive or None, "
                             f"got {self.max_queue}")
        if isinstance(self.buckets, dict):      # to_dict round-trip
            object.__setattr__(self, "buckets",
                               BucketPolicy.from_dict(self.buckets))
        if self.buckets is not None and not isinstance(self.buckets,
                                                       BucketPolicy):
            raise ValueError(
                f"buckets must be a repro.runtime.BucketPolicy or None, "
                f"got {type(self.buckets).__name__}")
        if self.prefill_chunk is not None:
            if self.prefill_chunk <= 0:
                raise ValueError(f"prefill_chunk must be positive or "
                                 f"None, got {self.prefill_chunk}")
            if self.max_len % self.prefill_chunk != 0:
                raise ValueError(
                    f"prefill_chunk ({self.prefill_chunk}) must divide "
                    f"max_len ({self.max_len})")
        if self.prefix_cache < 0:
            raise ValueError(f"prefix_cache must be >= 0, "
                             f"got {self.prefix_cache}")
        if self.prefix_cache > 0 and self.prefill_chunk is None:
            raise ValueError(
                "prefix_cache requires prefill_chunk: shared heads are "
                "snapshotted at chunk boundaries and tails are "
                "prefilled incrementally")
        if self.min_prefix < 0:
            raise ValueError(f"min_prefix must be >= 0, "
                             f"got {self.min_prefix}")
        if self.mesh is not None and not isinstance(self.mesh, MeshSpec):
            object.__setattr__(self, "mesh", MeshSpec.coerce(self.mesh))

    def replace(self, **kw) -> "SchedulerOptions":
        """Copy with the given fields replaced (re-validates)."""
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        """Plain-dict view of every option field."""
        return dataclasses.asdict(self)
