"""SchedulerOptions — the one options surface for ``repro.serve``.

The serving twin of ``CompileOptions``: a frozen, hashable dataclass
holding every scheduling choice, so a serving configuration can be
logged, compared and embedded in benchmark artifacts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..runtime.buckets import BucketPolicy

ADMISSION_POLICIES = ("fcfs", "shortest")


@dataclasses.dataclass(frozen=True)
class SchedulerOptions:
    """Every serving-time choice, in one place.

    slots:        number of concurrent decode slots (the fixed batch the
                  decode program is specialized for; continuous batching
                  rebatches at slot granularity every step).
    max_len:      KV-cache capacity per slot.  A request whose prompt
                  alone exceeds it is rejected at submit; ``max_new_tokens``
                  is clamped so the cache can never overflow.
    admission:    queue discipline used when a slot frees up —
                  ``"fcfs"`` (arrival order) or ``"shortest"`` (shortest
                  prompt first, minimizes mean TTFT under bursty load).
    max_queue:    admission control: ``submit`` raises
                  :class:`QueueFullError` once this many requests are
                  waiting.  ``None`` = unbounded.
    fold:         run ``fold_norms`` on the params at scheduler build
                  (compile-time weight rewriting, paper §3.5).
    seed:         PRNG seed for the default temperature sampler.
    buckets:      a :class:`repro.runtime.BucketPolicy` enabling
                  shape-polymorphic serving: each step decodes at the
                  smallest warm batch bucket covering the active slots
                  (cache rows sliced, outputs written back) and prefill
                  runs one program per length bucket instead of one per
                  prompt length; cold buckets compile on a background
                  worker.  Buckets are clipped to ``slots``/``max_len``.
                  ``None`` (default) = fixed-shape serving, bit-identical
                  to the pre-bucketing scheduler.
    """

    slots: int = 4
    max_len: int = 256
    admission: str = "fcfs"
    max_queue: Optional[int] = None
    fold: bool = True
    seed: int = 0
    buckets: Optional[BucketPolicy] = None

    def __post_init__(self) -> None:
        if self.slots <= 0:
            raise ValueError(f"slots must be positive, got {self.slots}")
        if self.max_len <= 1:
            raise ValueError(f"max_len must be > 1, got {self.max_len}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of "
                             f"{ADMISSION_POLICIES}, got {self.admission!r}")
        if self.max_queue is not None and self.max_queue <= 0:
            raise ValueError(f"max_queue must be positive or None, "
                             f"got {self.max_queue}")
        if isinstance(self.buckets, dict):      # to_dict round-trip
            object.__setattr__(self, "buckets",
                               BucketPolicy.from_dict(self.buckets))
        if self.buckets is not None and not isinstance(self.buckets,
                                                       BucketPolicy):
            raise ValueError(
                f"buckets must be a repro.runtime.BucketPolicy or None, "
                f"got {type(self.buckets).__name__}")

    def replace(self, **kw) -> "SchedulerOptions":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)
