"""Logical-axis sharding rules (MaxText-style) for the LM framework.

Model code annotates tensors with *logical* axis names; the rules table
maps them to mesh axes of whatever mesh is active.  With no mesh (unit
tests on 1 CPU device) every annotation is a no-op, so the same model
code runs everywhere — the compile-time-specialization philosophy of the
paper extended to distribution: the sharding is part of the compiled
artifact, not of the model definition.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

#: logical axis -> mesh axis (or tuple of mesh axes).  "batch" composes
#: pod×data so a multi-pod mesh is pure DP across pods by default.
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,             # sequence kept replicated by default ...
    "seq_shard": "data",     # ... except where context parallelism is on
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_capacity": None,
    "vocab": "model",
    # Decode KV caches shard the SEQUENCE dim over "model"
    # (flash-decoding layout): context lengths are always 16-divisible,
    # unlike kv-head counts (8, 1, ...), and the only collective the
    # layout needs is a tiny psum of the (B,H,dv) attention output.
    "kv_seq": "model",
    "conv": None,
    "state": None,
    "frames": None,
    # Parameter-only axes.  "fsdp" shards the weight fan-in dim over the
    # data axis (ZeRO-3-style: GSPMD all-gathers each layer's params at
    # use inside the scan); "layers" is the scan-stack dim.
    "fsdp": "data",
    "layers": None,
}

_local = threading.local()


def current_rules() -> Dict[str, AxisVal]:
    return getattr(_local, "rules", DEFAULT_RULES)


def current_mesh() -> Optional[Mesh]:
    mesh = getattr(_local, "mesh", None)
    if mesh is not None:
        return mesh
    # Fall back to the global mesh context (``with mesh:``).
    env_mesh = jax.sharding.get_abstract_mesh() if hasattr(
        jax.sharding, "get_abstract_mesh") else None
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        return m if m.devices.size > 1 else None
    except Exception:
        return None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, AxisVal]] = None):
    """Activate a mesh + rules for model-code annotations."""
    prev_mesh = getattr(_local, "mesh", None)
    prev_rules = getattr(_local, "rules", None)
    _local.mesh = mesh
    _local.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _local.mesh = prev_mesh
        if prev_rules is None:
            if hasattr(_local, "rules"):
                del _local.rules
        else:
            _local.rules = prev_rules


def spec_for(*logical_axes: Optional[str]) -> P:
    """PartitionSpec for a tuple of logical axis names, deduplicating
    mesh axes (a mesh axis may appear at most once in a spec)."""
    rules = current_rules()
    used = set()
    parts = []
    for ax in logical_axes:
        val = rules.get(ax) if ax else None
        if val is None:
            parts.append(None)
            continue
        axes = (val,) if isinstance(val, str) else tuple(val)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
            used.add(axes[0])
        else:
            parts.append(axes)
            used.update(axes)
    return P(*parts)


def logical(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op if no mesh
    is active or the mesh axes don't exist on the current mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(*logical_axes)
    # Drop mesh axes that this mesh doesn't have (e.g. "pod" on 2D mesh).
    names = set(mesh.axis_names)

    def keep(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in names else None
        kept = tuple(a for a in v if a in names)
        return kept if kept else None

    spec = P(*(keep(v) for v in spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical_axes: Optional[str]) -> NamedSharding:
    with use_mesh(None):  # rules only; don't re-enter mesh ctx
        pass
    spec = spec_for(*logical_axes)
    names = set(mesh.axis_names)

    def keep(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in names else None
        kept = tuple(a for a in v if a in names)
        return kept if kept else None

    return NamedSharding(mesh, P(*(keep(v) for v in spec)))
