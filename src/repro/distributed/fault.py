"""Fault-tolerance runtime pieces: straggler watchdog + retry wrapper.

At 1000+ nodes the failure model is (a) slow steps (network flaps, ECC
retries — mitigated by the watchdog raising after a deadline so the
launcher can restart from the last checkpoint), and (b) hard node loss
(the restart path itself: elastic restore re-shards to whatever mesh
comes back — see checkpoint/).  Both paths are exercised in tests by
simulation, per the assignment's CPU-only constraint.

Inference-side device loss is typed, not opaque: a sharded executable
or serve scheduler whose visible device set shrinks below its
:class:`~repro.dist.mesh.MeshSpec` raises
:class:`MeshUnavailableError` naming the axes that can no longer be
filled (re-exported here; :func:`check_mesh` is the polling form the
watchdogs compose with).  ``repro.serve`` records each raise in
``summary()["faults"]``.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Sequence

from ..dist.mesh import (MeshSpec, MeshUnavailableError,
                         ensure_mesh_available)


def check_mesh(spec: MeshSpec,
               devices: Optional[Sequence] = None) -> Optional[dict]:
    """One mesh-availability poll: ``None`` when ``spec`` fits the
    visible device set, else a plain-dict fault record (the shape
    ``repro.serve`` stores in ``summary()["faults"]``) — the
    non-raising twin of :func:`~repro.dist.mesh.ensure_mesh_available`
    for watchdog loops that want to log and keep running."""
    try:
        ensure_mesh_available(spec, devices)
    except MeshUnavailableError as e:
        return {"mesh": e.spec.describe(), "needed": e.needed,
                "available": e.available,
                "missing_axes": list(e.missing_axes)}
    return None


class StragglerWatchdog:
    """Deadline monitor for train steps.

    >>> wd = StragglerWatchdog(deadline_s=300, on_timeout=alarm)
    >>> with wd.step(i):           # raises / calls back if exceeded
    ...     train_step(...)
    """

    def __init__(self, deadline_s: float,
                 on_timeout: Optional[Callable[[int, float], None]] = None):
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout
        self.timeouts: list = []
        self._timer: Optional[threading.Timer] = None

    class _StepCtx:
        def __init__(self, wd: "StragglerWatchdog", step: int):
            self.wd, self.step = wd, step
            self.t0 = 0.0

        def __enter__(self):
            self.t0 = time.monotonic()
            wd = self.wd

            def fire():
                elapsed = time.monotonic() - self.t0
                wd.timeouts.append((self.step, elapsed))
                if wd.on_timeout:
                    wd.on_timeout(self.step, elapsed)

            wd._timer = threading.Timer(wd.deadline_s, fire)
            wd._timer.daemon = True
            wd._timer.start()
            return self

        def __exit__(self, *exc):
            if self.wd._timer is not None:
                self.wd._timer.cancel()
                self.wd._timer = None
            return False

    def step(self, step_idx: int) -> "_StepCtx":
        return self._StepCtx(self, step_idx)


def run_with_restarts(make_step: Callable[[], Callable[[int], None]],
                      n_steps: int, max_restarts: int = 3,
                      start_step: Callable[[], int] = lambda: 0) -> int:
    """Drive `step_fn(i)` for i in [start, n_steps), restarting the whole
    stack (make_step re-invoked — fresh compile, restored state) on
    failure.  Returns the number of restarts used."""
    restarts = 0
    while True:
        step_fn = make_step()
        i = start_step()
        try:
            while i < n_steps:
                step_fn(i)
                i += 1
            return restarts
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
