from . import sharding
from .fault import StragglerWatchdog, run_with_restarts
