from . import sharding
from .fault import (MeshUnavailableError, StragglerWatchdog, check_mesh,
                    run_with_restarts)
