"""Neural-network graph IR — the compiler's input.

This is the JAX analogue of CompiledNN's internal representation: a
static computational graph whose shapes (and, for inference, weights)
are known at compile time.  Every optimization pass in
``repro.core.passes`` consumes and produces this IR; the back end
(``repro.core.compiler``) lowers it to a jitted JAX program, and the
oracle (``repro.core.simple``) interprets it node by node.

Design notes
------------
* Tensors are identified by string names; ``Graph.params`` maps names of
  constant tensors (weights) to host numpy arrays.  Keeping weights as
  named constants is what lets passes rewrite them (BN folding, layout
  transformation) — the paper's "weights are compile-time constants so
  their layout is free" (Eq. 3) is only expressible if weights live in
  the IR.
* Shapes use NHWC for image tensors (TPU-native layout; the paper used
  HWC on x86 for the same streaming-friendliness reason).
* The IR is deliberately small: exactly the ops needed for the paper's
  Table-1 network suite plus generic elementwise/reduction ops.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


def _conv_out_hw(h, w, kh, kw, sh, sw, padding):
    """Output spatial dims for 'same'/'valid'/explicit ((t,b),(l,r)) padding."""
    if padding == "same":
        return -(-h // sh), -(-w // sw)
    if padding == "valid":
        return (h - kh) // sh + 1, (w - kw) // sw + 1
    (t, b), (l, r) = padding
    return (h + t + b - kh) // sh + 1, (w + l + r - kw) // sw + 1


# Ops understood by the IR.  Each entry: op name -> required attrs.
OPS: Dict[str, Tuple[str, ...]] = {
    "input": (),
    "constant": (),
    "conv2d": ("strides", "padding"),          # weights: (kh, kw, cin, cout)
    "depthwise_conv2d": ("strides", "padding"),  # weights: (kh, kw, c, mult)
    "dense": (),                                # weights: (cin, cout)
    "batchnorm": ("epsilon",),                  # params: gamma, beta, mean, var
    "activation": ("fn",),                      # fn in ACTIVATIONS
    "maxpool2d": ("pool_size", "strides", "padding"),
    "avgpool2d": ("pool_size", "strides", "padding"),
    "global_avg_pool": (),
    "upsample2d": ("factor",),                  # nearest-neighbour
    "zero_pad2d": ("padding",),                 # ((t,b),(l,r))
    "add": (),
    "mul": (),
    "concat": ("axis",),
    "reshape": ("shape",),
    "flatten": (),
    "softmax": ("axis",),
    # inputs: q (H,D), k_cache (S,Hkv,D), v_cache (S,Hkv,D)
    # [, lengths () int32]; optional attr "scale" (default D**-0.5)
    "decode_attention": (),
}


def register_op(name: str, required_attrs: Sequence[str] = ()) -> None:
    """Extend the IR vocabulary with a new op (idempotent).  Pair with
    :func:`register_shape_rule` and a ``repro.core.lowering``
    ``@register_lowering`` rule to make it compilable end to end."""
    OPS[name] = tuple(required_attrs)


#: Shape-inference rules for ops registered from outside this module:
#: op -> fn(node, input_specs, graph) -> TensorSpec.  Consulted before
#: the built-in rules, so a plug-in op never edits ``_infer_node``.
SHAPE_RULES: Dict[str, Any] = {}


def register_shape_rule(op: str):
    """Decorator: register the static shape rule for ``op``."""

    def deco(fn):
        SHAPE_RULES[op] = fn
        return fn

    return deco

#: Activation functions the compiler understands.  ``fusable`` means the
#: back end may apply them as an epilogue of a producing matmul/conv
#: (paper §3.4: applied in registers before the store).
ACTIVATIONS = {
    "linear": True,
    "relu": True,
    "relu6": True,
    "leaky_relu": True,
    "sigmoid": True,   # via tanh identity, Eq. 4
    "tanh": True,
    "elu": True,
    "hard_sigmoid": True,
    "softmax": False,  # two-pass, never fusable (paper §3.4)
}


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Shape+dtype of an IR tensor (batch dim excluded; the compiler
    specializes on the batch size separately, like the paper specializes
    on the input shape)."""

    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    @property
    def nbytes(self) -> int:
        return self.size * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class Signature:
    """First-class model I/O: ordered, named, multi-input *and*
    multi-output.

    ``inputs`` and ``outputs`` are ordered ``(name, spec)`` pairs.  The
    names are the *public* contract — what ``Executable.__call__``
    binds positionally-or-by-keyword and what the output dict is keyed
    by — independent of the SSA tensor names inside the graph.  A
    ``spec`` may be ``None`` for executables whose shapes are not
    statically known (the framework-scale "engine" target).
    """

    inputs: Tuple[Tuple[str, Optional[TensorSpec]], ...]
    outputs: Tuple[Tuple[str, Optional[TensorSpec]], ...]

    @property
    def input_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.inputs)

    @property
    def output_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.outputs)

    def bind(self, args: Sequence[Any], kwargs: Dict[str, Any]) -> Dict[str, Any]:
        """Positional-or-keyword binding of call arguments to input
        names (missing-input checks are left to the caller, which knows
        how to phrase its own diagnostic)."""
        names = self.input_names
        if len(args) > len(names):
            raise TypeError(
                f"got {len(args)} positional inputs; signature takes "
                f"{len(names)}: {list(names)}")
        bound = dict(zip(names, args))
        for k, v in kwargs.items():
            if k in bound:
                raise TypeError(f"got multiple values for input {k!r}")
            bound[k] = v
        return bound

    # -- (de)serialization --------------------------------------------
    @staticmethod
    def _spec_dict(spec: Optional[TensorSpec]):
        if spec is None:
            return None
        return {"shape": list(spec.shape), "dtype": spec.dtype}

    @staticmethod
    def _spec_from(d) -> Optional[TensorSpec]:
        if d is None:
            return None
        return TensorSpec(tuple(d["shape"]), d["dtype"])

    def to_dict(self) -> dict:
        return {
            "inputs": [[n, self._spec_dict(s)] for n, s in self.inputs],
            "outputs": [[n, self._spec_dict(s)] for n, s in self.outputs],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Signature":
        return cls(
            inputs=tuple((n, cls._spec_from(s)) for n, s in d["inputs"]),
            outputs=tuple((n, cls._spec_from(s)) for n, s in d["outputs"]),
        )

    def cache_token(self) -> str:
        """Stable string for the persistent executable-cache key: two
        compilations whose public I/O contract differs (names, order,
        shapes) must never share a cached program."""
        return json.dumps(self.to_dict(), sort_keys=True)


@dataclasses.dataclass
class Node:
    """One IR node.  ``params`` holds names of weight tensors in
    ``Graph.params``; ``attrs`` holds static attributes.

    ``epilogue`` is filled in by the activation-fusion pass: the name of
    an activation to apply to this node's output inside the producing
    kernel (the paper's "before writing the result into memory").
    """

    op: str
    name: str
    inputs: List[str]
    output: str
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    params: Dict[str, str] = dataclasses.field(default_factory=dict)
    epilogue: Optional[str] = None
    epilogue_attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def validate(self) -> None:
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r} in node {self.name!r}")
        for attr in OPS[self.op]:
            if attr not in self.attrs:
                raise ValueError(
                    f"node {self.name!r} (op {self.op}) missing attr {attr!r}"
                )


class Graph:
    """A static NN graph: nodes in insertion order + named weights.

    The graph is SSA-like: every tensor name is produced by exactly one
    node (or is a graph input); nodes may consume any previously
    produced tensor.
    """

    def __init__(self) -> None:
        self.nodes: List[Node] = []
        self.inputs: Dict[str, TensorSpec] = {}
        self.outputs: List[str] = []
        self.params: Dict[str, np.ndarray] = {}
        self._producers: Dict[str, Node] = {}
        # Public output names (None = default to the tensor names).
        self._output_names: Optional[List[str]] = None
        # Incrementally-maintained shape specs: add_input/add_node keep
        # it current so construction-time queries (ModelBuilder, the
        # tracer) are O(1) per layer instead of re-inferring the whole
        # graph.  Any mutation outside those two paths invalidates it
        # (None), and infer_shapes() falls back to the full walk.
        self._spec_cache: Optional[Dict[str, TensorSpec]] = {}
        # Distribution annotations (repro.dist): {"mesh", "rules"} set
        # by a sharded compile, {"shardings", "edits"} added by the
        # propagation pass.  None = unsharded; mixed into
        # structure_hash() only when set, so unsharded hashes (and
        # every existing cache key) are unchanged.
        self.dist: Optional[Dict[str, Any]] = None
        # Quantization request (repro.core.passes.quantize): {"mode",
        # "calibrate", "budget", ...} set by a low-precision compile;
        # the pass consumes it and annotates nodes with quant.* attrs.
        # Same contract as `dist`: None = full precision, mixed into
        # structure_hash() only when set so f32 cache keys are
        # unchanged.
        self.quant: Optional[Dict[str, Any]] = None

    # -- construction -------------------------------------------------
    def add_input(self, name: str, shape: Sequence[int], dtype: str = "float32") -> str:
        if name in self.inputs or name in self._producers:
            raise ValueError(f"duplicate tensor name {name!r}")
        self.inputs[name] = TensorSpec(tuple(shape), dtype)
        if self._spec_cache is not None:
            self._spec_cache[name] = self.inputs[name]
        return name

    def add_param(self, name: str, value: np.ndarray) -> str:
        if name in self.params:
            raise ValueError(f"duplicate param name {name!r}")
        self.params[name] = np.asarray(value, dtype=np.float32)
        return name

    def add_node(
        self,
        op: str,
        name: str,
        inputs: Sequence[str],
        output: Optional[str] = None,
        attrs: Optional[Dict[str, Any]] = None,
        params: Optional[Dict[str, str]] = None,
    ) -> str:
        output = output or f"{name}:out"
        if output in self._producers or output in self.inputs:
            raise ValueError(f"duplicate tensor name {output!r}")
        for t in inputs:
            if t not in self._producers and t not in self.inputs:
                raise ValueError(f"node {name!r} consumes unknown tensor {t!r}")
        node = Node(
            op=op,
            name=name,
            inputs=list(inputs),
            output=output,
            attrs=dict(attrs or {}),
            params=dict(params or {}),
        )
        node.validate()
        for p in node.params.values():
            if p not in self.params:
                raise ValueError(f"node {name!r} references unknown param {p!r}")
        self.nodes.append(node)
        self._producers[output] = node
        if self._spec_cache is not None:
            try:
                self._spec_cache[output] = self._infer_node(
                    node, self._spec_cache)
            except Exception:
                # Not inferable right now (missing input spec, plug-in
                # rule quirk, genuinely invalid graph): drop the cache;
                # infer_shapes() will recompute — and surface the real
                # error where it always has.
                self._spec_cache = None
        return output

    def set_outputs(self, names) -> None:
        """Declare the graph outputs.

        ``names`` is either a sequence of tensor names (public output
        names default to the tensor names) or a mapping of *public name
        -> tensor name*, which gives the outputs user-chosen names —
        the multi-output half of the graph's :class:`Signature`.
        """
        if isinstance(names, dict):
            public, tensors = list(names.keys()), list(names.values())
        else:
            public, tensors = None, list(names)
        for n in tensors:
            if n not in self._producers and n not in self.inputs:
                raise ValueError(f"unknown output tensor {n!r}")
        if public is not None and len(set(public)) != len(public):
            raise ValueError(f"duplicate output names {public}")
        self.outputs = tensors
        self._output_names = public

    @property
    def output_names(self) -> List[str]:
        """Public output names, parallel to ``outputs`` (defaults to
        the tensor names when none were chosen)."""
        if (self._output_names is not None
                and len(self._output_names) == len(self.outputs)):
            return list(self._output_names)
        return list(self.outputs)

    def signature(self) -> Signature:
        """The graph's public I/O contract (names + static specs)."""
        specs = self.infer_shapes()
        return Signature(
            inputs=tuple(self.inputs.items()),
            outputs=tuple((pub, specs[t])
                          for pub, t in zip(self.output_names, self.outputs)),
        )

    # -- queries ------------------------------------------------------
    def producer(self, tensor: str) -> Optional[Node]:
        return self._producers.get(tensor)

    def consumers(self, tensor: str) -> List[Node]:
        return [n for n in self.nodes if tensor in n.inputs]

    def rebuild_index(self) -> None:
        """Recompute the producer index after passes mutate ``nodes``."""
        self._producers = {n.output: n for n in self.nodes}
        self._spec_cache = None

    def toposort(self) -> List[Node]:
        """Nodes are appended in topological order by construction, but
        passes may reorder; verify and return a valid order."""
        available = set(self.inputs)
        order: List[Node] = []
        pending = list(self.nodes)
        while pending:
            progressed = False
            rest: List[Node] = []
            for node in pending:
                if all(t in available for t in node.inputs):
                    order.append(node)
                    available.add(node.output)
                    progressed = True
                else:
                    rest.append(node)
            if not progressed:
                names = [n.name for n in rest]
                raise ValueError(f"graph has a cycle or dangling inputs: {names}")
            pending = rest
        return order

    # -- shape inference ---------------------------------------------
    def infer_shapes(self) -> Dict[str, TensorSpec]:
        """Static shape inference over the whole graph.

        This is the compile-time knowledge the paper exploits: every
        intermediate tensor's shape is known before any code runs.
        """
        if (self._spec_cache is not None
                and len(self._spec_cache) == len(self.inputs) + len(self.nodes)):
            return dict(self._spec_cache)
        specs: Dict[str, TensorSpec] = dict(self.inputs)
        for node in self.toposort():
            specs[node.output] = self._infer_node(node, specs)
        self._spec_cache = dict(specs)
        return specs

    def spec(self, tensor: str) -> TensorSpec:
        """Static spec of one tensor — O(1) during construction (the
        incremental cache), a full inference otherwise."""
        if self._spec_cache is not None and tensor in self._spec_cache:
            return self._spec_cache[tensor]
        return self.infer_shapes()[tensor]

    def _infer_node(self, node: Node, specs: Dict[str, TensorSpec]) -> TensorSpec:
        op = node.op
        ins = [specs[t] for t in node.inputs]
        if op in SHAPE_RULES:
            return SHAPE_RULES[op](node, ins, self)
        if op == "constant":
            return TensorSpec(tuple(self.params[node.params["value"]].shape))
        if op == "conv2d":
            h, w, _ = ins[0].shape
            kh, kw, _, cout = self.params[node.params["kernel"]].shape
            sh, sw = node.attrs["strides"]
            oh, ow = _conv_out_hw(h, w, kh, kw, sh, sw, node.attrs["padding"])
            return TensorSpec((oh, ow, cout))
        if op == "depthwise_conv2d":
            h, w, c = ins[0].shape
            kh, kw, _, mult = self.params[node.params["kernel"]].shape
            sh, sw = node.attrs["strides"]
            oh, ow = _conv_out_hw(h, w, kh, kw, sh, sw, node.attrs["padding"])
            return TensorSpec((oh, ow, c * mult))
        if op == "dense":
            kshape = self.params[node.params["kernel"]].shape
            if node.attrs.get("kernel_layout") == "oi":
                cout, cin = kshape
            else:
                cin, cout = kshape
            # The layout pass may have padded cout; the logical width is
            # the original (the back end slices the padding off).
            cout = node.attrs.get("orig_cout", cout)
            if ins[0].shape[-1] != cin:
                raise ValueError(
                    f"dense {node.name!r}: input {ins[0].shape} vs kernel cin {cin}"
                )
            return TensorSpec(ins[0].shape[:-1] + (cout,))
        if op in ("batchnorm", "activation"):
            return ins[0]
        if op in ("maxpool2d", "avgpool2d"):
            h, w, c = ins[0].shape
            ph, pw = node.attrs["pool_size"]
            sh, sw = node.attrs["strides"]
            oh, ow = _conv_out_hw(h, w, ph, pw, sh, sw, node.attrs["padding"])
            return TensorSpec((oh, ow, c))
        if op == "global_avg_pool":
            return TensorSpec((ins[0].shape[-1],))
        if op == "upsample2d":
            h, w, c = ins[0].shape
            f = node.attrs["factor"]
            return TensorSpec((h * f, w * f, c))
        if op == "zero_pad2d":
            (t, b), (l, r) = node.attrs["padding"]
            h, w, c = ins[0].shape
            return TensorSpec((h + t + b, w + l + r, c))
        if op in ("add", "mul"):
            if ins[0].shape != ins[1].shape:
                raise ValueError(f"{op} {node.name!r}: shape mismatch {ins}")
            return ins[0]
        if op == "concat":
            ax = node.attrs["axis"]
            shape = list(ins[0].shape)
            shape[ax] = sum(s.shape[ax] for s in ins)
            return TensorSpec(tuple(shape))
        if op == "reshape":
            return TensorSpec(tuple(node.attrs["shape"]))
        if op == "flatten":
            return TensorSpec((ins[0].size,))
        if op == "softmax":
            return ins[0]
        if op == "decode_attention":
            h, d = ins[0].shape
            s, hkv, dk = ins[1].shape
            if ins[1].shape != ins[2].shape:
                raise ValueError(
                    f"{op} {node.name!r}: K/V cache shapes differ "
                    f"{ins[1].shape} vs {ins[2].shape}")
            if dk != d or h % hkv:
                raise ValueError(
                    f"{op} {node.name!r}: q (H={h}, D={d}) incompatible "
                    f"with cache (Hkv={hkv}, D={dk}); H must be a "
                    f"multiple of Hkv")
            return ins[0]
        raise NotImplementedError(op)

    # -- hashing (compile-cache key) ----------------------------------
    def structure_hash(self) -> str:
        """Hash of the graph structure + shapes (not weight values).

        Used as the compile-cache key: two models with identical
        architecture share a compiled program when weights are passed as
        arguments (framework mode); in embed_weights mode the weight
        hash is mixed in by the compiler.
        """
        payload = {
            "inputs": {k: (v.shape, v.dtype) for k, v in self.inputs.items()},
            "outputs": self.outputs,
            "output_names": self.output_names,
            "nodes": [
                (
                    n.op,
                    n.name,
                    tuple(n.inputs),
                    n.output,
                    json.dumps(n.attrs, sort_keys=True, default=str),
                    tuple(sorted(n.params.items())),
                    n.epilogue,
                    json.dumps(n.epilogue_attrs, sort_keys=True, default=str),
                )
                for n in self.nodes
            ],
            "param_shapes": {k: v.shape for k, v in sorted(self.params.items())},
        }
        if self.dist:
            payload["dist"] = self.dist
        if self.quant:
            payload["quant"] = self.quant
        blob = json.dumps(payload, sort_keys=True, default=str).encode()
        return hashlib.sha256(blob).hexdigest()

    def copy(self) -> "Graph":
        g = Graph()
        g.inputs = dict(self.inputs)
        g.outputs = list(self.outputs)
        g.params = {k: v.copy() for k, v in self.params.items()}
        g.nodes = [
            Node(
                op=n.op,
                name=n.name,
                inputs=list(n.inputs),
                output=n.output,
                attrs=dict(n.attrs),
                params=dict(n.params),
                epilogue=n.epilogue,
                epilogue_attrs=dict(n.epilogue_attrs),
            )
            for n in self.nodes
        ]
        g.rebuild_index()
        g._output_names = (list(self._output_names)
                           if self._output_names is not None else None)
        g._spec_cache = (dict(self._spec_cache)
                         if self._spec_cache is not None else None)
        if self.dist is not None:
            import copy as _copy
            g.dist = _copy.deepcopy(self.dist)
        if self.quant is not None:
            import copy as _copy
            g.quant = _copy.deepcopy(self.quant)
        return g

    def summary(self) -> str:
        specs = self.infer_shapes()
        lines = [f"Graph: {len(self.nodes)} nodes, {len(self.params)} params"]
        for node in self.nodes:
            epi = f" +{node.epilogue}" if node.epilogue else ""
            lines.append(
                f"  {node.name:<24} {node.op:<18}{epi:<12} -> {specs[node.output].shape}"
            )
        return "\n".join(lines)
