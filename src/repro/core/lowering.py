"""Graph-IR → JAX lowering: one function per trace.

This is the back end shared by every compiled target: it walks an
(optimized) graph once, at ``jax.jit`` trace time, emitting jnp/lax ops
— the analogue of CompiledNN walking its graph once to emit machine
code.  Nothing here runs per inference call; the walk is baked into the
jaxpr.

``execute_graph`` is a pure function of ``(graph, env, params)`` plus
static lowering choices (``precision``, ``use_pallas``), so both the
legacy ``CompiledModel`` shim and the ``repro.api`` targets call it.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .graph import Graph, Node
from .simple import _activation, _lax_padding, _pool_padding
from ..kernels.fast_act import ref as fast_ref
from ..kernels.fused_matmul.ops import fused_matmul


def fast_activation(fn: str, x: jnp.ndarray, attrs: Dict) -> jnp.ndarray:
    """The paper's §3.4 approximations; falls back to exact forms."""
    if fn == "tanh":
        return fast_ref.cf_tanh(x)
    if fn == "sigmoid":
        return fast_ref.cf_sigmoid(x)
    if fn == "softmax":
        return fast_ref.fast_softmax(x, axis=attrs.get("axis", -1))
    if fn == "elu":
        return jnp.where(x >= 0, x, fast_ref.schraudolph_exp(x) - 1.0)
    return _activation(fn, x, attrs)


def execute_graph(
    graph: Graph,
    env: Dict[str, jnp.ndarray],
    params,
    *,
    precision: str = "exact",
    use_pallas: bool = False,
) -> Dict[str, jnp.ndarray]:
    """Trace the graph.  ``env`` maps input names to (traced) arrays."""
    for node in graph.toposort():
        env[node.output] = emit_node(
            node, env, params, precision=precision, use_pallas=use_pallas
        )
    return {name: env[name] for name in graph.outputs}


def emit_node(
    node: Node,
    env: Dict[str, jnp.ndarray],
    params,
    *,
    precision: str = "exact",
    use_pallas: bool = False,
) -> jnp.ndarray:
    op = node.op
    ins = [env[t] for t in node.inputs]
    act = fast_activation if precision == "fast" else _activation

    def epilogue(y):
        if node.epilogue and node.epilogue != "linear":
            y = act(node.epilogue, y, node.epilogue_attrs)
        pa = node.epilogue_attrs.get("post_affine")
        if pa:
            s, o = params[pa[0]], params[pa[1]]
            y = y * s + o
        return y

    if op == "constant":
        batch = next(iter(env.values())).shape[0] if env else 1
        v = params[node.params["value"]]
        return jnp.broadcast_to(v, (batch,) + v.shape)

    if op == "dense":
        w = params[node.params["kernel"]]
        b = params[node.params["bias"]] if "bias" in node.params else None
        layout = node.attrs.get("kernel_layout", "io")
        pa = node.epilogue_attrs.get("post_affine")
        scale = params[pa[0]] if pa else None
        offset = params[pa[1]] if pa else None
        fn = node.epilogue if node.epilogue not in (None, "linear") else None
        if fn == "softmax":
            fn = None  # handled below (two-pass, not fusable in-kernel)
        y = fused_matmul(
            ins[0], w, b, scale, offset,
            fn=fn,
            fast=precision == "fast",
            w_layout=layout,
            use_pallas=use_pallas,
            attrs=node.epilogue_attrs,
        )
        if "orig_cout" in node.attrs:
            y = y[..., : node.attrs["orig_cout"]]
        if node.epilogue == "softmax":
            y = act("softmax", y, node.epilogue_attrs)
        return y

    if op == "conv2d":
        k = params[node.params["kernel"]]
        y = jax.lax.conv_general_dilated(
            ins[0], k,
            window_strides=node.attrs["strides"],
            padding=_lax_padding(node.attrs["padding"]),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if "bias" in node.params:
            y = y + params[node.params["bias"]]
        return epilogue(y)

    if op == "depthwise_conv2d":
        k = params[node.params["kernel"]]
        kh, kw, c, mult = k.shape
        y = jax.lax.conv_general_dilated(
            ins[0], k.reshape(kh, kw, 1, c * mult),
            window_strides=node.attrs["strides"],
            padding=_lax_padding(node.attrs["padding"]),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c,
        )
        if "bias" in node.params:
            y = y + params[node.params["bias"]]
        return epilogue(y)

    if op == "batchnorm":
        # Unfolded BN survives only when no adjacent foldable layer
        # existed; emit the precomputed affine (scale/offset folded
        # at compile time — cheaper than the 4-param formula).
        gamma = params[node.params["gamma"]]
        beta = params[node.params["beta"]]
        mean = params[node.params["mean"]]
        var = params[node.params["var"]]
        eps = node.attrs["epsilon"]
        s = gamma * jax.lax.rsqrt(var + eps)
        o = beta - s * mean
        return epilogue(ins[0] * s + o)

    if op == "activation":
        return epilogue(act(node.attrs["fn"], ins[0], node.attrs))

    if op == "maxpool2d":
        y = jax.lax.reduce_window(
            ins[0], -jnp.inf, jax.lax.max,
            (1,) + tuple(node.attrs["pool_size"]) + (1,),
            (1,) + tuple(node.attrs["strides"]) + (1,),
            _pool_padding(node.attrs["padding"]),
        )
        return epilogue(y)

    if op == "avgpool2d":
        window = (1,) + tuple(node.attrs["pool_size"]) + (1,)
        strides = (1,) + tuple(node.attrs["strides"]) + (1,)
        pad = _pool_padding(node.attrs["padding"])
        s = jax.lax.reduce_window(ins[0], 0.0, jax.lax.add, window, strides, pad)
        ones = jnp.ones_like(ins[0])
        nrm = jax.lax.reduce_window(ones, 0.0, jax.lax.add, window, strides, pad)
        return epilogue(s / nrm)

    if op == "global_avg_pool":
        return epilogue(jnp.mean(ins[0], axis=(1, 2)))

    if op == "upsample2d":
        f = node.attrs["factor"]
        return epilogue(jnp.repeat(jnp.repeat(ins[0], f, axis=1), f, axis=2))

    if op == "zero_pad2d":
        (t, b), (l, r) = node.attrs["padding"]
        return epilogue(jnp.pad(ins[0], ((0, 0), (t, b), (l, r), (0, 0))))

    if op == "add":
        return epilogue(ins[0] + ins[1])
    if op == "mul":
        return epilogue(ins[0] * ins[1])
    if op == "concat":
        return epilogue(jnp.concatenate(ins, axis=node.attrs["axis"] + 1))
    if op == "reshape":
        return epilogue(
            ins[0].reshape((ins[0].shape[0],) + tuple(node.attrs["shape"]))
        )
    if op == "flatten":
        return epilogue(ins[0].reshape(ins[0].shape[0], -1))
    if op == "softmax":
        return epilogue(act("softmax", ins[0], node.attrs))
    raise NotImplementedError(op)
