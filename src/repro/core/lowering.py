"""Graph-IR → JAX lowering: a rule registry, one function per op.

This is the back end shared by every compiled target: it walks an
(optimized) graph once, at ``jax.jit`` trace time, emitting jnp/lax ops
— the analogue of CompiledNN walking its graph once to emit machine
code.  Nothing here runs per inference call; the walk is baked into the
jaxpr.

Ops lower through registered rules instead of a monolithic dispatch::

    @register_lowering("my_op")
    def _lower_my_op(node, ins, ctx):
        return ctx.epilogue(node, some_jnp_expression(ins))

A rule may be target-specific — ``register_lowering("dense",
target="pallas")`` overrides the generic rule only when compiling for
the ``"pallas"`` target, which is how the Pallas kernels plug in
without a ``use_pallas`` flag threading through every signature.  The
target rule consults the compile-time kernel selection
(:mod:`repro.core.selection`) carried by the :class:`LoweringContext`,
so shape-unfriendly nodes fall back to the generic lax path.

``execute_graph`` is a pure function of ``(graph, env, params)`` plus
the static context (precision, target, batch size, selection), so both
the legacy ``CompiledModel`` shim and the ``repro.api`` targets call it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, Node
from .ops_common import (apply_activation, fast_activation, lax_padding,
                         pool_padding)
from ..kernels import qmath
from ..kernels.decode_attention.ops import decode_attention as decode_attention_op
from ..kernels.fast_act.ops import fast_act
from ..kernels.fused_matmul.ops import fused_matmul, fused_matmul_q8


class UnsupportedOpError(NotImplementedError):
    """No lowering rule for an op — a structured diagnostic instead of a
    bare ``NotImplementedError(op)``."""

    def __init__(self, op: str, target: Optional[str]) -> None:
        self.op = op
        self.target = target
        ops = registered_ops(target)
        super().__init__(
            f"no lowering rule for op {op!r}"
            + (f" (target {target!r})" if target else "")
            + f"; registered ops: {', '.join(ops)}. "
            f"Add one with @register_lowering({op!r})"
            + (f" or @register_lowering({op!r}, target={target!r})"
               if target else "")
        )


@dataclasses.dataclass
class LoweringContext:
    """Static compile-time state threaded through every lowering rule.

    ``batch_size`` is the explicit runtime batch the program is being
    specialized for — rules must use it rather than peeking at some
    other tensor's leading dimension (which crashes on input-free
    prefixes and mis-broadcasts rank-1 tensors).
    ``selection`` maps node names to the kernel selector's
    :class:`~repro.core.selection.KernelChoice` for this compilation;
    target rules honor it and fall back to the generic path when the
    selector said so.
    """

    params: Mapping[str, jnp.ndarray]
    batch_size: int = 1
    precision: str = "exact"
    target: Optional[str] = None
    selection: Mapping[str, "KernelChoice"] = dataclasses.field(
        default_factory=dict)
    #: Sharded compiles (repro.dist): the live jax Mesh, the resolved
    #: per-tensor axis lists from ``graph.dist["shardings"]``, and the
    #: mesh's {axis name: size} map (what collective lowerings consult
    #: for their static shard geometry).  Empty/None = unsharded.
    mesh: Optional[object] = None
    shardings: Mapping[str, list] = dataclasses.field(default_factory=dict)
    mesh_axis_sizes: Mapping[str, int] = dataclasses.field(
        default_factory=dict)

    def act(self, fn: str, x: jnp.ndarray, attrs: Dict) -> jnp.ndarray:
        if self.precision == "fast":
            return fast_activation(fn, x, attrs)
        return apply_activation(fn, x, attrs)

    def epilogue(self, node: Node, y: jnp.ndarray) -> jnp.ndarray:
        """Apply the node's fused epilogue: activation, then the folded
        post-activation affine (paper §3.4/§3.5)."""
        if node.epilogue and node.epilogue != "linear":
            y = self.act(node.epilogue, y, node.epilogue_attrs)
        pa = node.epilogue_attrs.get("post_affine")
        if pa:
            s, o = self.params[pa[0]], self.params[pa[1]]
            y = y * s + o
        return y

    def wants(self, node: Node, kernel: str) -> bool:
        """Did the selector pick ``kernel`` for this node?  Nodes absent
        from the selection default to the target's native kernel, so
        legacy callers that skip selection keep the old behavior."""
        choice = self.selection.get(node.name)
        return choice is None or choice.kernel == kernel

    def tuned_block(self, node: Node):
        """The autotuner's measured block geometry for this node, or
        None.  Heuristic choices return None on purpose — the kernel
        wrappers then recompute ``pick_block`` exactly as they always
        have, keeping ``autotune="off"`` bit-identical to the
        pre-autotuner compiler."""
        choice = self.selection.get(node.name)
        if choice is not None and choice.source == "measured":
            return choice.block
        return None


LoweringRule = Callable[[Node, List[jnp.ndarray], LoweringContext], jnp.ndarray]

#: (op, target) -> rule; target=None is the generic rule.
_RULES: Dict[Tuple[str, Optional[str]], LoweringRule] = {}


def register_lowering(
    op: str, *, target: Optional[str] = None
) -> Callable[[LoweringRule], LoweringRule]:
    """Decorator: register the lowering rule for ``op`` (overwrites).
    With ``target=``, the rule only applies when compiling for that
    target and shadows the generic rule."""

    def deco(rule: LoweringRule) -> LoweringRule:
        _RULES[(op, target)] = rule
        return rule

    return deco


def get_lowering(op: str, target: Optional[str] = None) -> LoweringRule:
    """The rule for ``op`` under ``target``: target-specific override
    first, generic rule otherwise."""
    rule = _RULES.get((op, target)) or _RULES.get((op, None))
    if rule is None:
        raise UnsupportedOpError(op, target)
    return rule


def registered_ops(target: Optional[str] = None) -> Tuple[str, ...]:
    """Ops lowerable under ``target`` (generic rules always count)."""
    return tuple(sorted({op for op, t in _RULES if t in (None, target)}))


_FILE_DIGESTS: Dict[str, str] = {}


def _hash_code(h, code) -> None:
    """Recursive, process-stable digest of a code object: bytecode,
    referenced names, and nested code objects (a ``repr`` of co_consts
    would embed memory addresses of nested lambdas/comprehensions and
    change every run)."""
    import types

    h.update(code.co_code)
    h.update(" ".join(code.co_names).encode())
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            _hash_code(h, c)
        else:
            h.update(repr(c).encode())


def _rule_token(rule) -> str:
    """Per-rule digest: the defining module's source (so edits to
    same-module helpers like ``_dense_impl`` count) plus the rule's own
    recursive bytecode (so re-registering a different body from the same
    file counts).  Helpers in *other* modules (e.g. the kernel bodies)
    are outside this boundary — clear the cache dir when editing those
    across an executable-cache-sharing fleet."""
    import hashlib
    import sys

    h = hashlib.sha256()
    mod = sys.modules.get(getattr(rule, "__module__", ""))
    src_file = getattr(mod, "__file__", None)
    if src_file:
        if src_file not in _FILE_DIGESTS:
            try:
                with open(src_file, "rb") as f:
                    _FILE_DIGESTS[src_file] = hashlib.sha256(
                        f.read()).hexdigest()
            except OSError:
                _FILE_DIGESTS[src_file] = src_file
        h.update(_FILE_DIGESTS[src_file].encode())
    code = getattr(rule, "__code__", None)
    if code is not None:
        _hash_code(h, code)
    return h.hexdigest()


def lowering_fingerprint(target: Optional[str] = None) -> str:
    """Digest of the rule set effective under ``target``, mixed into the
    persistent executable-cache key: registering, removing, or editing a
    rule (including a plug-in op's) changes the key instead of silently
    serving a stale executable.  Deterministic across processes."""
    import hashlib

    h = hashlib.sha256()
    for (op, t), rule in sorted(_RULES.items(),
                                key=lambda kv: (kv[0][0], kv[0][1] or "")):
        if t in (None, target):
            h.update(f"{op}/{t}/{_rule_token(rule)}".encode())
    return h.hexdigest()


def sharding_constraint(x: jnp.ndarray, entry, mesh) -> jnp.ndarray:
    """Apply one resolved per-tensor sharding as a
    ``with_sharding_constraint`` on ``x`` (batch-inclusive axis lists,
    as stored in ``graph.dist["shardings"]``).

    Dims whose size does not divide the named axes' device product are
    left unconstrained (e.g. batch 1 over ``data=4``) — the constraint
    is a placement hint, never a shape requirement — so numerics are
    mesh-independent by construction and a single-device mesh is a
    no-op."""
    if mesh is None or not entry or len(entry) != x.ndim:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    sizes = dict(mesh.shape)
    parts = []
    for dim, axes in zip(x.shape, entry):
        axes = [a for a in (axes or ()) if a in sizes]
        k = 1
        for a in axes:
            k *= sizes[a]
        if k <= 1 or dim % k:
            parts.append(None)
        else:
            parts.append(axes[0] if len(axes) == 1 else tuple(axes))
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*parts)))


def execute_graph(
    graph: Graph,
    env: Dict[str, jnp.ndarray],
    params,
    *,
    precision: str = "exact",
    use_pallas: bool = False,
    target: Optional[str] = None,
    batch_size: Optional[int] = None,
    selection: Optional[Mapping[str, "KernelChoice"]] = None,
    mesh=None,
    shardings: Optional[Mapping[str, list]] = None,
) -> Dict[str, jnp.ndarray]:
    """Trace the graph.  ``env`` maps input names to (traced) arrays.

    ``use_pallas`` is the legacy spelling of ``target="pallas"``.  If
    ``batch_size`` is not given it is read off the first graph *input*
    (never an arbitrary env entry).  With a ``mesh`` + resolved
    ``shardings`` (a sharded compile), every graph input and node
    output gets its propagated placement re-applied as a sharding
    constraint — the anchors XLA's SPMD partitioner works between.
    """
    if target is None:
        target = "pallas" if use_pallas else "jit"
    if batch_size is None:
        for name in graph.inputs:
            if name in env:
                batch_size = env[name].shape[0]
                break
        else:
            batch_size = 1
    shardings = shardings or {}
    ctx = LoweringContext(
        params=params,
        batch_size=batch_size,
        precision=precision,
        target=target,
        selection=selection or {},
        mesh=mesh,
        shardings=shardings,
        mesh_axis_sizes=dict(mesh.shape) if mesh is not None else {},
    )
    if mesh is not None:
        for name in graph.inputs:
            if name in env:
                env[name] = sharding_constraint(
                    env[name], shardings.get(name), mesh)
    for node in graph.toposort():
        rule = get_lowering(node.op, target)
        ins = [env[t] for t in node.inputs]
        out = rule(node, ins, ctx)
        if mesh is not None:
            out = sharding_constraint(out, shardings.get(node.output), mesh)
        env[node.output] = out
    return {name: env[name] for name in graph.outputs}


# ---------------------------------------------------------------------------
# Generic rules (every target)
# ---------------------------------------------------------------------------
@register_lowering("constant")
def _lower_constant(node, ins, ctx):
    v = ctx.params[node.params["value"]]
    return jnp.broadcast_to(v, (ctx.batch_size,) + tuple(v.shape))


def _dense_impl(node, ins, ctx, use_pallas: bool, block=None):
    w = ctx.params[node.params["kernel"]]
    b = ctx.params[node.params["bias"]] if "bias" in node.params else None
    layout = node.attrs.get("kernel_layout", "io")
    pa = node.epilogue_attrs.get("post_affine")
    scale = ctx.params[pa[0]] if pa else None
    offset = ctx.params[pa[1]] if pa else None
    fn = node.epilogue if node.epilogue not in (None, "linear") else None
    if fn == "softmax":
        fn = None  # handled below (two-pass, not fusable in-kernel)
    qm = node.attrs.get("quant.mode")
    if qm == "int8":
        # quant.w_scale is per *logical* out channel (the pass runs
        # pre-layout); the layout pass may have padded the kernel to a
        # LANE multiple afterwards.  Padded channels are zero, so any
        # scale works — pad with 1.0 to the physical width.
        ws = np.asarray(node.attrs["quant.w_scale"], dtype=np.float32)
        pn = w.shape[1] if layout == "io" else w.shape[0]
        if ws.shape[0] < pn:
            ws = np.pad(ws, (0, pn - ws.shape[0]), constant_values=1.0)
        y = fused_matmul_q8(
            ins[0], w, b, scale, offset,
            x_scale=node.attrs["quant.x_scale"],
            w_scales=ws,
            fn=fn,
            fast=ctx.precision == "fast",
            w_layout=layout,
            use_pallas=use_pallas,
            block=block,
            attrs=node.epilogue_attrs,
        )
    else:
        x = ins[0]
        if qm == "bf16":
            x, w = qmath.bf16_cast_pair(x, w)
        y = fused_matmul(
            x, w, b, scale, offset,
            fn=fn,
            fast=ctx.precision == "fast",
            w_layout=layout,
            use_pallas=use_pallas,
            block=block,
            attrs=node.epilogue_attrs,
        )
    if "orig_cout" in node.attrs:
        y = y[..., : node.attrs["orig_cout"]]
    if node.epilogue == "softmax":
        y = ctx.act("softmax", y, node.epilogue_attrs)
    return y


@register_lowering("dense")
def _lower_dense(node, ins, ctx):
    return _dense_impl(node, ins, ctx, use_pallas=False)


@register_lowering("conv2d")
def _lower_conv2d(node, ins, ctx):
    k = ctx.params[node.params["kernel"]]
    qm = node.attrs.get("quant.mode")
    if qm == "int8":
        y = qmath.conv2d_q8(
            ins[0], k,
            node.attrs["quant.x_scale"], node.attrs["quant.w_scale"],
            strides=node.attrs["strides"],
            padding=lax_padding(node.attrs["padding"]))
    elif qm == "bf16":
        y = qmath.conv2d_bf16(
            ins[0], k,
            strides=node.attrs["strides"],
            padding=lax_padding(node.attrs["padding"]))
    else:
        y = jax.lax.conv_general_dilated(
            ins[0], k,
            window_strides=node.attrs["strides"],
            padding=lax_padding(node.attrs["padding"]),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
    if "bias" in node.params:
        y = y + ctx.params[node.params["bias"]]
    return ctx.epilogue(node, y)


@register_lowering("depthwise_conv2d")
def _lower_depthwise_conv2d(node, ins, ctx):
    k = ctx.params[node.params["kernel"]]
    kh, kw, c, mult = k.shape
    y = jax.lax.conv_general_dilated(
        ins[0], k.reshape(kh, kw, 1, c * mult),
        window_strides=node.attrs["strides"],
        padding=lax_padding(node.attrs["padding"]),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    if "bias" in node.params:
        y = y + ctx.params[node.params["bias"]]
    return ctx.epilogue(node, y)


@register_lowering("batchnorm")
def _lower_batchnorm(node, ins, ctx):
    # Unfolded BN survives only when no adjacent foldable layer existed;
    # emit the precomputed affine (scale/offset folded at compile time —
    # cheaper than the 4-param formula).
    gamma = ctx.params[node.params["gamma"]]
    beta = ctx.params[node.params["beta"]]
    mean = ctx.params[node.params["mean"]]
    var = ctx.params[node.params["var"]]
    eps = node.attrs["epsilon"]
    s = gamma * jax.lax.rsqrt(var + eps)
    o = beta - s * mean
    return ctx.epilogue(node, ins[0] * s + o)


@register_lowering("activation")
def _lower_activation(node, ins, ctx):
    return ctx.epilogue(node, ctx.act(node.attrs["fn"], ins[0], node.attrs))


@register_lowering("maxpool2d")
def _lower_maxpool2d(node, ins, ctx):
    y = jax.lax.reduce_window(
        ins[0], -jnp.inf, jax.lax.max,
        (1,) + tuple(node.attrs["pool_size"]) + (1,),
        (1,) + tuple(node.attrs["strides"]) + (1,),
        pool_padding(node.attrs["padding"]),
    )
    return ctx.epilogue(node, y)


@register_lowering("avgpool2d")
def _lower_avgpool2d(node, ins, ctx):
    window = (1,) + tuple(node.attrs["pool_size"]) + (1,)
    strides = (1,) + tuple(node.attrs["strides"]) + (1,)
    pad = pool_padding(node.attrs["padding"])
    s = jax.lax.reduce_window(ins[0], 0.0, jax.lax.add, window, strides, pad)
    ones = jnp.ones_like(ins[0])
    nrm = jax.lax.reduce_window(ones, 0.0, jax.lax.add, window, strides, pad)
    return ctx.epilogue(node, s / nrm)


@register_lowering("global_avg_pool")
def _lower_global_avg_pool(node, ins, ctx):
    return ctx.epilogue(node, jnp.mean(ins[0], axis=(1, 2)))


@register_lowering("upsample2d")
def _lower_upsample2d(node, ins, ctx):
    f = node.attrs["factor"]
    return ctx.epilogue(node, jnp.repeat(jnp.repeat(ins[0], f, axis=1), f, axis=2))


@register_lowering("zero_pad2d")
def _lower_zero_pad2d(node, ins, ctx):
    (t, b), (l, r) = node.attrs["padding"]
    return ctx.epilogue(node, jnp.pad(ins[0], ((0, 0), (t, b), (l, r), (0, 0))))


@register_lowering("add")
def _lower_add(node, ins, ctx):
    return ctx.epilogue(node, ins[0] + ins[1])


@register_lowering("mul")
def _lower_mul(node, ins, ctx):
    return ctx.epilogue(node, ins[0] * ins[1])


@register_lowering("concat")
def _lower_concat(node, ins, ctx):
    return ctx.epilogue(node, jnp.concatenate(ins, axis=node.attrs["axis"] + 1))


@register_lowering("reshape")
def _lower_reshape(node, ins, ctx):
    return ctx.epilogue(
        node, ins[0].reshape((ins[0].shape[0],) + tuple(node.attrs["shape"]))
    )


@register_lowering("flatten")
def _lower_flatten(node, ins, ctx):
    return ctx.epilogue(node, ins[0].reshape(ins[0].shape[0], -1))


@register_lowering("softmax")
def _lower_softmax(node, ins, ctx):
    return ctx.epilogue(node, ctx.act("softmax", ins[0], node.attrs))


def _decode_attention_impl(node, ins, ctx, use_pallas: bool, bs=None):
    lengths = ins[3] if len(ins) > 3 else None
    y = decode_attention_op(
        ins[0], ins[1], ins[2], lengths,
        scale=node.attrs.get("scale"),
        fast=ctx.precision == "fast",
        use_pallas=use_pallas,
        bs=bs,
    )
    return ctx.epilogue(node, y)


@register_lowering("decode_attention")
def _lower_decode_attention(node, ins, ctx):
    return _decode_attention_impl(node, ins, ctx, use_pallas=False)


# ---------------------------------------------------------------------------
# Pallas-target overrides: the fused kernels register themselves as
# lowering rules; the kernel selector's per-node decision picks between
# the Pallas kernel and the generic lax path.
# ---------------------------------------------------------------------------
@register_lowering("dense", target="pallas")
def _lower_dense_pallas(node, ins, ctx):
    kernel = ("pallas.fused_matmul_q8"
              if node.attrs.get("quant.mode") == "int8"
              else "pallas.fused_matmul")
    return _dense_impl(node, ins, ctx,
                       use_pallas=ctx.wants(node, kernel),
                       block=ctx.tuned_block(node))


@register_lowering("activation", target="pallas")
def _lower_activation_pallas(node, ins, ctx):
    # Unlike dense (whose Pallas kernel is this target's native path),
    # the fast_act kernel is only used when the selector explicitly
    # picked it — on CPU its interpret mode would lose to the jnp
    # reference, and the reference is the §3.4 semantics either way.
    choice = ctx.selection.get(node.name)
    if (ctx.precision == "fast" and choice is not None
            and choice.kernel == "pallas.fast_act"):
        return ctx.epilogue(node, fast_act(ins[0], node.attrs["fn"],
                                           use_pallas=True,
                                           block=ctx.tuned_block(node)))
    return _lower_activation(node, ins, ctx)


@register_lowering("decode_attention", target="pallas")
def _lower_decode_attention_pallas(node, ins, ctx):
    block = ctx.tuned_block(node)
    return _decode_attention_impl(
        node, ins, ctx,
        use_pallas=ctx.wants(node, "pallas.decode_attention"),
        bs=block[0] if block else None)
