"""SimpleNN — the straightforward oracle interpreter.

The paper ships ``SimpleNN``, "a straightforward, but slow
implementation of neural network inference … written to be as exact in
its calculations as possible, [so] it can be used to benchmark the
compiler in terms of numeric precision" (§3.1).  This is that class:
it walks the *unoptimized* graph node by node with plain ``jnp`` ops,
no fusion, no folding, no approximations.  Every compiler pass and the
whole compiled program are validated against it.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, Node
from .ops_common import apply_activation, lax_padding, pool_padding

# Compat aliases: the padding/activation helpers moved to ops_common so
# the oracle and the lowering registry share one copy.
_activation = apply_activation
_lax_padding = lax_padding
_pool_padding = pool_padding


class SimpleNN:
    """Node-by-node interpreter of a :class:`~repro.core.graph.Graph`.

    Inputs/outputs carry an explicit leading batch dimension.  All image
    tensors are NHWC.
    """

    def __init__(self, graph: Graph):
        self.graph = graph
        self.specs = graph.infer_shapes()
        self._jnp_params = None  # lazy, for the plugin-op fallback only

    def __call__(self, **inputs: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        env: Dict[str, jnp.ndarray] = {}
        for name, spec in self.graph.inputs.items():
            if name not in inputs:
                raise ValueError(f"missing input {name!r}")
            x = jnp.asarray(inputs[name])
            if x.shape[1:] != spec.shape:
                raise ValueError(
                    f"input {name!r}: expected (batch,)+{spec.shape}, got {x.shape}"
                )
            env[name] = x
        # Weights may be rewritten between calls (random_params_like,
        # pass experiments); drop the plugin-fallback memo so plug-in
        # ops see the same live params as the built-in ops.
        self._jnp_params = None
        # The batch size is read off the declared graph inputs once, not
        # inferred from arbitrary env entries mid-walk (which crashes on
        # input-free prefixes and mis-broadcasts rank-1 tensors).
        batch = next(
            (env[n].shape[0] for n in self.graph.inputs if n in env), 1)
        for node in self.graph.toposort():
            env[node.output] = self._eval(node, env, batch)
            # SimpleNN never fuses: if a pass attached an epilogue we
            # still apply it, but as a separate elementwise step.
            if node.epilogue and node.epilogue != "linear":
                env[node.output] = _activation(
                    node.epilogue, env[node.output], node.epilogue_attrs
                )
        return {name: env[name] for name in self.graph.outputs}

    # ------------------------------------------------------------------
    def _eval(self, node: Node, env: Dict[str, jnp.ndarray],
              batch: int = 1) -> jnp.ndarray:
        g = self.graph
        op = node.op
        ins = [env[t] for t in node.inputs]
        if op == "constant":
            # Broadcast the constant over the batch dimension.
            v = jnp.asarray(g.params[node.params["value"]])
            return jnp.broadcast_to(v, (batch,) + v.shape)
        if op == "conv2d":
            k = jnp.asarray(g.params[node.params["kernel"]])
            qm = node.attrs.get("quant.mode")
            if qm == "int8":
                from ..kernels import qmath
                y = qmath.conv2d_q8(
                    ins[0], k, node.attrs["quant.x_scale"],
                    node.attrs["quant.w_scale"],
                    strides=node.attrs["strides"],
                    padding=_lax_padding(node.attrs["padding"]))
            elif qm == "bf16":
                from ..kernels import qmath
                y = qmath.conv2d_bf16(
                    ins[0], k, strides=node.attrs["strides"],
                    padding=_lax_padding(node.attrs["padding"]))
            else:
                y = jax.lax.conv_general_dilated(
                    ins[0],
                    k,
                    window_strides=node.attrs["strides"],
                    padding=_lax_padding(node.attrs["padding"]),
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
            if "bias" in node.params:
                y = y + jnp.asarray(g.params[node.params["bias"]])
            return y
        if op == "depthwise_conv2d":
            k = jnp.asarray(g.params[node.params["kernel"]])  # (kh,kw,c,mult)
            kh, kw, c, mult = k.shape
            y = jax.lax.conv_general_dilated(
                ins[0],
                k.reshape(kh, kw, 1, c * mult),
                window_strides=node.attrs["strides"],
                padding=_lax_padding(node.attrs["padding"]),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=c,
            )
            if "bias" in node.params:
                y = y + jnp.asarray(g.params[node.params["bias"]])
            return y
        if op == "dense":
            k = jnp.asarray(g.params[node.params["kernel"]])
            b = (jnp.asarray(g.params[node.params["bias"]])
                 if "bias" in node.params else None)
            # quant.* annotations change the node's semantics, so even
            # the oracle honors them — through the same shared kernel
            # wrappers the compiled targets use (epilogues still apply
            # separately in __call__; SimpleNN never fuses).
            qm = node.attrs.get("quant.mode")
            if qm == "int8":
                from ..kernels.fused_matmul.ops import fused_matmul_q8
                return fused_matmul_q8(
                    ins[0], k, b,
                    x_scale=node.attrs["quant.x_scale"],
                    w_scales=node.attrs["quant.w_scale"])
            if qm == "bf16":
                from ..kernels.fused_matmul.ops import fused_matmul
                from ..kernels.qmath import bf16_cast_pair
                return fused_matmul(*bf16_cast_pair(ins[0], k), b)
            y = ins[0] @ k
            if b is not None:
                y = y + b
            return y
        if op == "batchnorm":
            gamma = jnp.asarray(g.params[node.params["gamma"]])
            beta = jnp.asarray(g.params[node.params["beta"]])
            mean = jnp.asarray(g.params[node.params["mean"]])
            var = jnp.asarray(g.params[node.params["var"]])
            eps = node.attrs["epsilon"]
            # Deliberately the two-step textbook formula (the paper notes
            # folding changes associativity; the oracle keeps it unfolded).
            return gamma * (ins[0] - mean) / jnp.sqrt(var + eps) + beta
        if op == "activation":
            return _activation(node.attrs["fn"], ins[0], node.attrs)
        if op == "maxpool2d":
            return jax.lax.reduce_window(
                ins[0],
                -jnp.inf,
                jax.lax.max,
                (1,) + tuple(node.attrs["pool_size"]) + (1,),
                (1,) + tuple(node.attrs["strides"]) + (1,),
                _pool_padding(node.attrs["padding"]),
            )
        if op == "avgpool2d":
            ones = jnp.ones_like(ins[0])
            window = (1,) + tuple(node.attrs["pool_size"]) + (1,)
            strides = (1,) + tuple(node.attrs["strides"]) + (1,)
            pad = _pool_padding(node.attrs["padding"])
            s = jax.lax.reduce_window(ins[0], 0.0, jax.lax.add, window, strides, pad)
            n = jax.lax.reduce_window(ones, 0.0, jax.lax.add, window, strides, pad)
            return s / n
        if op == "global_avg_pool":
            return jnp.mean(ins[0], axis=(1, 2))
        if op == "upsample2d":
            f = node.attrs["factor"]
            return jnp.repeat(jnp.repeat(ins[0], f, axis=1), f, axis=2)
        if op == "zero_pad2d":
            (t, b), (l, r) = node.attrs["padding"]
            return jnp.pad(ins[0], ((0, 0), (t, b), (l, r), (0, 0)))
        if op == "add":
            return ins[0] + ins[1]
        if op == "mul":
            return ins[0] * ins[1]
        if op == "concat":
            # attrs axis excludes batch; +1 for the runtime batch dim.
            return jnp.concatenate(ins, axis=node.attrs["axis"] + 1)
        if op == "reshape":
            return ins[0].reshape((ins[0].shape[0],) + tuple(node.attrs["shape"]))
        if op == "flatten":
            return ins[0].reshape(ins[0].shape[0], -1)
        if op == "softmax":
            return jax.nn.softmax(ins[0], axis=node.attrs["axis"])
        if op == "decode_attention":
            from ..kernels.decode_attention import ref as attn_ref
            lengths = ins[3] if len(ins) > 3 else None
            return attn_ref.decode_attention_ref(
                ins[0], ins[1], ins[2], lengths,
                scale=node.attrs.get("scale"))
        # Plug-in ops (register_op + @register_lowering): the oracle
        # falls back to the *generic* lowering rule in exact precision,
        # so one registered rule covers all three targets.  The rule is
        # handed an epilogue-free view of the node — the __call__ loop
        # applies epilogues as a separate step (SimpleNN never fuses),
        # and a rule that calls ctx.epilogue must not apply it twice.
        import dataclasses as _dc

        from .lowering import LoweringContext, get_lowering
        rule = get_lowering(op, None)
        if self._jnp_params is None:
            self._jnp_params = {k: jnp.asarray(v)
                                for k, v in g.params.items()}
        ctx = LoweringContext(params=self._jnp_params, batch_size=batch)
        bare = _dc.replace(node, epilogue=None, epilogue_attrs={})
        return rule(bare, ins, ctx)


def random_params_like(graph: Graph, seed: int = 0) -> None:
    """Fill ``graph.params`` in place with deterministic random values —
    used by tests/benchmarks that build architecture-only graphs."""
    rng = np.random.default_rng(seed)
    for name, value in graph.params.items():
        if name.endswith(("var",)) or "var" in name.split("/")[-1]:
            graph.params[name] = rng.uniform(0.5, 2.0, value.shape).astype(np.float32)
        else:
            graph.params[name] = (rng.standard_normal(value.shape) * 0.1).astype(
                np.float32
            )
