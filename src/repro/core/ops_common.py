"""Op helpers shared by the SimpleNN oracle and the lowering rules.

Exactly one copy of the activation table and the padding-normalization
helpers exists; ``core.simple`` (the oracle) and ``core.lowering`` (the
registry-driven back end) both import from here, so an activation added
for one is automatically exact-checked against the other.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def lax_padding(padding):
    """'same'/'valid' -> lax string form; explicit ((t,b),(l,r)) -> pairs."""
    if isinstance(padding, str):
        return padding.upper()
    (t, b), (l, r) = padding
    return [(t, b), (l, r)]


def pool_padding(padding):
    """Padding for ``reduce_window`` over NHWC: unlike conv, explicit
    padding must name all four dims, not just the spatial pair."""
    p = lax_padding(padding)
    if isinstance(p, str):
        return p
    return [(0, 0), *p, (0, 0)]


def apply_activation(fn: str, x: jnp.ndarray, attrs: Dict) -> jnp.ndarray:
    """The exact activation semantics (oracle and compiled paths alike)."""
    if fn == "linear":
        return x
    if fn == "relu":
        return jnp.maximum(x, 0.0)
    if fn == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if fn == "leaky_relu":
        alpha = attrs.get("alpha", 0.01)
        return jnp.where(x >= 0, x, alpha * x)
    if fn == "sigmoid":
        return jax.nn.sigmoid(x)
    if fn == "tanh":
        return jnp.tanh(x)
    if fn == "elu":
        return jnp.where(x >= 0, x, jnp.expm1(x))
    if fn == "hard_sigmoid":
        return jnp.clip(x * 0.2 + 0.5, 0.0, 1.0)
    if fn == "softmax":
        return jax.nn.softmax(x, axis=attrs.get("axis", -1))
    raise NotImplementedError(fn)


def fast_activation(fn: str, x: jnp.ndarray, attrs: Dict) -> jnp.ndarray:
    """The paper's §3.4 approximations; falls back to exact forms."""
    from ..kernels.fast_act import ref as fast_ref

    if fn == "tanh":
        return fast_ref.cf_tanh(x)
    if fn == "sigmoid":
        return fast_ref.cf_sigmoid(x)
    if fn == "softmax":
        return fast_ref.fast_softmax(x, axis=attrs.get("axis", -1))
    if fn == "elu":
        return jnp.where(x >= 0, x, fast_ref.schraudolph_exp(x) - 1.0)
    return apply_activation(fn, x, attrs)
