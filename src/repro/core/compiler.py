"""CompiledModel — the JAX rendition of the paper's ``CompiledNN``.

``CompiledModel(graph).compile(batch_size)`` runs the optimization
pipeline (repro.core.passes) over the graph IR, then traces the
*optimized* program once and hands it to ``jax.jit`` — the analogue of
CompiledNN emitting machine code via AsmJit at model-load time.  After
compilation, ``apply()`` calls the specialized program; nothing about
the network structure is interpreted at call time (all Python-level
graph walking happens at trace time and is baked into the jaxpr, just
as CompiledNN bakes its graph walk into the instruction stream).

Modes
-----
* ``embed_weights=True`` (paper-faithful, default): weights are closed
  over as constants — XLA sees literal arrays and may constant-fold
  through them.  Right choice for the paper's CNN scale.
* ``embed_weights=False`` (framework mode): weights are a pytree
  argument; the compiled program is reusable across checkpoints and the
  cache key is the structure hash only.
* ``precision='exact'|'fast'``: 'fast' swaps tanh/sigmoid/softmax/exp
  for the paper's approximations (§3.4).
* ``use_pallas``: route dense nodes through the fused-epilogue Pallas
  kernel (TPU target; interpret-mode on CPU — correct but slow, so CPU
  benchmarks default to the identical-semantics jnp path).

The compile cache and compile-time measurement mirror the paper's
Table 1 "Compilation Time" row.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph, Node
from .passes import run_pipeline
from .simple import _activation, _lax_padding
from ..kernels.fast_act import ref as fast_ref
from ..kernels.fused_matmul.ops import fused_matmul


def _fast_activation(fn: str, x: jnp.ndarray, attrs: Dict) -> jnp.ndarray:
    if fn == "tanh":
        return fast_ref.cf_tanh(x)
    if fn == "sigmoid":
        return fast_ref.cf_sigmoid(x)
    if fn == "softmax":
        return fast_ref.fast_softmax(x, axis=attrs.get("axis", -1))
    if fn == "elu":
        return jnp.where(x >= 0, x, fast_ref.schraudolph_exp(x) - 1.0)
    return _activation(fn, x, attrs)


class CompiledModel:
    """Compile a graph IR model into a specialized JAX program."""

    def __init__(
        self,
        graph: Graph,
        *,
        embed_weights: bool = True,
        precision: str = "exact",
        use_pallas: bool = False,
        passes: Optional[Tuple[str, ...]] = None,
    ) -> None:
        assert precision in ("exact", "fast")
        self.source = graph
        self.embed_weights = embed_weights
        self.precision = precision
        self.use_pallas = use_pallas
        t0 = time.perf_counter()
        self.graph, self.report = run_pipeline(graph, passes)
        self._pass_time = time.perf_counter() - t0
        self._cache: Dict[Any, Callable] = {}
        self.compile_time: Optional[float] = None

    # ------------------------------------------------------------------
    def compile(self, batch_size: int = 1) -> Callable:
        """Lower + compile for a given batch size; cached thereafter."""
        key = (batch_size, self.graph.structure_hash(), self.embed_weights,
               self.precision, self.use_pallas)
        if key in self._cache:
            return self._cache[key]
        t0 = time.perf_counter()

        input_names = list(self.graph.inputs)
        params = {k: jnp.asarray(v) for k, v in self.graph.params.items()}

        if self.embed_weights:
            def program(*args):
                env = dict(zip(input_names, args))
                return self._execute(env, params)

            fn = jax.jit(program)
        else:
            def program(param_arg, *args):
                env = dict(zip(input_names, args))
                return self._execute(env, param_arg)

            import functools
            fn = functools.partial(jax.jit(program), params)

        # Trigger actual XLA compilation now (the paper measures
        # model-load + compile as one number).
        specs = [
            jnp.zeros((batch_size,) + self.graph.inputs[n].shape,
                      self.graph.inputs[n].dtype)
            for n in input_names
        ]
        jax.block_until_ready(fn(*specs))
        self.compile_time = (time.perf_counter() - t0) + self._pass_time
        self._cache[key] = fn
        return fn

    def apply(self, **inputs: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        batch = next(iter(inputs.values())).shape[0]
        fn = self.compile(batch)
        args = [jnp.asarray(inputs[n]) for n in self.graph.inputs]
        return fn(*args)

    # ------------------------------------------------------------------
    def _execute(self, env: Dict[str, jnp.ndarray], params) -> Dict[str, jnp.ndarray]:
        """Trace the optimized graph.  Runs once, at jit-trace time."""
        for node in self.graph.toposort():
            env[node.output] = self._emit(node, env, params)
        return {name: env[name] for name in self.graph.outputs}

    def _emit(self, node: Node, env, params) -> jnp.ndarray:
        op = node.op
        ins = [env[t] for t in node.inputs]
        act = (_fast_activation if self.precision == "fast" else _activation)

        def epilogue(y):
            if node.epilogue and node.epilogue != "linear":
                y = act(node.epilogue, y, node.epilogue_attrs)
            pa = node.epilogue_attrs.get("post_affine")
            if pa:
                s, o = params[pa[0]], params[pa[1]]
                y = y * s + o
            return y

        if op == "constant":
            batch = next(iter(env.values())).shape[0] if env else 1
            v = params[node.params["value"]]
            return jnp.broadcast_to(v, (batch,) + v.shape)

        if op == "dense":
            w = params[node.params["kernel"]]
            b = params[node.params["bias"]] if "bias" in node.params else None
            layout = node.attrs.get("kernel_layout", "io")
            pa = node.epilogue_attrs.get("post_affine")
            scale = params[pa[0]] if pa else None
            offset = params[pa[1]] if pa else None
            fn = node.epilogue if node.epilogue not in (None, "linear") else None
            if fn == "softmax":
                fn = None  # handled below (two-pass, not fusable in-kernel)
            y = fused_matmul(
                ins[0], w, b, scale, offset,
                fn=fn,
                fast=self.precision == "fast",
                w_layout=layout,
                use_pallas=self.use_pallas,
                attrs=node.epilogue_attrs,
            )
            if "orig_cout" in node.attrs:
                y = y[..., : node.attrs["orig_cout"]]
            if node.epilogue == "softmax":
                y = act("softmax", y, node.epilogue_attrs)
            return y

        if op == "conv2d":
            k = params[node.params["kernel"]]
            y = jax.lax.conv_general_dilated(
                ins[0], k,
                window_strides=node.attrs["strides"],
                padding=_lax_padding(node.attrs["padding"]),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            if "bias" in node.params:
                y = y + params[node.params["bias"]]
            return epilogue(y)

        if op == "depthwise_conv2d":
            k = params[node.params["kernel"]]
            kh, kw, c, mult = k.shape
            y = jax.lax.conv_general_dilated(
                ins[0], k.reshape(kh, kw, 1, c * mult),
                window_strides=node.attrs["strides"],
                padding=_lax_padding(node.attrs["padding"]),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=c,
            )
            if "bias" in node.params:
                y = y + params[node.params["bias"]]
            return epilogue(y)

        if op == "batchnorm":
            # Unfolded BN survives only when no adjacent foldable layer
            # existed; emit the precomputed affine (scale/offset folded
            # at compile time — cheaper than the 4-param formula).
            gamma = params[node.params["gamma"]]
            beta = params[node.params["beta"]]
            mean = params[node.params["mean"]]
            var = params[node.params["var"]]
            eps = node.attrs["epsilon"]
            s = gamma * jax.lax.rsqrt(var + eps)
            o = beta - s * mean
            return epilogue(ins[0] * s + o)

        if op == "activation":
            return epilogue(act(node.attrs["fn"], ins[0], node.attrs))

        if op == "maxpool2d":
            y = jax.lax.reduce_window(
                ins[0], -jnp.inf, jax.lax.max,
                (1,) + tuple(node.attrs["pool_size"]) + (1,),
                (1,) + tuple(node.attrs["strides"]) + (1,),
                node.attrs["padding"].upper(),
            )
            return epilogue(y)

        if op == "avgpool2d":
            window = (1,) + tuple(node.attrs["pool_size"]) + (1,)
            strides = (1,) + tuple(node.attrs["strides"]) + (1,)
            pad = node.attrs["padding"].upper()
            s = jax.lax.reduce_window(ins[0], 0.0, jax.lax.add, window, strides, pad)
            ones = jnp.ones_like(ins[0])
            nrm = jax.lax.reduce_window(ones, 0.0, jax.lax.add, window, strides, pad)
            return epilogue(s / nrm)

        if op == "global_avg_pool":
            return epilogue(jnp.mean(ins[0], axis=(1, 2)))

        if op == "upsample2d":
            f = node.attrs["factor"]
            return epilogue(jnp.repeat(jnp.repeat(ins[0], f, axis=1), f, axis=2))

        if op == "zero_pad2d":
            (t, b), (l, r) = node.attrs["padding"]
            return epilogue(jnp.pad(ins[0], ((0, 0), (t, b), (l, r), (0, 0))))

        if op == "add":
            return epilogue(ins[0] + ins[1])
        if op == "mul":
            return epilogue(ins[0] * ins[1])
        if op == "concat":
            return epilogue(jnp.concatenate(ins, axis=node.attrs["axis"] + 1))
        if op == "reshape":
            return epilogue(
                ins[0].reshape((ins[0].shape[0],) + tuple(node.attrs["shape"]))
            )
        if op == "softmax":
            return epilogue(act("softmax", ins[0], node.attrs))
        raise NotImplementedError(op)
