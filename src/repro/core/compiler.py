"""CompiledModel — DEPRECATED shim over ``repro.compile``.

The paper's ``CompiledNN::compile`` entry point now lives behind the
unified API::

    import repro
    exe = repro.compile(graph, repro.CompileOptions(
        target="jit", precision="exact", embed_weights=True))

This class survives one deprecation cycle so existing call sites keep
working: it forwards every constructor kwarg into ``CompileOptions``,
delegates to the ``"jit"``/``"pallas"`` targets, and re-exposes the old
attributes (``graph``, ``report``, ``compile_time``).  A single
``DeprecationWarning`` is emitted per process.
"""

from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from .graph import Graph

_warned = False


def _warn_once() -> None:
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(
            "CompiledModel is deprecated; use repro.compile(graph, "
            "repro.CompileOptions(...)) instead",
            DeprecationWarning,
            stacklevel=3,
        )


class CompiledModel:
    """Deprecated: compile a graph IR model via the legacy surface."""

    def __init__(
        self,
        graph: Graph,
        *,
        embed_weights: bool = True,
        precision: str = "exact",
        use_pallas: bool = False,
        passes: Optional[Tuple[str, ...]] = None,
    ) -> None:
        _warn_once()
        from ..api import CompileOptions, compile as api_compile

        self.source = graph
        self.embed_weights = embed_weights
        self.precision = precision
        self.use_pallas = use_pallas
        self._exe = api_compile(
            graph,
            CompileOptions(
                target="pallas" if use_pallas else "jit",
                precision=precision,
                embed_weights=embed_weights,
                passes=passes,
            ),
        )

    # -- legacy attribute surface --------------------------------------
    @property
    def graph(self) -> Graph:
        return self._exe.graph

    @property
    def report(self) -> Dict:
        return self._exe.report

    @property
    def compile_time(self) -> Optional[float]:
        return self._exe.compile_time

    @property
    def executable(self):
        """The new-API executable this shim wraps."""
        return self._exe

    # -- legacy methods ------------------------------------------------
    def compile(self, batch_size: int = 1) -> Callable:
        """Lower + compile for a given batch size; cached thereafter."""
        return self._exe.ensure_compiled(batch_size)

    def apply(self, **inputs: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        return self._exe(**inputs)
