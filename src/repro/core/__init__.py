"""repro.core — the paper's contribution: a model-load-time compiler.

Public API:
    Graph, ModelBuilder         — build/load models (front end, §3.1)
    SimpleNN                    — exact oracle interpreter (§3.1)
    run_pipeline                — the pass pipeline, standalone
    CompiledModel               — DEPRECATED shim; use ``repro.compile``
                                  with ``repro.CompileOptions`` instead

The compilation entry point lives in ``repro.api`` (``repro.compile``);
the shared graph→JAX lowering is ``repro.core.lowering``.
"""

from .graph import (Graph, Node, Signature, TensorSpec, register_op,
                    register_shape_rule)
from .keras_like import ModelBuilder
# The container moved to repro.frontends.container; re-export the live
# implementations (keras_like keeps warn-once shims for old call sites).
from ..frontends.container import load_model, save_model
from .compiler import CompiledModel
from .simple import SimpleNN
from .passes import (run_pipeline, DEFAULT_PIPELINE, PassManager,
                     PassOrderingError, PassVerificationError, register_pass)
from .lowering import (execute_graph, register_lowering, registered_ops,
                       UnsupportedOpError)
from .selection import KernelChoice, select_kernels

__all__ = [
    "Graph", "Node", "TensorSpec", "register_op", "register_shape_rule",
    "ModelBuilder", "load_model", "save_model",
    "CompiledModel", "SimpleNN",
    "run_pipeline", "DEFAULT_PIPELINE",
    "PassManager", "PassOrderingError", "PassVerificationError",
    "register_pass",
    "execute_graph", "register_lowering", "registered_ops",
    "UnsupportedOpError",
    "KernelChoice", "select_kernels",
]
