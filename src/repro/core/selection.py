"""Shape-aware kernel selection — the compile-time cost model.

The paper's thesis is that the compiler should exploit "statically
known properties of the network"; whether a custom kernel beats the
stock lowering is exactly such a property.  This module decides, per
node and **before any code is traced**, which kernel the lowering rules
should emit, from nothing but the inferred shapes, the batch size the
program is being specialized for, and the target:

* ``dense`` under the ``"pallas"`` target uses the fused Pallas matmul
  only when the M/K/N tile picture makes sense — the block working set
  must fit VMEM and the MXU-granule padding waste must stay bounded.  A
  batch-1 GEMV against a 32×2 head pads 256× and is *still* worth
  fusing (the whole weight rides one MXU pass); a degenerate
  sub-granule matmul (1×1 "dense" = a scalar multiply) pads ~16000×
  and loses to XLA's scalar code, so it falls back to lax.
* ``activation`` under ``"pallas"`` + ``precision="fast"`` uses the
  Pallas fast-act kernel only on a real TPU with a lane-aligned minor
  dim; anywhere else the jnp reference (identical §3.4 math) wins.
* ``decode_attention`` under ``"pallas"`` requires the head dim to be a
  multiple of the 128-lane tile; otherwise the jnp reference lowers it.

Decisions are returned as :class:`KernelChoice` records (kernel + the
reason, human-readable) and surfaced through
``Executable.cost_summary()["kernel_selection"]`` so "why didn't my
layer use the fused kernel?" is answerable without a debugger.

These heuristics are the *prior*: with ``CompileOptions(autotune=
"cached"|"full")`` the profile-guided tuner (:mod:`repro.autotune`)
overrides individual choices with micro-benchmarked winners
(``source="measured"``, tuned block geometry attached); with the
default ``autotune="off"`` the decisions below are final and
bit-identical to the pre-autotuner selector.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from .graph import Graph
from ..kernels.tiles import (LANE, VMEM_BUDGET_BYTES, block_vmem_bytes,
                             ceil_to, pick_block, sublane_for)

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())

#: Padded-MACs / logical-MACs bound for the fused matmul (lane/sublane
#: granule waste).  The table-1 suite's smallest head (32×2, batch 1)
#: wastes 256× and measurably still wins fused; a sub-granule scalar op
#: (1×1) wastes ~16k× and does not.
MAX_PAD_WASTE = 1024.0


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """One selector decision, as shown in ``cost_summary()``.

    ``source`` records whether the decision is the static heuristic's
    prior or a micro-benchmarked winner from :mod:`repro.autotune`;
    measured choices also carry the winning ``block`` geometry (honored
    by the Pallas lowering rules instead of recomputing ``pick_block``)
    and the per-candidate ``measured_us`` table.
    """

    node: str
    op: str
    kernel: str   # e.g. "pallas.fused_matmul", "lax.dot", "jnp.ref"
    reason: str
    source: str = "heuristic"          # "heuristic" | "measured"
    block: Optional[Tuple[int, ...]] = None
    measured_us: Optional[Dict[str, float]] = None

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _select_dense(node, in_spec, batch_size: int, n: int) -> KernelChoice:
    rows = max(1, in_spec.size // max(1, in_spec.shape[-1]))
    m = batch_size * rows
    k = in_spec.shape[-1]
    # Granules and the VMEM working set are dtype-parametrized: bf16
    # packs twice the elements per byte, so its sublane granule doubles
    # and its K-dim block cap grows instead of idling half the budget.
    # A quant.* annotation overrides the tensor dtype — the kernel will
    # consume int8 (itemsize 1) or bf16 (2) operands regardless of what
    # flows in as f32.
    qm = node.attrs.get("quant.mode")
    itemsize = {"int8": 1, "bf16": 2}.get(
        qm, int(np.dtype(in_spec.dtype).itemsize))
    if qm == "int8" and not _ON_TPU:
        # Backend-aware prior: off-TPU the Pallas q8 kernel only runs in
        # interpret mode, while the reference lax int8 lowering compiles
        # to real vectorized code — the measured winner by a wide margin.
        return KernelChoice(
            node.name, "dense", "lax.dot",
            "int8 site off-TPU: reference lax int8 lowering beats "
            "interpret-mode Pallas")
    sub = sublane_for(itemsize)
    m_pad, k_pad, n_pad = ceil_to(m, sub), ceil_to(k, LANE), ceil_to(n, LANE)

    bm, bk, bn = pick_block(m, k, n, itemsize)
    # VMEM legality: with today's pick_block caps the working set always
    # fits; this check is what *keeps* that true if the block geometry
    # in kernels/tiles.py is ever retuned upward.
    vmem = block_vmem_bytes(bm, bk, bn, itemsize)
    if vmem > VMEM_BUDGET_BYTES:
        return KernelChoice(
            node.name, "dense", "lax.dot",
            f"block working set {vmem // 1024} KiB exceeds VMEM budget "
            f"{VMEM_BUDGET_BYTES // 1024} KiB (M={m} K={k} N={n})")
    waste = (m_pad * k_pad * n_pad) / float(m_pad * k * n)
    if waste > MAX_PAD_WASTE:
        return KernelChoice(
            node.name, "dense", "lax.dot",
            f"sub-granule matmul: lane padding wastes {waste:.0f}x "
            f"(> {MAX_PAD_WASTE:.0f}x) at M={m} K={k} N={n}")
    kernel = ("pallas.fused_matmul_q8" if qm == "int8"
              else "pallas.fused_matmul")
    return KernelChoice(
        node.name, "dense", kernel,
        f"M={m} K={k} N={n} tiles to ({bm},{bk},{bn}), "
        f"{vmem // 1024} KiB VMEM, {waste:.1f}x pad waste",
        block=(bm, bk, bn))


def _select_activation(node, in_spec, precision: str) -> KernelChoice:
    fn = node.attrs["fn"]
    if precision != "fast":
        return KernelChoice(node.name, "activation", "jnp.act",
                            "exact precision: stock activation")
    if fn not in ("tanh", "sigmoid"):
        return KernelChoice(node.name, "activation", "jnp.act",
                            f"fast {fn} has no Pallas kernel form")
    if not _ON_TPU:
        return KernelChoice(
            node.name, "activation", "jnp.act",
            "no TPU: interpret-mode Pallas loses to the jnp reference")
    if in_spec.shape and in_spec.shape[-1] % LANE == 0:
        return KernelChoice(node.name, "activation", "pallas.fast_act",
                            f"minor dim {in_spec.shape[-1]} is lane-aligned")
    minor = in_spec.shape[-1] if in_spec.shape else 1
    return KernelChoice(
        node.name, "activation", "jnp.act",
        f"minor dim {minor} not a multiple of {LANE} lanes")


def _select_decode_attention(node, q_spec) -> KernelChoice:
    h, d = q_spec.shape
    if d % LANE:
        return KernelChoice(
            node.name, "decode_attention", "jnp.ref",
            f"head dim {d} not a multiple of the {LANE}-lane tile")
    return KernelChoice(
        node.name, "decode_attention", "pallas.decode_attention",
        f"H={h} D={d}: online-softmax Pallas kernel")


def select_kernels(
    graph: Graph,
    *,
    batch_size: int,
    target: Optional[str],
    precision: str = "exact",
) -> Dict[str, KernelChoice]:
    """The static selection for one (graph, batch_size, target)
    compilation.  Only ops with a kernel decision to make appear in the
    result; everything else lowers through its generic rule."""
    if target != "pallas":
        return {}
    specs = graph.infer_shapes()
    choices: Dict[str, KernelChoice] = {}
    for node in graph.nodes:
        in_spec = specs[node.inputs[0]] if node.inputs else None
        if node.op == "dense":
            # Logical N: the pre-padding width if the layout pass padded,
            # else the kernel's output dim under its recorded layout.
            kshape = graph.params[node.params["kernel"]].shape
            cout = (kshape[0] if node.attrs.get("kernel_layout") == "oi"
                    else kshape[-1])
            n = int(node.attrs.get("orig_cout", cout))
            choices[node.name] = _select_dense(node, in_spec, batch_size, n)
        elif node.op == "activation":
            choices[node.name] = _select_activation(node, in_spec, precision)
        elif node.op == "decode_attention":
            choices[node.name] = _select_decode_attention(node, in_spec)
    return choices
