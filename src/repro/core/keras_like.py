"""Keras-style sequential/functional model builder over the graph IR.

Plays the role of the paper's front end ("the Model class allows to load
a network … as written by the Python library Keras").  There is no HDF5
in this environment, so instead of a file loader this is a programmatic
builder with the same layer vocabulary; ``save``/``load`` round-trip the
graph through an ``.npz`` + JSON container so the "load a pretrained
model at runtime, then compile" flow of the paper is preserved.
"""

from __future__ import annotations

import json
from typing import Optional, Sequence, Tuple

import numpy as np

from .graph import Graph


class ModelBuilder:
    """Functional builder: each method appends a node and returns the
    output tensor name, so models compose like Keras' functional API."""

    def __init__(self) -> None:
        self.graph = Graph()
        self._n = 0
        self._rng = np.random.default_rng(0)

    def seed(self, seed: int) -> "ModelBuilder":
        self._rng = np.random.default_rng(seed)
        return self

    def _name(self, kind: str) -> str:
        self._n += 1
        return f"{kind}_{self._n}"

    def _init(self, shape: Tuple[int, ...], fan_in: int) -> np.ndarray:
        scale = np.sqrt(2.0 / max(1, fan_in))
        return (self._rng.standard_normal(shape) * scale).astype(np.float32)

    # -- layers ---------------------------------------------------------
    def input(self, shape: Sequence[int], name: str = "input",
              dtype: str = "float32") -> str:
        return self.graph.add_input(name, shape, dtype)

    def conv2d(self, x: str, filters: int, kernel_size: Tuple[int, int],
               strides=(1, 1), padding="same", use_bias=True,
               activation: Optional[str] = None) -> str:
        name = self._name("conv2d")
        cin = self.graph.infer_shapes()[x].shape[-1]
        k = self._init(kernel_size + (cin, filters), cin * kernel_size[0] * kernel_size[1])
        params = {"kernel": self.graph.add_param(f"{name}/kernel", k)}
        if use_bias:
            params["bias"] = self.graph.add_param(
                f"{name}/bias", np.zeros(filters, np.float32))
        out = self.graph.add_node("conv2d", name, [x],
                                  attrs={"strides": tuple(strides), "padding": padding},
                                  params=params)
        return self.activation(out, activation) if activation else out

    def depthwise_conv2d(self, x: str, kernel_size: Tuple[int, int],
                         strides=(1, 1), padding="same", mult: int = 1,
                         use_bias=True, activation: Optional[str] = None) -> str:
        name = self._name("dwconv2d")
        c = self.graph.infer_shapes()[x].shape[-1]
        k = self._init(kernel_size + (c, mult), kernel_size[0] * kernel_size[1])
        params = {"kernel": self.graph.add_param(f"{name}/kernel", k)}
        if use_bias:
            params["bias"] = self.graph.add_param(
                f"{name}/bias", np.zeros(c * mult, np.float32))
        out = self.graph.add_node("depthwise_conv2d", name, [x],
                                  attrs={"strides": tuple(strides), "padding": padding},
                                  params=params)
        return self.activation(out, activation) if activation else out

    def dense(self, x: str, units: int, use_bias=True,
              activation: Optional[str] = None) -> str:
        name = self._name("dense")
        cin = self.graph.infer_shapes()[x].shape[-1]
        params = {"kernel": self.graph.add_param(
            f"{name}/kernel", self._init((cin, units), cin))}
        if use_bias:
            params["bias"] = self.graph.add_param(
                f"{name}/bias", np.zeros(units, np.float32))
        out = self.graph.add_node("dense", name, [x], params=params)
        return self.activation(out, activation) if activation else out

    def batchnorm(self, x: str, epsilon: float = 1e-3) -> str:
        name = self._name("bn")
        c = self.graph.infer_shapes()[x].shape[-1]
        params = {
            "gamma": self.graph.add_param(
                f"{name}/gamma", self._rng.uniform(0.5, 1.5, c).astype(np.float32)),
            "beta": self.graph.add_param(
                f"{name}/beta", (self._rng.standard_normal(c) * 0.1).astype(np.float32)),
            "mean": self.graph.add_param(
                f"{name}/mean", (self._rng.standard_normal(c) * 0.1).astype(np.float32)),
            "var": self.graph.add_param(
                f"{name}/var", self._rng.uniform(0.5, 2.0, c).astype(np.float32)),
        }
        return self.graph.add_node("batchnorm", name, [x],
                                   attrs={"epsilon": epsilon}, params=params)

    def activation(self, x: str, fn: str, **attrs) -> str:
        name = self._name(f"act_{fn}")
        return self.graph.add_node("activation", name, [x],
                                   attrs={"fn": fn, **attrs})

    def maxpool(self, x: str, pool_size=(2, 2), strides=None, padding="valid") -> str:
        name = self._name("maxpool")
        return self.graph.add_node(
            "maxpool2d", name, [x],
            attrs={"pool_size": tuple(pool_size),
                   "strides": tuple(strides or pool_size), "padding": padding})

    def avgpool(self, x: str, pool_size=(2, 2), strides=None, padding="valid") -> str:
        name = self._name("avgpool")
        return self.graph.add_node(
            "avgpool2d", name, [x],
            attrs={"pool_size": tuple(pool_size),
                   "strides": tuple(strides or pool_size), "padding": padding})

    def global_avg_pool(self, x: str) -> str:
        return self.graph.add_node("global_avg_pool", self._name("gap"), [x])

    def upsample(self, x: str, factor: int = 2) -> str:
        return self.graph.add_node("upsample2d", self._name("up"), [x],
                                   attrs={"factor": factor})

    def zero_pad(self, x: str, padding=((1, 1), (1, 1))) -> str:
        return self.graph.add_node("zero_pad2d", self._name("pad"), [x],
                                   attrs={"padding": tuple(map(tuple, padding))})

    def add(self, a: str, b: str) -> str:
        return self.graph.add_node("add", self._name("add"), [a, b])

    def concat(self, xs: Sequence[str], axis: int = -1) -> str:
        specs = self.graph.infer_shapes()
        rank = len(specs[xs[0]].shape)
        axis = axis % rank
        return self.graph.add_node("concat", self._name("concat"), list(xs),
                                   attrs={"axis": axis})

    def flatten(self, x: str) -> str:
        return self.graph.add_node("flatten", self._name("flatten"), [x])

    def softmax(self, x: str, axis: int = -1) -> str:
        return self.graph.add_node("softmax", self._name("softmax"), [x],
                                   attrs={"axis": axis})

    def decode_attention(self, q: str, k_cache: str, v_cache: str,
                         lengths: Optional[str] = None,
                         scale: Optional[float] = None) -> str:
        """Single-token GQA decode attention over a KV cache.  ``q`` is
        (H, D); caches are (S, Hkv, D); optional ``lengths`` is a scalar
        int32 input of per-example valid context lengths."""
        ins = [q, k_cache, v_cache] + ([lengths] if lengths else [])
        attrs = {} if scale is None else {"scale": float(scale)}
        return self.graph.add_node("decode_attention", self._name("attn"),
                                   ins, attrs=attrs)

    def build(self, outputs: Sequence[str]) -> Graph:
        self.graph.set_outputs(list(outputs))
        return self.graph


# ---------------------------------------------------------------------------
def save_model(graph: Graph, path: str) -> None:
    """Serialize graph + weights (.npz with an embedded JSON header) —
    the stand-in for the paper's Keras-HDF5 container."""
    header = {
        "inputs": {k: {"shape": v.shape, "dtype": v.dtype}
                   for k, v in graph.inputs.items()},
        "outputs": graph.outputs,
        "nodes": [
            {"op": n.op, "name": n.name, "inputs": n.inputs, "output": n.output,
             "attrs": _jsonify(n.attrs), "params": n.params,
             "epilogue": n.epilogue, "epilogue_attrs": _jsonify(n.epilogue_attrs)}
            for n in graph.nodes
        ],
    }
    arrays = {f"param::{k}": v for k, v in graph.params.items()}
    arrays["__header__"] = np.frombuffer(
        json.dumps(header).encode(), dtype=np.uint8)
    np.savez(path, **arrays)


def load_model(path: str) -> Graph:
    data = np.load(path, allow_pickle=False)
    header = json.loads(bytes(data["__header__"]).decode())
    g = Graph()
    for name, spec in header["inputs"].items():
        g.add_input(name, spec["shape"], spec["dtype"])
    for k in data.files:
        if k.startswith("param::"):
            g.add_param(k[len("param::"):], data[k])
    for nd in header["nodes"]:
        from .graph import Node
        node = Node(op=nd["op"], name=nd["name"], inputs=nd["inputs"],
                    output=nd["output"], attrs=_tuplify(nd["attrs"]),
                    params=nd["params"], epilogue=nd["epilogue"],
                    epilogue_attrs=_tuplify(nd["epilogue_attrs"]))
        g.nodes.append(node)
    g.rebuild_index()
    g.set_outputs(header["outputs"])
    return g


def _jsonify(obj):
    if isinstance(obj, dict):
        return {k: _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (tuple, list)):
        return [_jsonify(v) for v in obj]
    return obj


def _tuplify(obj):
    """JSON round-trips tuples as lists; the IR uses tuples for shapes
    and paddings, so convert lists (recursively) back to tuples."""
    if isinstance(obj, dict):
        return {k: _tuplify(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return tuple(_tuplify(v) for v in obj)
    return obj
