"""Keras-style sequential/functional model builder over the graph IR.

Plays the role of the paper's front end ("the Model class allows to load
a network … as written by the Python library Keras").  There is no HDF5
in this environment, so instead of a file loader this is a programmatic
builder with the same layer vocabulary; ``save``/``load`` round-trip the
graph through an ``.npz`` + JSON container so the "load a pretrained
model at runtime, then compile" flow of the paper is preserved.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple

import numpy as np

from .graph import Graph


class ModelBuilder:
    """Functional builder: each method appends a node and returns the
    output tensor name, so models compose like Keras' functional API."""

    def __init__(self) -> None:
        self.graph = Graph()
        self._n = 0
        self._rng = np.random.default_rng(0)

    def seed(self, seed: int) -> "ModelBuilder":
        self._rng = np.random.default_rng(seed)
        return self

    def _name(self, kind: str) -> str:
        self._n += 1
        return f"{kind}_{self._n}"

    def _init(self, shape: Tuple[int, ...], fan_in: int) -> np.ndarray:
        scale = np.sqrt(2.0 / max(1, fan_in))
        return (self._rng.standard_normal(shape) * scale).astype(np.float32)

    # -- layers ---------------------------------------------------------
    def input(self, shape: Sequence[int], name: str = "input",
              dtype: str = "float32") -> str:
        return self.graph.add_input(name, shape, dtype)

    def conv2d(self, x: str, filters: int, kernel_size: Tuple[int, int],
               strides=(1, 1), padding="same", use_bias=True,
               activation: Optional[str] = None) -> str:
        name = self._name("conv2d")
        cin = self.graph.spec(x).shape[-1]
        k = self._init(kernel_size + (cin, filters), cin * kernel_size[0] * kernel_size[1])
        params = {"kernel": self.graph.add_param(f"{name}/kernel", k)}
        if use_bias:
            params["bias"] = self.graph.add_param(
                f"{name}/bias", np.zeros(filters, np.float32))
        out = self.graph.add_node("conv2d", name, [x],
                                  attrs={"strides": tuple(strides), "padding": padding},
                                  params=params)
        return self.activation(out, activation) if activation else out

    def depthwise_conv2d(self, x: str, kernel_size: Tuple[int, int],
                         strides=(1, 1), padding="same", mult: int = 1,
                         use_bias=True, activation: Optional[str] = None) -> str:
        name = self._name("dwconv2d")
        c = self.graph.spec(x).shape[-1]
        k = self._init(kernel_size + (c, mult), kernel_size[0] * kernel_size[1])
        params = {"kernel": self.graph.add_param(f"{name}/kernel", k)}
        if use_bias:
            params["bias"] = self.graph.add_param(
                f"{name}/bias", np.zeros(c * mult, np.float32))
        out = self.graph.add_node("depthwise_conv2d", name, [x],
                                  attrs={"strides": tuple(strides), "padding": padding},
                                  params=params)
        return self.activation(out, activation) if activation else out

    def dense(self, x: str, units: int, use_bias=True,
              activation: Optional[str] = None) -> str:
        name = self._name("dense")
        cin = self.graph.spec(x).shape[-1]
        params = {"kernel": self.graph.add_param(
            f"{name}/kernel", self._init((cin, units), cin))}
        if use_bias:
            params["bias"] = self.graph.add_param(
                f"{name}/bias", np.zeros(units, np.float32))
        out = self.graph.add_node("dense", name, [x], params=params)
        return self.activation(out, activation) if activation else out

    def batchnorm(self, x: str, epsilon: float = 1e-3) -> str:
        name = self._name("bn")
        c = self.graph.spec(x).shape[-1]
        params = {
            "gamma": self.graph.add_param(
                f"{name}/gamma", self._rng.uniform(0.5, 1.5, c).astype(np.float32)),
            "beta": self.graph.add_param(
                f"{name}/beta", (self._rng.standard_normal(c) * 0.1).astype(np.float32)),
            "mean": self.graph.add_param(
                f"{name}/mean", (self._rng.standard_normal(c) * 0.1).astype(np.float32)),
            "var": self.graph.add_param(
                f"{name}/var", self._rng.uniform(0.5, 2.0, c).astype(np.float32)),
        }
        return self.graph.add_node("batchnorm", name, [x],
                                   attrs={"epsilon": epsilon}, params=params)

    def activation(self, x: str, fn: str, **attrs) -> str:
        name = self._name(f"act_{fn}")
        return self.graph.add_node("activation", name, [x],
                                   attrs={"fn": fn, **attrs})

    def maxpool(self, x: str, pool_size=(2, 2), strides=None, padding="valid") -> str:
        name = self._name("maxpool")
        return self.graph.add_node(
            "maxpool2d", name, [x],
            attrs={"pool_size": tuple(pool_size),
                   "strides": tuple(strides or pool_size), "padding": padding})

    def avgpool(self, x: str, pool_size=(2, 2), strides=None, padding="valid") -> str:
        name = self._name("avgpool")
        return self.graph.add_node(
            "avgpool2d", name, [x],
            attrs={"pool_size": tuple(pool_size),
                   "strides": tuple(strides or pool_size), "padding": padding})

    def global_avg_pool(self, x: str) -> str:
        return self.graph.add_node("global_avg_pool", self._name("gap"), [x])

    def upsample(self, x: str, factor: int = 2) -> str:
        return self.graph.add_node("upsample2d", self._name("up"), [x],
                                   attrs={"factor": factor})

    def zero_pad(self, x: str, padding=((1, 1), (1, 1))) -> str:
        return self.graph.add_node("zero_pad2d", self._name("pad"), [x],
                                   attrs={"padding": tuple(map(tuple, padding))})

    def add(self, a: str, b: str) -> str:
        return self.graph.add_node("add", self._name("add"), [a, b])

    def concat(self, xs: Sequence[str], axis: int = -1) -> str:
        rank = len(self.graph.spec(xs[0]).shape)
        axis = axis % rank
        return self.graph.add_node("concat", self._name("concat"), list(xs),
                                   attrs={"axis": axis})

    def flatten(self, x: str) -> str:
        return self.graph.add_node("flatten", self._name("flatten"), [x])

    def softmax(self, x: str, axis: int = -1) -> str:
        return self.graph.add_node("softmax", self._name("softmax"), [x],
                                   attrs={"axis": axis})

    def decode_attention(self, q: str, k_cache: str, v_cache: str,
                         lengths: Optional[str] = None,
                         scale: Optional[float] = None) -> str:
        """Single-token GQA decode attention over a KV cache.  ``q`` is
        (H, D); caches are (S, Hkv, D); optional ``lengths`` is a scalar
        int32 input of per-example valid context lengths."""
        ins = [q, k_cache, v_cache] + ([lengths] if lengths else [])
        attrs = {} if scale is None else {"scale": float(scale)}
        return self.graph.add_node("decode_attention", self._name("attn"),
                                   ins, attrs=attrs)

    def build(self, outputs) -> Graph:
        """Finalize the graph.  ``outputs`` is a sequence of tensor
        names, or a mapping of *public output name -> tensor name* for
        user-chosen multi-output signatures."""
        self.graph.set_outputs(
            dict(outputs) if isinstance(outputs, dict) else list(outputs))
        return self.graph


# ---------------------------------------------------------------------------
# The .npz+JSON container moved to repro.frontends.container; these
# shims keep old imports working (once-per-process DeprecationWarning).
_warned = False


def _warn_once() -> None:
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(
            "repro.core.keras_like.save_model/load_model moved to "
            "repro.frontends.container (and repro.compile('model.npz') "
            "now loads containers directly via the 'container' frontend)",
            DeprecationWarning, stacklevel=3)


def save_model(graph: Graph, path) -> None:
    """DEPRECATED shim: use :func:`repro.frontends.container.save_model`."""
    _warn_once()
    from ..frontends.container import save_model as _save
    _save(graph, path)


def load_model(path) -> Graph:
    """DEPRECATED shim: use :func:`repro.frontends.container.load_model`."""
    _warn_once()
    from ..frontends.container import load_model as _load
    return _load(path)


def _jsonify(obj):
    from ..frontends.container import _jsonify as _j
    return _j(obj)


def _tuplify(obj):
    from ..frontends.container import _tuplify as _t
    return _t(obj)
