"""Compile-time weight re-layout (paper §3.3, Eq. 3 — adapted to TPU).

The paper's observation: "the elements of the matrix are parameters of
the neural network known at compile time, so the memory layout of the
matrix can be chosen arbitrarily without any impact on performance".  On
x86 it chooses a diagonal-rotated layout to save one XMM register and a
shuffle.  On TPU the register-file argument does not exist; the two
layout degrees of freedom that matter are

1. **Contraction-major storage** — for GEMV-shaped products (matrix ×
   single vector, the dominant op in both the paper's CNNs and LLM
   decode) the weight should be stored so the contraction dimension is
   minor-most, letting the kernel stream HBM contiguously instead of
   striding.  We store dense kernels as (cout, cin) ["oi"] when the
   expected activation rows are small, (cin, cout) ["io"] otherwise.

2. **MXU-aligned padding** — the systolic array processes 128×128
   tiles (8×128 for f32 sublanes); weights whose channel dims are not
   multiples of the tile get padded *once at compile time* instead of
   per-call.  The back end slices the output back to the logical size.

Both transformations are free at runtime precisely because of the
paper's insight: weights are constants, their layout is ours to choose.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..graph import Graph
from ...kernels.tiles import LANE, SUBLANE, ceil_to
from .manager import register_pass

#: Channel alignment for the MXU lane dimension.
LANE_ALIGN = LANE
#: Sublane alignment for f32.
SUBLANE_ALIGN = SUBLANE
#: Pad only if the relative overhead stays below this bound — padding a
#: 3-channel tensor to 128 would be a 42x blowup, which no sane compiler
#: does.  (CompiledNN similarly specializes per-dimension-case instead
#: of forcing one scheme.)
MAX_PAD_RATIO = 1.5

#: Tuning-site hook: the graph-level autotuner
#: (``repro.autotune.decisions``) sets this attr on a dense node to pin
#: the kernel storage layout to ``"oi"`` or ``"io"``, overriding the
#: row-count heuristic below.  Absent keeps the heuristic, so
#: ``autotune="off"`` is bit-identical.  An explicit user
#: ``kernel_layout`` attr still wins over both.
TUNE_LAYOUT_ATTR = "tune.layout"


_pad_to = ceil_to


@register_pass("optimize_layout", after=("fold_batchnorm",))
def optimize_layout(graph: Graph) -> Tuple[Graph, Dict]:
    g = graph.copy()
    specs = g.infer_shapes()
    transposed = 0
    padded = 0
    for node in g.nodes:
        if node.op != "dense":
            continue
        k = g.params[node.params["kernel"]]
        cin, cout = k.shape
        in_spec = specs[node.inputs[0]]
        # Rows the matmul will see per example (product of non-channel dims).
        rows = max(1, in_spec.size // max(1, in_spec.shape[-1]))

        # 1. contraction-major storage for GEMV-shaped products.
        tuned = node.attrs.get(TUNE_LAYOUT_ATTR)
        want_oi = (tuned == "oi") if tuned in ("oi", "io") else (
            rows < SUBLANE_ALIGN)
        if want_oi and node.attrs.get("kernel_layout") is None:
            g.params[node.params["kernel"]] = np.ascontiguousarray(k.T)
            node.attrs["kernel_layout"] = "oi"
            transposed += 1
            k = g.params[node.params["kernel"]]
        else:
            node.attrs.setdefault("kernel_layout", "io")

        # 2. MXU-aligned output padding (compile-time, sliced by back end).
        pad_cout = _pad_to(cout, LANE_ALIGN)
        if pad_cout != cout and pad_cout / cout <= MAX_PAD_RATIO:
            if node.attrs["kernel_layout"] == "oi":
                knew = np.zeros((pad_cout, cin), np.float32)
                knew[:cout] = k
            else:
                knew = np.zeros((cin, pad_cout), np.float32)
                knew[:, :cout] = k
            g.params[node.params["kernel"]] = knew
            if "bias" in node.params:
                b = g.params[node.params["bias"]]
                bnew = np.zeros((pad_cout,), np.float32)
                bnew[:cout] = b
                g.params[node.params["bias"]] = bnew
            # A folded-BN affine epilogue rides on the same channel dim.
            pa = node.epilogue_attrs.get("post_affine")
            if pa:
                for pname in pa:
                    v = g.params[pname]
                    vnew = np.zeros((pad_cout,), np.float32)
                    vnew[: v.shape[0]] = v
                    g.params[pname] = vnew
            node.attrs["orig_cout"] = cout
            padded += 1
    g.rebuild_index()
    return g, {"transposed": transposed, "padded": padded}
