"""Constant folding: any node all of whose inputs are graph params (or
constants) is evaluated at compile time and replaced by a new param.

The paper's compiler does this implicitly (everything weight-derived is
baked into the emitted code); in the IR it is an explicit pass so the
report can show what got precomputed.
"""

from __future__ import annotations

from typing import Dict, Set, Tuple

import numpy as np

from ..graph import Graph
from ..simple import SimpleNN
from .manager import register_pass


@register_pass("fold_constants", after=("canonicalize",))
def fold_constants(graph: Graph) -> Tuple[Graph, Dict]:
    g = graph.copy()
    # Tensors that are compile-time constants: params referenced via
    # ``constant`` nodes.  (Graph inputs are runtime values.)
    const_tensors: Set[str] = set()
    for node in g.nodes:
        if node.op == "constant":
            const_tensors.add(node.output)

    if not const_tensors:
        return g, {"folded": 0}

    folded = 0
    changed = True
    while changed:
        changed = False
        for node in list(g.nodes):
            if node.op == "constant":
                continue
            if node.inputs and all(t in const_tensors for t in node.inputs):
                # Evaluate this node at compile time via the oracle on a
                # single-node graph.
                sub = Graph()
                sub.params = g.params
                for t in node.inputs:
                    prod = g.producer(t)
                    sub.add_input(t, g.params[prod.params["value"]].shape)
                sub.nodes = [node]
                sub.rebuild_index()
                sub.set_outputs([node.output])
                oracle = SimpleNN(sub)
                feeds = {
                    t: np.asarray(g.params[g.producer(t).params["value"]])[None]
                    for t in node.inputs
                }
                value = np.asarray(oracle(**feeds)[node.output])[0]
                pname = f"{node.name}/folded"
                g.params[pname] = value.astype(np.float32)
                node.op = "constant"
                node.inputs = []
                node.params = {"value": pname}
                node.attrs = {}
                const_tensors.add(node.output)
                folded += 1
                changed = True
    g.rebuild_index()
    return g, {"folded": folded}
