"""Compiler passes over the graph IR — a registry, not a list.

Each pass is a pure function ``Graph -> (Graph, stats)`` that registers
itself with ordering constraints::

    @register_pass("fuse_activation", after=("canonicalize",),
                   before=("fold_batchnorm",))
    def fuse_activation(graph): ...

:class:`PassManager` resolves the constraints into a pipeline, re-runs
shape inference as a verifier after every pass, and records per-pass
timings and node deltas in the compile report (see ``manager.py``).

The default pipeline mirrors the paper's intermediate processing
(§3.2/§3.5):

1. canonicalize           — normalize ops (flatten→reshape, lone softmax→activation)
2. fold_constants         — precompute weight-only subgraphs
3. fuse_pad               — merge zero_pad2d into the following conv
4. fuse_activation        — activations become epilogues of producers (§3.4)
5. fold_batchnorm         — BN folded into adjacent conv/dense (§3.5)
6. fuse_activation.post_bn — rerun: BN removal exposes new conv→act pairs
7. quantize               — calibration-driven int8/bf16 annotation
                            (reads the request on ``graph.quant``;
                            no-op without one)
8. optimize_layout        — compile-time weight re-layout (Eq. 3 analogue) (§3.3)
9. propagate_sharding     — per-tensor PartitionSpecs + collectives
                            (repro.dist); no-op without a mesh

followed by ``plan_memory`` (lifetime analysis + arena assignment,
§3.2), which is an analysis over the final graph rather than a rewrite,
so the manager runs it as the pipeline finalizer.

``run_pipeline(graph, passes)`` remains as the functional wrapper every
call site uses; ``passes=None`` means the resolved default pipeline.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from ..graph import Graph
from .manager import (PassManager, PassOrderingError, PassVerificationError,
                      pipeline_candidates, register_pass, registered_passes,
                      resolve_order, unregister_pass)

# Importing a pass module registers it; import order is the tie-break
# order for constraint resolution.
from .canonicalize import canonicalize
from .fold_constants import fold_constants
from .fuse_pad import fuse_pad
from .fuse_activation import fuse_activation
from .fold_batchnorm import fold_batchnorm
from .layout import optimize_layout
from .memory_plan import MemoryPlan, plan_memory

# Second instance of activation fusion, scheduled after BN folding (the
# same function object; ``.post_bn`` marks the instance, the base name
# stays "fuse_activation" so ablations remove both at once).
register_pass("fuse_activation.post_bn", after=("fold_batchnorm",),
              before=("optimize_layout",))(fuse_activation)

# Quantization reads the request on ``graph.quant`` and must calibrate
# against the fully fused/folded weights, so it registers between the
# post-BN fusion rerun and layout (imported here, after the
# ``fuse_activation.post_bn`` instance it orders against exists).
from .quantize import quantize

# Distribution: resolve per-tensor shardings + insert collectives
# (repro.dist) on the final optimized graph; a no-op without a mesh.
from .sharding import propagate_sharding

#: The resolved default pipeline (instance names, in execution order).
DEFAULT_PIPELINE: Tuple[str, ...] = resolve_order()


def run_pipeline(
    graph: Graph,
    passes: Optional[Sequence[str]] = None,
    *,
    verify: bool = True,
    dump_ir=None,
) -> Tuple[Graph, Dict]:
    """Run the pass pipeline; returns the optimized graph and a report
    with per-pass statistics plus the memory plan.

    ``passes=None`` runs the registry-resolved default; an explicit
    sequence of names runs exactly those, in that order.
    """
    return PassManager(passes, verify=verify, dump_ir=dump_ir).run(graph)


__all__ = [
    "run_pipeline",
    "DEFAULT_PIPELINE",
    "PassManager",
    "PassOrderingError",
    "PassVerificationError",
    "pipeline_candidates",
    "register_pass",
    "registered_passes",
    "resolve_order",
    "unregister_pass",
    "canonicalize",
    "fold_constants",
    "fold_batchnorm",
    "fuse_pad",
    "fuse_activation",
    "plan_memory",
    "MemoryPlan",
    "optimize_layout",
    "propagate_sharding",
    "quantize",
]
