"""Compiler passes over the graph IR.

Pipeline order mirrors the paper's intermediate processing (§3.2/§3.5):

1. canonicalize      — normalize ops (flatten→reshape, lone softmax→activation)
2. fold_constants    — precompute weight-only subgraphs
3. fuse_pad          — merge zero_pad2d into the following conv (fewer passes)
4. fuse_activation   — activations become epilogues of producers (§3.4)
5. fold_batchnorm    — BN folded into adjacent conv/dense (§3.5); runs after
                       activation fusion so the conv→act→BN pattern can fold
                       as a post-activation affine epilogue, as the paper does
6. optimize_layout   — compile-time weight re-layout (Eq. 3 analogue) (§3.3)
7. plan_memory       — lifetime analysis + arena assignment, in-place reuse (§3.2)

Each pass is a pure function Graph -> Graph (plus optional report).
``run_pipeline`` applies them and returns (graph, report dict).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..graph import Graph
from .canonicalize import canonicalize
from .fold_constants import fold_constants
from .fold_batchnorm import fold_batchnorm
from .fuse_pad import fuse_pad
from .fuse_activation import fuse_activation
from .memory_plan import MemoryPlan, plan_memory
from .layout import optimize_layout

# fuse_activation runs twice: once so the conv→act→BN pattern folds as a
# post-activation affine (paper §3.5), and once more because BN removal
# exposes new conv→act adjacencies (conv→BN→act becomes conv→act).
DEFAULT_PIPELINE = (
    "canonicalize",
    "fold_constants",
    "fuse_pad",
    "fuse_activation",
    "fold_batchnorm",
    "fuse_activation",
    "optimize_layout",
)

_PASSES = {
    "canonicalize": canonicalize,
    "fold_constants": fold_constants,
    "fuse_pad": fuse_pad,
    "fold_batchnorm": fold_batchnorm,
    "fuse_activation": fuse_activation,
    "optimize_layout": optimize_layout,
}


def run_pipeline(
    graph: Graph,
    passes: Optional[Tuple[str, ...]] = None,
) -> Tuple[Graph, Dict]:
    """Run the pass pipeline; returns the optimized graph and a report
    with per-pass statistics plus the memory plan."""
    report: Dict = {"passes": []}
    g = graph.copy()
    for name in passes if passes is not None else DEFAULT_PIPELINE:
        before = len(g.nodes)
        g, stats = _PASSES[name](g)
        g.rebuild_index()
        report["passes"].append(
            {"pass": name, "nodes_before": before, "nodes_after": len(g.nodes), **stats}
        )
    plan = plan_memory(g)
    report["memory_plan"] = plan.stats()
    report["plan"] = plan
    return g, report


__all__ = [
    "run_pipeline",
    "DEFAULT_PIPELINE",
    "canonicalize",
    "fold_constants",
    "fold_batchnorm",
    "fuse_pad",
    "fuse_activation",
    "plan_memory",
    "MemoryPlan",
    "optimize_layout",
]
