"""Batch-norm folding (paper §3.5).

A batchnorm computes ``y = gamma * (x - mean) / sqrt(var + eps) + beta``
which is the affine ``y = s * x + o`` with

    s = gamma / sqrt(var + eps)
    o = beta - s * mean

If the BN is immediately **after** a conv/dense node:

    y = s * (W x + b) + o  =  (s ⊙ W) x + (s*b + o)

so the BN disappears by scaling the producing kernel's output channels.

If the BN is immediately **before** a conv/dense node (and nothing else
consumes the BN output):

    W (s*x + o) + b  =  (W ⊙ s) x + (W o + b)

so the BN disappears by scaling the consuming kernel's input channels and
adjusting its bias.

The paper notes that if an activation sits between the BN and the other
layer, the BN is *still* fused and applied after the activation inside
the same compilation unit; in this IR that is represented by keeping the
BN as an affine epilogue on the producer (epilogue_attrs carries s,o) —
the back end applies activation-then-affine before the store.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..graph import Graph, Node
from .manager import register_pass


def _bn_scale_offset(g: Graph, bn: Node) -> Tuple[np.ndarray, np.ndarray]:
    gamma = g.params[bn.params["gamma"]]
    beta = g.params[bn.params["beta"]]
    mean = g.params[bn.params["mean"]]
    var = g.params[bn.params["var"]]
    eps = bn.attrs["epsilon"]
    s = gamma / np.sqrt(var + eps)
    o = beta - s * mean
    return s.astype(np.float32), o.astype(np.float32)


def _scale_output_channels(g: Graph, node: Node, s: np.ndarray, o: np.ndarray) -> None:
    """Fold y = s*conv(x)+o into the conv/dense weights (BN-after case)."""
    k = g.params[node.params["kernel"]]
    if node.op in ("conv2d", "dense"):
        g.params[node.params["kernel"]] = (k * s).astype(np.float32)  # last axis = cout
    elif node.op == "depthwise_conv2d":
        kh, kw, c, mult = k.shape
        g.params[node.params["kernel"]] = (
            k * s.reshape(c, mult)
        ).astype(np.float32)
    else:  # pragma: no cover - guarded by caller
        raise AssertionError(node.op)
    if "bias" in node.params:
        b = g.params[node.params["bias"]]
        g.params[node.params["bias"]] = (s * b + o).astype(np.float32)
    else:
        bname = f"{node.name}/folded_bias"
        g.params[bname] = o.astype(np.float32)
        node.params["bias"] = bname


def _scale_input_channels(g: Graph, node: Node, s: np.ndarray, o: np.ndarray) -> None:
    """Fold conv(s*x+o) into the conv/dense weights (BN-before case)."""
    k = g.params[node.params["kernel"]]
    if node.op == "dense":
        g.params[node.params["kernel"]] = (k * s[:, None]).astype(np.float32)
        extra = k.T @ o  # (cout,)
    elif node.op == "conv2d":
        g.params[node.params["kernel"]] = (k * s[None, None, :, None]).astype(
            np.float32
        )
        extra = np.einsum("hwio,i->o", k, o)
    else:  # depthwise: each channel independent
        kh, kw, c, mult = k.shape
        g.params[node.params["kernel"]] = (k * s[None, None, :, None]).astype(
            np.float32
        )
        extra = (k.sum(axis=(0, 1)) * o[:, None]).reshape(-1)
    if "bias" in node.params:
        b = g.params[node.params["bias"]]
        g.params[node.params["bias"]] = (b + extra).astype(np.float32)
    else:
        bname = f"{node.name}/folded_bias"
        g.params[bname] = extra.astype(np.float32)
        node.params["bias"] = bname


@register_pass("fold_batchnorm", after=("canonicalize",))
def fold_batchnorm(graph: Graph) -> Tuple[Graph, Dict]:
    g = graph.copy()
    folded_after = folded_before = affine_epilogue = 0

    changed = True
    while changed:
        changed = False
        for bn in list(g.nodes):
            if bn.op != "batchnorm":
                continue
            src = g.producer(bn.inputs[0])
            consumers = g.consumers(bn.output)

            # Case 1: conv/dense -> BN  (fold into producer's output chans)
            if (
                src is not None
                and src.op in ("conv2d", "depthwise_conv2d", "dense")
                and src.epilogue in (None, "linear")
                and len(g.consumers(src.output)) == 1
            ):
                s, o = _bn_scale_offset(g, bn)
                # depthwise conv2d with stride: only valid if padding didn't
                # change channel semantics — always safe for BN-after.
                _scale_output_channels(g, src, s, o)
                _remove_node(g, bn)
                folded_after += 1
                changed = True
                continue

            # Case 1b: conv/dense -> activation -> BN.  Paper: "the batch
            # normalization is still fused into the other layer and
            # applied after the activation".  Represent as an affine
            # epilogue on the producer.
            if (
                src is not None
                and src.op in ("conv2d", "depthwise_conv2d", "dense")
                and src.epilogue not in (None, "linear")
                and len(g.consumers(src.output)) == 1
                and src.epilogue != "softmax"
            ):
                s, o = _bn_scale_offset(g, bn)
                sname = f"{bn.name}/scale"
                oname = f"{bn.name}/offset"
                g.params[sname] = s
                g.params[oname] = o
                src.epilogue_attrs = dict(src.epilogue_attrs)
                src.epilogue_attrs["post_affine"] = (sname, oname)
                _remove_node(g, bn)
                affine_epilogue += 1
                changed = True
                continue

            # Case 2: BN -> conv/dense  (fold into consumer's input chans).
            # For convs this is only exact with 'valid' padding: with
            # 'same' padding the folded bias correction W·o would also be
            # added at taps that originally saw zero padding, not s*x+o.
            if (
                len(consumers) == 1
                and (
                    consumers[0].op == "dense"
                    or (
                        consumers[0].op in ("conv2d", "depthwise_conv2d")
                        and consumers[0].attrs.get("padding") == "valid"
                    )
                )
                and bn.output not in g.outputs
            ):
                s, o = _bn_scale_offset(g, bn)
                _scale_input_channels(g, consumers[0], s, o)
                _remove_node(g, bn)
                folded_before += 1
                changed = True
                continue
    g.rebuild_index()
    return g, {
        "folded_after": folded_after,
        "folded_before": folded_before,
        "affine_epilogue": affine_epilogue,
    }


def _remove_node(g: Graph, node: Node) -> None:
    """Remove a single-input node, rewiring consumers to its input."""
    src_tensor = node.inputs[0]
    for other in g.nodes:
        other.inputs = [src_tensor if t == node.output else t for t in other.inputs]
    g.outputs = [src_tensor if t == node.output else t for t in g.outputs]
    g.nodes.remove(node)
    g.rebuild_index()
