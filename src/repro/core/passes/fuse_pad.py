"""Merge explicit zero-padding nodes into the following convolution.

CompiledNN merges layers "if that is deemed beneficial for … the
performance of the generated code" (§3.2); an explicit ZeroPadding2D in
front of a 'valid' conv is the canonical case — the conv kernel can read
the padding implicitly instead of materializing a padded copy of the
tensor in memory.
"""

from __future__ import annotations

from typing import Dict, Tuple

from ..graph import Graph
from .manager import register_pass


@register_pass("fuse_pad", after=("canonicalize",))
def fuse_pad(graph: Graph) -> Tuple[Graph, Dict]:
    g = graph.copy()
    fused = 0
    changed = True
    while changed:
        changed = False
        for pad in list(g.nodes):
            if pad.op != "zero_pad2d" or pad.output in g.outputs:
                continue
            consumers = g.consumers(pad.output)
            if len(consumers) != 1:
                continue
            conv = consumers[0]
            if conv.op not in ("conv2d", "depthwise_conv2d"):
                continue
            if conv.attrs.get("padding") != "valid":
                continue
            (t, b), (l, r) = pad.attrs["padding"]
            # Explicit per-edge padding replaces the 'valid' string form.
            conv.attrs["padding"] = ((t, b), (l, r))
            conv.inputs = [pad.inputs[0]]
            g.nodes.remove(pad)
            g.rebuild_index()
            fused += 1
            changed = True
    g.rebuild_index()
    return g, {"fused_pads": fused}
