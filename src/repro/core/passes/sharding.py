"""propagate_sharding — the distribution pass, as a registry citizen.

The thin pipeline wrapper around :mod:`repro.dist.propagate`.  It
registers in the ordinary pass registry (scheduled after every
optimization pass: placement is decided on the *final* graph, so fusion
and layout rewrites never have to reason about collective nodes), and
is a no-op for graphs without a ``dist`` annotation — which keeps the
default pipeline byte-identical for unsharded compiles while letting
``DEFAULT_PIPELINE`` carry one canonical pass list for both.

The heavy lifting lives in ``repro.dist`` and is imported lazily, so
``repro.core`` keeps zero import-time dependency on the distribution
subsystem (only sharded compiles pay for it).
"""

from __future__ import annotations

from typing import Dict, Tuple

from ..graph import Graph
from .manager import register_pass


@register_pass("propagate_sharding",
               after=("canonicalize", "fold_constants", "fuse_pad",
                      "fuse_activation", "fold_batchnorm",
                      "fuse_activation.post_bn", "optimize_layout"))
def propagate_sharding(graph: Graph) -> Tuple[Graph, Dict]:
    """Resolve per-tensor shardings + insert collectives (repro.dist);
    no-op (``{"sharded": False}``) for unsharded graphs."""
    if not getattr(graph, "dist", None):
        return graph, {"sharded": False}
    from ...dist.propagate import propagate_shardings
    stats = propagate_shardings(graph)
    return graph, stats
