"""Activation fusion (paper §3.4).

Elementwise activations following a conv/dense node are removed from the
graph and recorded as the producer's ``epilogue``: the back end applies
them to the accumulator tile before the store to memory ("the activation
function is applied before writing the result of the operation into
memory. This avoids an additional loop with load and store operations").

Softmax is never fused — it needs two passes (§3.4) and always stays a
separate compilation unit.
"""

from __future__ import annotations

from typing import Dict, Tuple

from ..graph import ACTIVATIONS, Graph
from .fold_batchnorm import _remove_node
from .manager import register_pass

FUSABLE_PRODUCERS = ("conv2d", "depthwise_conv2d", "dense")

#: Tuning-site hook: when the graph-level autotuner has measured that an
#: activation is better left unfused (``repro.autotune.decisions``), it
#: sets this attr to ``False`` on the activation node and the pass skips
#: that site.  Absent / ``True`` keeps the heuristic (fuse when legal),
#: so ``autotune="off"`` is bit-identical.  The attr is *not* popped:
#: the pass runs twice (base + ``.post_bn``) and both must honor it.
TUNE_FUSE_ATTR = "tune.fuse"


# Registered twice (see passes/__init__): once before BN folding so the
# conv→act→BN pattern can fold as a post-activation affine (§3.5), and
# once after as "fuse_activation.post_bn", because BN removal exposes
# new conv→act adjacencies (conv→BN→act becomes conv→act).
@register_pass("fuse_activation", after=("canonicalize",),
               before=("fold_batchnorm",))
def fuse_activation(graph: Graph) -> Tuple[Graph, Dict]:
    g = graph.copy()
    fused = 0
    changed = True
    while changed:
        changed = False
        for act in list(g.nodes):
            if act.op != "activation":
                continue
            fn = act.attrs["fn"]
            if not ACTIVATIONS.get(fn, False):
                continue  # not fusable (softmax)
            if act.attrs.get(TUNE_FUSE_ATTR) is False:
                continue  # autotuner measured this site faster unfused
            src = g.producer(act.inputs[0])
            if src is None or src.op not in FUSABLE_PRODUCERS:
                continue
            if src.epilogue not in (None, "linear"):
                continue  # already has a fused activation
            if len(g.consumers(src.output)) != 1:
                # The pre-activation value is needed elsewhere; fusing
                # would force recomputation.  CompiledNN only fuses when
                # "deemed beneficial" — skip.
                continue
            src.epilogue = fn
            src.epilogue_attrs = {
                k: v for k, v in act.attrs.items() if k != "fn"
            }
            _remove_node(g, act)
            fused += 1
            changed = True
    g.rebuild_index()
    return g, {"fused_activations": fused}
