"""Canonicalization: rewrite sugar ops into core forms so later passes
see a uniform IR (the paper's front end does the equivalent when mapping
Keras layers onto compilation units)."""

from __future__ import annotations

from typing import Dict, Tuple

from ..graph import Graph
from .manager import register_pass


@register_pass("canonicalize")
def canonicalize(graph: Graph) -> Tuple[Graph, Dict]:
    g = graph.copy()
    specs = g.infer_shapes()
    rewrites = 0
    for node in g.nodes:
        # flatten -> reshape with an explicit static shape.
        if node.op == "flatten":
            node.op = "reshape"
            node.attrs = {"shape": (specs[node.inputs[0]].size,)}
            rewrites += 1
        # standalone softmax node -> activation(fn=softmax) so the
        # fusion pass has one representation of activations.
        elif node.op == "softmax":
            node.op = "activation"
            node.attrs = {"fn": "softmax", "axis": node.attrs["axis"]}
            rewrites += 1
    g.rebuild_index()
    return g, {"rewrites": rewrites}
