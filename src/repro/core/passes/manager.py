"""PassManager — the registry-driven pass pipeline driver.

The middle end used to be a hard-coded tuple of pass names; new passes
meant editing ``core/passes/__init__.py``.  Now passes self-register::

    @register_pass("fuse_widgets", after=("canonicalize",),
                   before=("optimize_layout",))
    def fuse_widgets(graph: Graph) -> Tuple[Graph, Dict]:
        ...

and :class:`PassManager` resolves the ordering constraints into a
pipeline (deterministically: Kahn's algorithm, registration order breaks
ties), runs it, and

* re-runs shape inference after **every** pass as a verifier — a pass
  that corrupts the graph (cycle, dangling tensor, changed output
  shapes) is rejected on the spot with the pass named, instead of
  surfacing as a cryptic trace error at lowering time;
* records per-pass wall time and node-count deltas in the compile
  report;
* optionally dumps the IR between passes (``CompileOptions.dump_ir`` or
  ``$REPRO_DUMP_IR`` — a directory receiving one ``NN-<pass>.txt``
  summary per stage, or ``-``/``stderr`` to stream to stderr).

A pass may be registered under several *instance* names (the default
pipeline runs ``fuse_activation`` twice, the second time as
``fuse_activation.post_bn``); the text before the first ``.`` is the
*base* name, which is what :meth:`PassManager.without` matches — so an
ablation removing ``fuse_activation`` removes every instance.
"""

from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from ..graph import Graph
from .memory_plan import plan_memory

PassFn = Callable[[Graph], Tuple[Graph, Dict]]


class PassOrderingError(ValueError):
    """after=/before= constraints are unsatisfiable (a cycle)."""


class PassVerificationError(RuntimeError):
    """A pass produced a graph that fails shape inference or changed the
    model's output signature."""


@dataclasses.dataclass(frozen=True)
class PassSpec:
    name: str                     # instance name, e.g. "fuse_activation.post_bn"
    fn: PassFn
    after: Tuple[str, ...] = ()   # instance names this pass must follow
    before: Tuple[str, ...] = ()  # instance names this pass must precede

    @property
    def base(self) -> str:
        """Base name: instance name up to the first '.'."""
        return self.name.split(".", 1)[0]


#: Instance name -> spec, in registration order (dicts preserve it).
_REGISTRY: Dict[str, PassSpec] = {}


def register_pass(
    name: str,
    *,
    after: Sequence[str] = (),
    before: Sequence[str] = (),
) -> Callable[[PassFn], PassFn]:
    """Decorator: register a Graph -> (Graph, stats) pass under ``name``
    with ordering constraints.  Re-registering a name overwrites it (so
    a test can shadow a pass) but keeps its original position for
    tie-breaking."""

    def deco(fn: PassFn) -> PassFn:
        _REGISTRY[name] = PassSpec(
            name=name, fn=fn, after=tuple(after), before=tuple(before)
        )
        return fn

    return deco


def unregister_pass(name: str) -> None:
    """Remove a registered pass instance (tests clean up with this)."""
    _REGISTRY.pop(name, None)


def registered_passes() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_order(names: Optional[Iterable[str]] = None) -> Tuple[str, ...]:
    """Topologically order the given pass instances (default: the whole
    registry) under their after/before constraints.

    Deterministic: among ready passes, registration order wins.
    Constraints that name absent passes are ignored, so removing a pass
    never invalidates the rest of the pipeline.
    """
    if names is None:
        names = tuple(_REGISTRY)
    specs = [_REGISTRY[n] for n in names]
    present = {s.name for s in specs}
    edges: Dict[str, set] = {s.name: set() for s in specs}   # u -> {v}: u before v
    for s in specs:
        for dep in s.after:
            if dep in present:
                edges[dep].add(s.name)
        for succ in s.before:
            if succ in present:
                edges[s.name].add(succ)
    indeg = {s.name: 0 for s in specs}
    for u, vs in edges.items():
        for v in vs:
            indeg[v] += 1
    order: List[str] = []
    remaining = [s.name for s in specs]  # registration order
    while remaining:
        ready = [n for n in remaining if indeg[n] == 0]
        if not ready:
            raise PassOrderingError(
                f"pass ordering constraints form a cycle among {remaining}; "
                f"check the after=/before= declarations of these passes"
            )
        n = ready[0]
        remaining.remove(n)
        order.append(n)
        for v in edges[n]:
            indeg[v] -= 1
    return tuple(order)


def _lookup(name: str) -> PassSpec:
    """Resolve an explicit pipeline entry: exact instance name first,
    then the first registered instance of that base name (so legacy
    tuples like ``("canonicalize", "fuse_activation")`` keep working)."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    for spec in _REGISTRY.values():
        if spec.base == name:
            return spec
    raise KeyError(
        f"unknown pass {name!r}; registered: {sorted(_REGISTRY)}"
    )


def _resolve_dump_ir(dump_ir) -> Tuple[str, ...]:
    """Normalize a dump_ir setting to a tuple of sinks.  Accepts None
    (fall back to ``$REPRO_DUMP_IR``), a single directory / ``-`` /
    ``stderr`` string, or a sequence of such sinks (capture bundles tee
    the IR dumps into the bundle alongside any user-requested sink)."""
    if dump_ir is None:
        dump_ir = os.environ.get("REPRO_DUMP_IR") or None
    if dump_ir is None:
        return ()
    if isinstance(dump_ir, str):
        return (dump_ir,)
    return tuple(dump_ir)


def pipeline_candidates() -> Dict[str, Tuple[str, ...]]:
    """Named whole-pipeline variants the graph-level autotuner may pick
    between (``repro.autotune.decisions``, "pipeline" sites).

    Each variant is derived from the current default registry order by
    ``PassManager.without`` surgery, so a newly registered pass is
    automatically part of every variant.  ``"default"`` is always
    present and always first.
    """
    default = PassManager.default()
    return {
        "default": default.pipeline,
        "no_fusion": default.without("fuse_activation").pipeline,
        "no_layout": default.without("optimize_layout").pipeline,
    }


class PassManager:
    """An ordered, verified pass pipeline.

    ``pipeline=None`` resolves the full registry under its constraints;
    an explicit sequence of names (instance or base, duplicates allowed)
    runs exactly those in exactly that order — this is what
    ``CompileOptions.passes`` feeds in.
    """

    def __init__(
        self,
        pipeline: Optional[Sequence[str]] = None,
        *,
        verify: bool = True,
        dump_ir: Optional[object] = None,  # str | Sequence[str] | None
    ) -> None:
        if pipeline is None:
            self._specs = [_REGISTRY[n] for n in resolve_order()]
        else:
            self._specs = [_lookup(n) for n in pipeline]
        self.verify = verify
        self.dump_ir = _resolve_dump_ir(dump_ir)

    # -- registry-style pipeline surgery (ablations, tests) ------------
    @classmethod
    def default(cls, **kw) -> "PassManager":
        return cls(None, **kw)

    @property
    def pipeline(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self._specs)

    def without(self, *names: str) -> "PassManager":
        """A new manager with every instance of the named passes removed
        (base-name match: ``without("fuse_activation")`` drops both the
        pre- and post-BN instances)."""
        drop = set(names)
        kept = [s.name for s in self._specs
                if s.name not in drop and s.base not in drop]
        return PassManager(kept, verify=self.verify, dump_ir=self.dump_ir)

    def with_pass(self, name: str, index: Optional[int] = None) -> "PassManager":
        """A new manager with registered pass ``name`` inserted (at
        ``index``, default: appended)."""
        names = list(self.pipeline)
        names.insert(len(names) if index is None else index, _lookup(name).name)
        return PassManager(names, verify=self.verify, dump_ir=self.dump_ir)

    # -- execution -----------------------------------------------------
    def _dump(self, stage: int, name: str, graph: Graph) -> None:
        if not self.dump_ir:
            return
        text = graph.summary()
        for sink in self.dump_ir:
            if sink in ("-", "stderr"):
                print(f"// IR after {stage:02d}-{name}\n{text}",
                      file=sys.stderr)
                continue
            os.makedirs(sink, exist_ok=True)
            path = os.path.join(sink, f"{stage:02d}-{name}.txt")
            with open(path, "w") as f:
                f.write(text + "\n")

    def _verify(self, name: str, graph: Graph, want_outputs) -> None:
        try:
            specs = graph.infer_shapes()   # also validates the toposort
        except Exception as e:
            raise PassVerificationError(
                f"pass {name!r} produced an invalid graph: {e}"
            ) from e
        got = [(specs[t].shape, specs[t].dtype) for t in graph.outputs]
        if got != want_outputs:
            raise PassVerificationError(
                f"pass {name!r} changed the model's output signature: "
                f"{want_outputs} -> {got}"
            )
        if getattr(graph, "dist", None):
            # Sharded compile: re-check the distribution annotations
            # after every pass, exactly like shape inference — a pass
            # that breaks a collective's mesh axes or (post-propagation)
            # leaves a tensor without a resolved spec is rejected here
            # with the pass named.
            from ...dist.propagate import ShardingError, check_shardings
            try:
                check_shardings(graph)
            except ShardingError as e:
                raise PassVerificationError(
                    f"pass {name!r} broke the sharding annotations: {e}"
                ) from e

    def run(self, graph: Graph) -> Tuple[Graph, Dict]:
        """Run the pipeline; returns (optimized graph, report).  The
        report carries the resolved pipeline, per-pass stats (wall time,
        node deltas, pass-specific counters) and the memory plan."""
        report: Dict = {"pipeline": self.pipeline, "passes": []}
        g = graph.copy()
        if self.verify:
            specs = g.infer_shapes()
            want_outputs = [(specs[t].shape, specs[t].dtype) for t in g.outputs]
        self._dump(0, "input", g)
        for stage, spec in enumerate(self._specs, start=1):
            before = len(g.nodes)
            t0 = time.perf_counter()
            g, stats = spec.fn(g)
            g.rebuild_index()
            dt = time.perf_counter() - t0
            if self.verify:
                self._verify(spec.name, g, want_outputs)
            self._dump(stage, spec.name, g)
            report["passes"].append({
                "pass": spec.name,
                "nodes_before": before,
                "nodes_after": len(g.nodes),
                "time_ms": dt * 1e3,
                **stats,
            })
        plan = plan_memory(g)
        report["memory_plan"] = plan.stats()
        report["plan"] = plan
        return g, report
