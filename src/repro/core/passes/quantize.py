"""Calibration-driven low-precision quantization (the ROADMAP's
"TensorRT playbook": calibrated ranges + per-site tactic selection).

The pass consumes a quantization *request* riding on ``graph.quant``
(the same contract ``dist`` uses — targets attach it from
``CompileOptions(precision=..., calibrate=...)``), and annotates the
graph instead of rewriting arithmetic:

* a **calibration walk** runs ``calibrate`` seeded sample batches
  through the oracle semantics of the *current* (fused, folded) graph,
  recording per-tensor ``|x|`` abs-max and 99.9th-percentile ranges;
* every eligible site (``dense``, ``conv2d``) is annotated with
  ``quant.mode`` plus — for int8 — the calibrated per-tensor input
  scale (``quant.x_scale``) and per-output-channel weight scales
  (``quant.w_scale``, computed from the static f32 weights).  Zero
  points are always 0 (symmetric quantization; ``quant.zp`` records
  it);
* ``mode="mixed"`` measures per-site f32/bf16/int8 candidates under
  the autotune :class:`~repro.autotune.measure.Deadline`, persists
  winners in the fingerprinted tactic cache, and only picks a narrow
  dtype where it is both faster *and* within the accuracy budget
  (max_abs_err vs the f32 calibration outputs).

Annotations are plain node attrs, so they flow into
``Graph.structure_hash()`` — executable and tactic cache keys stay
correct with no extra plumbing — and survive ``serialize()`` through
the container's attr round-trip.  The actual low-precision arithmetic
lives in the lowering rules and ``repro.kernels.qmath``: every target
(interpret/jit/pallas) reads the same attrs and runs the same shared
expressions, which is what keeps them golden-comparable.

Scheduling: after ``fuse_activation.post_bn`` (calibration must see
the folded weights the compiled program will actually quantize) and
before ``optimize_layout`` (the walk interprets logical ``(K, N)``
kernels; layout transposes/pads afterwards, and the per-channel scales
are layout-invariant).

Backend-aware prior (documented in docs/quantization.md): int8 conv
sites stay f32 off-TPU — XLA's CPU int8 convolutions are slower than
f32, so quantizing them would trade accuracy for a slowdown; int8
dense sites lower to the reference ``lax.dot_general`` int8 path on
CPU and the dedicated Pallas q8 kernel on TPU.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..graph import Graph, Node
from .manager import register_pass
from ...kernels import qmath

#: Ops the pass may rewrite to low precision.
QUANT_OPS = ("dense", "conv2d")
#: Sample batches when the request does not say (CompileOptions leaves
#: ``calibrate=None``).
DEFAULT_CALIBRATE = 4
#: Rows per calibration batch.
CALIBRATION_BATCH = 4
#: Accuracy budget (max_abs_err vs the f32 calibration outputs) for
#: ``mode="mixed"`` when the request carries none.
DEFAULT_PRECISION_BUDGET = 0.05
#: Default wall-clock budget for mixed-mode measurement.
DEFAULT_MEASURE_BUDGET_MS = 1000.0

_MODES = ("f32", "bf16", "int8", "mixed")


def _on_tpu() -> bool:
    import jax
    return any(d.platform == "tpu" for d in jax.devices())


def _kernel_out_axis(node: Node) -> int:
    """Output-channel axis of the site's kernel param: dense (K, N) →
    1, conv2d HWIO → 3.  The pass runs pre-layout, so dense kernels are
    always logical "io"."""
    return 1 if node.op == "dense" else 3


def _apply_manual_epilogue(y, node: Node, params):
    """SimpleNN applies fused epilogues as separate steps but skips the
    folded post-activation affine; the calibration walk needs both, so
    the recorded ranges match what the compiled program feeds each
    quantized site."""
    from ..ops_common import apply_activation
    if node.epilogue and node.epilogue != "linear":
        y = apply_activation(node.epilogue, y, node.epilogue_attrs)
    pa = node.epilogue_attrs.get("post_affine")
    if pa:
        import jax.numpy as jnp
        y = y * jnp.asarray(params[pa[0]]) + jnp.asarray(params[pa[1]])
    return y


def _calibrate(graph: Graph, batches: int,
               sites: List[Node]) -> Tuple[Dict, Dict, Dict]:
    """Seeded oracle walk over the current graph.  Returns
    ``(ranges, first_inputs, first_outputs)``: per-tensor
    ``{"absmax", "p999"}`` stats over every batch, plus the first
    batch's input/f32-output arrays for each site (what mixed-mode
    accuracy checks diff against)."""
    import jax.numpy as jnp
    from ..simple import SimpleNN

    sim = SimpleNN(graph)
    site_names = {n.name for n in sites}
    rng = np.random.default_rng(0)
    ranges: Dict[str, Dict[str, float]] = {}
    first_inputs: Dict[str, np.ndarray] = {}
    first_outputs: Dict[str, np.ndarray] = {}
    for bi in range(batches):
        env: Dict[str, jnp.ndarray] = {
            name: jnp.asarray(
                rng.standard_normal((CALIBRATION_BATCH,) + spec.shape)
                .astype(np.float32))
            for name, spec in graph.inputs.items()
        }
        for node in graph.toposort():
            y = sim._eval(node, env, CALIBRATION_BATCH)
            y = _apply_manual_epilogue(y, node, graph.params)
            env[node.output] = y
            if bi == 0 and node.name in site_names:
                first_inputs[node.name] = np.asarray(env[node.inputs[0]])
                first_outputs[node.name] = np.asarray(y)
        for name, val in env.items():
            a = np.abs(np.asarray(val, dtype=np.float32))
            r = ranges.setdefault(name, {"absmax": 0.0, "p999": 0.0})
            r["absmax"] = max(r["absmax"], float(a.max()) if a.size else 0.0)
            if a.size:
                r["p999"] = max(r["p999"], float(np.percentile(a, 99.9)))
    return ranges, first_inputs, first_outputs


def _annotate_int8(node: Node, graph: Graph, ranges: Dict,
                   method: str) -> None:
    stat = ranges[node.inputs[0]]
    absmax = stat["p999"] if method == "percentile" else stat["absmax"]
    w = graph.params[node.params["kernel"]]
    scales = qmath.channel_scales(w, _kernel_out_axis(node))
    node.attrs["quant.mode"] = "int8"
    node.attrs["quant.method"] = method
    node.attrs["quant.x_scale"] = qmath.tensor_scale(absmax)
    # A tuple, matching the IR's attr convention (the container's JSON
    # round trip re-tuplifies lists, so tuples survive save/load as-is).
    node.attrs["quant.w_scale"] = tuple(round(float(s), 10) for s in scales)
    node.attrs["quant.zp"] = 0


def _static_site_mode(node: Node, mode: str, on_tpu: bool) -> Optional[str]:
    """The non-measured prior: which precision a site gets under a
    static ``bf16``/``int8`` request.  ``None`` = stay f32."""
    if mode == "bf16":
        return "bf16"
    if mode == "int8":
        if node.op == "conv2d" and not on_tpu:
            return None    # XLA CPU int8 conv loses to f32 — keep exact
        return "int8"
    return None


# ---------------------------------------------------------------------------
# mixed mode: per-site measured tactic selection
# ---------------------------------------------------------------------------
def _site_runner(node: Node, graph: Graph, cand: str, x: np.ndarray,
                 ranges: Dict, method: str):
    """A jitted callable + args computing this site at precision
    ``cand`` — the same expressions the lowering rules emit, measured
    on the first calibration batch."""
    import functools

    import jax
    import jax.numpy as jnp

    from ...kernels.fused_matmul.ops import fused_matmul, fused_matmul_q8
    from ..ops_common import lax_padding

    w = jnp.asarray(graph.params[node.params["kernel"]])
    b = (jnp.asarray(graph.params[node.params["bias"]])
         if "bias" in node.params else None)
    fn = (node.epilogue
          if node.epilogue not in (None, "linear", "softmax") else None)
    xj = jnp.asarray(x)
    if node.op == "dense":
        if cand == "int8":
            stat = ranges[node.inputs[0]]
            absmax = stat["p999"] if method == "percentile" else stat["absmax"]
            run = jax.jit(functools.partial(
                fused_matmul_q8,
                x_scale=qmath.tensor_scale(absmax),
                w_scales=qmath.channel_scales(np.asarray(w), 1),
                fn=fn))
        elif cand == "bf16":
            base = functools.partial(fused_matmul, fn=fn)
            run = jax.jit(lambda x, w, b: base(
                x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), b))
        else:
            run = jax.jit(functools.partial(fused_matmul, fn=fn))
        return run, (xj, w, b)

    # conv2d
    strides = node.attrs["strides"]
    padding = lax_padding(node.attrs["padding"])

    def conv(x, w, b, *, dtype=None, pet=None):
        if dtype is not None:
            x, w = x.astype(dtype), w.astype(dtype)
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=strides, padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            **({"preferred_element_type": pet} if pet else {}))
        if pet is jnp.int32:
            y = y.astype(jnp.float32)
        if b is not None:
            y = y + b
        return y

    if cand == "int8":
        stat = ranges[node.inputs[0]]
        absmax = stat["p999"] if method == "percentile" else stat["absmax"]
        xs = qmath.tensor_scale(absmax)
        ws = qmath.channel_scales(np.asarray(w), 3)
        deq = qmath.dequant_scales(xs, ws)

        def run_q8(x, w, b):
            xq = qmath.quantize_q8(x, jnp.float32(xs))
            wq = qmath.quantize_q8(w, jnp.asarray(ws)[None, None, None, :])
            y = jax.lax.conv_general_dilated(
                xq, wq, window_strides=strides, padding=padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                preferred_element_type=jnp.int32)
            y = y.astype(jnp.float32) * deq
            if b is not None:
                y = y + b
            return y

        return jax.jit(run_q8), (xj, w, b)
    if cand == "bf16":
        return jax.jit(functools.partial(
            conv, dtype=jnp.bfloat16, pet=jnp.float32)), (xj, w, b)
    return jax.jit(conv), (xj, w, b)


def _tune_mixed(nodes: List[Node], graph: Graph, ranges: Dict,
                first_inputs: Dict, req: Dict, on_tpu: bool,
                method: str) -> Tuple[Dict[str, str], Dict]:
    """Measured per-site precision selection: candidates are f32 plus
    every narrow dtype the static prior would allow; the winner is the
    fastest candidate whose max_abs_err against the f32 site output on
    the calibration batch stays within the budget.  Winners persist in
    the shared fingerprinted tactic cache keyed by (site shape ×
    epilogue × budget)."""
    from ...autotune.cache import environment_fingerprint, tactic_key
    from ...autotune.measure import Deadline, bench_min_us

    budget = req.get("budget") or DEFAULT_PRECISION_BUDGET
    measure = req.get("measure", True)
    deadline = Deadline(req.get("budget_ms", DEFAULT_MEASURE_BUDGET_MS)
                        if measure else 0.0)
    cache = None
    if req.get("use_cache", True):
        from ...autotune import open_tactic_cache
        cache = open_tactic_cache(req.get("cache_dir"))
    fp = environment_fingerprint()

    decisions: Dict[str, str] = {}
    entries: Dict[str, dict] = {}
    specs = graph.infer_shapes()
    for node in nodes:
        in_spec = specs[node.inputs[0]]
        cands = ["f32"]
        for cand in ("bf16", "int8"):
            if _static_site_mode(node, cand, on_tpu) == cand:
                cands.append(cand)
        desc = {"kind": "precision", "op": node.op,
                "in_shape": list(in_spec.shape), "in_dtype": in_spec.dtype,
                "kshape": list(graph.params[node.params["kernel"]].shape),
                "epilogue": node.epilogue or "",
                "has_bias": "bias" in node.params,
                "budget": budget, "method": method,
                "batch": CALIBRATION_BATCH, "tpu": on_tpu}
        key = tactic_key(desc, fp)
        entry = cache.load(key, fp) if cache is not None else None
        if entry is None and measure and not deadline.expired():
            x = first_inputs[node.name]
            measured, errs = {}, {}
            want = None
            for cand in cands:
                if deadline.expired() and cand != "f32":
                    break
                try:
                    run, args = _site_runner(node, graph, cand, x,
                                             ranges, method)
                    out = np.asarray(run(*args))
                except Exception:
                    continue
                if cand == "f32":
                    want = out
                    errs[cand] = 0.0
                else:
                    errs[cand] = (float(np.abs(out - want).max())
                                  if want is not None else float("inf"))
                us = bench_min_us(run, args, reps=5, warmup=1,
                                  deadline=deadline)
                if us is not None:
                    measured[cand] = us
            ok = [c for c in measured
                  if errs.get(c, float("inf")) <= budget or c == "f32"]
            if ok:
                winner = min(ok, key=lambda c: measured[c])
                entry = {"winner": winner,
                         "measured_us": {k: round(v, 3)
                                         for k, v in measured.items()},
                         "max_abs_err": {k: round(v, 8)
                                         for k, v in errs.items()},
                         "desc": desc, "fingerprint": fp}
                if cache is not None:
                    cache.store(key, entry)
        if entry is not None:
            decisions[node.name] = entry["winner"]
            entries[key] = entry
        else:
            decisions[node.name] = "f32"   # no data: stay exact
    report = {"spent_ms": round(deadline.spent_ms(), 3),
              "budget": budget, "entries": len(entries)}
    return decisions, report


# ---------------------------------------------------------------------------
@register_pass("quantize", after=("fuse_activation.post_bn",),
               before=("optimize_layout",))
def quantize(graph: Graph) -> Tuple[Graph, Dict]:
    """Annotate eligible sites with calibrated ``quant.*`` attrs per
    the request on ``graph.quant``; a no-op (zero annotations, ``quant``
    cleared) without a request or under ``mode="f32"``."""
    req = graph.quant
    if not req or req.get("mode") in (None, "f32"):
        if req:
            graph.quant = {"mode": "f32"}
        return graph, {"sites": 0}
    mode = req["mode"]
    if mode not in _MODES:
        raise ValueError(f"unknown quantization mode {mode!r}; "
                         f"expected one of {_MODES}")
    method = req.get("method", "absmax")
    on_tpu = _on_tpu()
    sites = [n for n in graph.nodes
             if n.op in QUANT_OPS and "kernel" in n.params]

    counts = {"f32": 0, "bf16": 0, "int8": 0}
    stats: Dict[str, object] = {"sites": len(sites), "mode": mode}
    ranges: Dict = {}
    need_calibration = mode in ("int8", "mixed")
    first_inputs: Dict[str, np.ndarray] = {}
    if need_calibration and sites:
        batches = int(req.get("calibrate") or DEFAULT_CALIBRATE)
        ranges, first_inputs, _ = _calibrate(graph, batches, sites)
        stats["calibrate_batches"] = batches
        stats["calibrated_tensors"] = len(ranges)

    if mode == "mixed" and sites:
        decisions, tune_report = _tune_mixed(
            sites, graph, ranges, first_inputs, req, on_tpu, method)
        stats["mixed"] = tune_report
    else:
        decisions = {n.name: (_static_site_mode(n, mode, on_tpu) or "f32")
                     for n in sites}

    for node in sites:
        site_mode = decisions.get(node.name, "f32")
        counts[site_mode] += 1
        if site_mode == "bf16":
            node.attrs["quant.mode"] = "bf16"
        elif site_mode == "int8":
            _annotate_int8(node, graph, ranges, method)
    stats.update(counts)
    # The surviving graph-level record is semantic only: the mode (and
    # per-mode site counts for introspection).  Request-side knobs that
    # must not leak into structure_hash — cache_dir, measurement
    # budgets — are consumed here and dropped.
    graph.quant = {"mode": mode, "decisions": dict(counts)}
    return graph, stats
