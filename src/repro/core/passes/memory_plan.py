"""Tensor lifetime analysis and arena planning (paper §3.2).

"the inputs and outputs of all nodes are assigned to actual memory
locations, taking into account that tensors with overlapping lifetimes
must use different memory. … the individual layer compilers can indicate
whether they want any of their outputs to use the memory of an input
tensor that is not referenced afterwards."

On TPU the XLA buffer assigner does the final allocation, but the plan
still matters twice over:

* it decides which ops are *eligible to run in place* — which the back
  end exposes to XLA via donation and via output-aliased Pallas calls;
* it is the compile-time VMEM/HBM working-set report used by the
  roofline analysis (arena bytes vs sum-of-all-tensors bytes).

The allocator is a greedy best-fit over [start, end) lifetime intervals,
processing tensors in program order, with an explicit in-place fast path
mirroring the paper's "output may use the memory of an input tensor that
is not referenced afterwards".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..graph import Graph

#: Ops whose output may alias their (first) input: elementwise or
#: shape-only ops.  Convs/matmuls cannot run in place (their input is
#: read repeatedly while outputs are produced).
INPLACE_OPS = ("activation", "batchnorm", "add", "mul", "reshape", "softmax")


@dataclasses.dataclass
class Assignment:
    offset: int
    nbytes: int
    inplace_of: Optional[str] = None


@dataclasses.dataclass
class MemoryPlan:
    assignments: Dict[str, Assignment]
    arena_bytes: int
    naive_bytes: int
    inplace_count: int

    def stats(self) -> Dict:
        return {
            "arena_bytes": self.arena_bytes,
            "naive_bytes": self.naive_bytes,
            "savings_ratio": (
                1.0 - self.arena_bytes / self.naive_bytes if self.naive_bytes else 0.0
            ),
            "inplace_count": self.inplace_count,
            "tensors": len(self.assignments),
        }


def _lifetimes(graph: Graph) -> Dict[str, Tuple[int, int]]:
    """[first-def, last-use] step index per intermediate tensor.
    Graph outputs live to the end; graph inputs from step -1."""
    order = graph.toposort()
    step_of = {node.output: i for i, node in enumerate(order)}
    last_use: Dict[str, int] = {}
    for i, node in enumerate(order):
        for t in node.inputs:
            last_use[t] = i
    n = len(order)
    lifetimes: Dict[str, Tuple[int, int]] = {}
    for name in graph.inputs:
        lifetimes[name] = (-1, last_use.get(name, -1))
    for node in order:
        t = node.output
        end = n if t in graph.outputs else last_use.get(t, step_of[t])
        lifetimes[t] = (step_of[t], end)
    return lifetimes


def plan_memory(graph: Graph, alignment: int = 128) -> MemoryPlan:
    """Greedy interval-based arena allocation with in-place reuse.

    ``alignment`` defaults to 128 bytes (TPU lane width × f32; the paper
    aligned to 16-byte XMM boundaries — same idea, different hardware).
    """
    specs = graph.infer_shapes()
    lifetimes = _lifetimes(graph)
    order = graph.toposort()

    def aligned(n: int) -> int:
        return -(-n // alignment) * alignment

    assignments: Dict[str, Assignment] = {}
    # Graph inputs each get their own space at the start of the arena.
    cursor = 0
    for name in graph.inputs:
        nbytes = aligned(specs[name].nbytes)
        assignments[name] = Assignment(offset=cursor, nbytes=nbytes)
        cursor += nbytes

    # live blocks: list of (offset, nbytes, tensor, end_step)
    live: List[Tuple[int, int, str, int]] = [
        (assignments[n].offset, assignments[n].nbytes, n, lifetimes[n][1])
        for n in graph.inputs
    ]
    arena_end = cursor
    inplace_count = 0

    for step, node in enumerate(order):
        t = node.output
        nbytes = aligned(specs[t].nbytes)

        # Expire blocks whose lifetime ended before this step.
        live = [blk for blk in live if blk[3] >= step]

        # In-place fast path: elementwise/shape ops whose first input
        # dies at this exact step and whose buffer is large enough.
        placed = False
        if node.op in INPLACE_OPS and node.inputs:
            src = node.inputs[0]
            src_assign = assignments.get(src)
            if (
                src_assign is not None
                and lifetimes[src][1] == step
                and src_assign.nbytes >= nbytes
                and src not in graph.outputs
            ):
                assignments[t] = Assignment(
                    offset=src_assign.offset, nbytes=nbytes, inplace_of=src
                )
                live.append((src_assign.offset, nbytes, t, lifetimes[t][1]))
                inplace_count += 1
                placed = True

        if not placed:
            # Best-fit search over gaps between live blocks.
            blocks = sorted(b for b in live)
            best_gap: Optional[int] = None
            best_size = None
            prev_end = 0
            for off, size, _, _ in blocks:
                gap = off - prev_end
                if gap >= nbytes and (best_size is None or gap < best_size):
                    best_gap, best_size = prev_end, gap
                prev_end = max(prev_end, off + size)
            if best_gap is None:
                best_gap = prev_end
            assignments[t] = Assignment(offset=best_gap, nbytes=nbytes)
            live.append((best_gap, nbytes, t, lifetimes[t][1]))
            arena_end = max(arena_end, best_gap + nbytes)

    naive = sum(aligned(specs[t].nbytes) for t in lifetimes)
    return MemoryPlan(
        assignments=assignments,
        arena_bytes=arena_end,
        naive_bytes=naive,
        inplace_count=inplace_count,
    )
