"""repro.replay — re-run a capture bundle and diff it against the record.

A capture bundle (``repro.api.capture``) is one directory holding
everything a compile decided: the input graph, the options, per-pass IR,
every tactic-cache entry it used, the resolved kernel/graph-decision
selections, and recorded input/output tensors.  This module is the other
half of the contract::

    python -m repro.replay <bundle>

re-runs the full pipeline from the bundle in the current process — a
fresh temp cache seeded with the bundle's tactic entries, autotune
downgraded ``full`` → ``cached`` so nothing is re-measured — and diffs

* the pass pipeline actually run,
* every graph-level decision winner (fusion / layout / pipeline),
* the resolved kernel selection per recorded batch (kernel + block),
* the outputs on the recorded inputs (exact by default, ``--tol`` for
  an allclose bound),

against what the bundle recorded.  Exit codes: **0** bundle reproduces,
**1** any divergence, **2** the bundle is unreadable or tampered with
(manifest hash mismatch).  One command to reproduce any perf or
accuracy regression offline.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Dict, List, Optional

import numpy as np

from ..api.capture import CAPTURE_FORMAT_VERSION, MANIFEST


class BundleError(Exception):
    """The bundle is unreadable, unsupported, or fails hash
    verification — replay exit code 2."""


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def load_manifest(bundle: str) -> dict:
    """Read and structurally validate MANIFEST.json."""
    path = os.path.join(bundle, MANIFEST)
    if not os.path.isdir(bundle) or not os.path.exists(path):
        raise BundleError(f"{bundle!r} is not a capture bundle "
                          f"(no {MANIFEST})")
    try:
        with open(path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise BundleError(f"unreadable {MANIFEST}: {e}") from e
    if manifest.get("format") != "repro-capture":
        raise BundleError(f"not a repro capture bundle: "
                          f"format={manifest.get('format')!r}")
    if manifest.get("version", 0) > CAPTURE_FORMAT_VERSION:
        raise BundleError(f"bundle version {manifest['version']} is newer "
                          f"than this repro ({CAPTURE_FORMAT_VERSION})")
    return manifest


def verify_bundle(bundle: str, manifest: dict) -> None:
    """Check every file named by the manifest exists and hashes to its
    recorded sha256 — the tamper seal.  Raises :class:`BundleError`."""
    for rel, want in sorted(manifest.get("files", {}).items()):
        path = os.path.join(bundle, rel)
        if not os.path.exists(path):
            raise BundleError(f"bundle file missing: {rel}")
        got = _sha256(path)
        if got != want:
            raise BundleError(
                f"bundle file tampered: {rel} (sha256 {got[:12]}… != "
                f"recorded {want[:12]}…)")


def _selection_identity(sel: Dict[str, dict]) -> Dict[str, tuple]:
    """The comparable identity of a kernel selection: which kernel and
    which block geometry per node (reasons and µs tables are
    presentation, not identity)."""
    out = {}
    for name, c in sel.items():
        block = c.get("block")
        out[name] = (c.get("op"), c.get("kernel"),
                     tuple(block) if block else None)
    return out


def _decision_identity(report: Optional[dict]) -> List[tuple]:
    """Comparable identity of the graph-decision report: per site, the
    kind/node/digest and the winning choice (source — cached vs measured
    — is expected to differ between capture and replay)."""
    if not report:
        return []
    return sorted(
        (row.get("kind"), row.get("node"), row.get("digest"),
         row.get("winner"))
        for row in report.get("sites", []))


def replay_bundle(bundle: str, *, tol: float = 0.0,
                  verbose: bool = True) -> dict:
    """Re-run the compile recorded in ``bundle`` and diff it.

    Returns a result dict with ``divergences`` (list of human-readable
    strings; empty = clean) plus per-section detail.  Raises
    :class:`BundleError` for an invalid/tampered bundle.
    """
    import repro
    from repro import CompileOptions
    from ..autotune.cache import TACTICS_SUBDIR, environment_fingerprint
    from ..frontends.container import load_model

    manifest = load_manifest(bundle)
    verify_bundle(bundle, manifest)

    def say(msg: str) -> None:
        if verbose:
            print(msg)

    divergences: List[str] = []
    fp = environment_fingerprint()
    if manifest.get("fingerprint") != fp:
        # Not fatal: the tactic cache will reject the seeded entries and
        # the pipeline falls back to heuristics — almost certainly a
        # divergence, but the diff below says exactly where.
        say("warning: environment fingerprint differs from the capture "
            "(jax version / backend / kernels changed); seeded tactics "
            "will be ignored")

    graph = load_model(os.path.join(bundle, "graph.npz"))
    with open(os.path.join(bundle, "options.json")) as f:
        options = CompileOptions.from_dict(json.load(f))
    with open(os.path.join(bundle, "report.json")) as f:
        recorded = json.load(f)

    with tempfile.TemporaryDirectory(prefix="repro-replay-") as td:
        # Seed a fresh cache root with the bundle's tactic entries; with
        # autotune="cached" the compile resolves every decision from
        # them, deterministically, measuring nothing.
        tactics_src = os.path.join(bundle, "tactics")
        tactics_dst = os.path.join(td, TACTICS_SUBDIR)
        os.makedirs(tactics_dst, exist_ok=True)
        if os.path.isdir(tactics_src):
            for name in os.listdir(tactics_src):
                shutil.copy(os.path.join(tactics_src, name),
                            os.path.join(tactics_dst, name))
        options = options.replace(
            cache_dir=td,
            autotune="cached" if options.autotune == "full"
                     else options.autotune,
            capture=None, dump_ir=None, buckets=None, batch_buckets=())
        exe = repro.compile(graph, options)

        # -- pipeline + graph decisions --------------------------------
        got_pipeline = list(exe.report.get("pipeline", ()))
        want_pipeline = list(recorded.get("pipeline", got_pipeline))
        if got_pipeline != want_pipeline:
            divergences.append(
                f"pass pipeline: recorded {want_pipeline}, "
                f"replayed {got_pipeline}")
        want_dec = _decision_identity(recorded.get("graph_decisions"))
        got_dec = _decision_identity(
            getattr(exe, "_decisions_report", None))
        if want_dec != got_dec:
            divergences.append(
                f"graph decisions: recorded {want_dec}, replayed {got_dec}")
        say(f"pipeline: {len(got_pipeline)} passes, "
            f"{len(got_dec)} graph decisions")

        # -- per-batch selection + outputs -----------------------------
        batches = manifest.get("batches", [])
        for batch in batches:
            rel = os.path.join(bundle, "batches", str(batch))
            fn = exe.ensure_compiled(batch)
            with open(os.path.join(rel, "selection.json")) as f:
                want_sel = _selection_identity(json.load(f))
            got_sel = _selection_identity({
                name: c.to_dict() for name, c in
                getattr(exe, "_selections", {}).get(batch, {}).items()})
            if want_sel != got_sel:
                only_want = {k: v for k, v in want_sel.items()
                             if got_sel.get(k) != v}
                only_got = {k: v for k, v in got_sel.items()
                            if want_sel.get(k) != v}
                divergences.append(
                    f"batch {batch} kernel selection: recorded "
                    f"{only_want}, replayed {only_got}")
            io = np.load(os.path.join(rel, "io.npz"))
            ins = [io[f"in::{n}"] for n in exe.graph.inputs]
            out = fn(*ins)
            for k in io.files:
                if not k.startswith("out::"):
                    continue
                name = k[len("out::"):]
                got = np.asarray(out[name])
                want = io[k]
                if tol > 0:
                    ok = np.allclose(got, want, rtol=tol, atol=tol)
                else:
                    ok = (got.shape == want.shape
                          and np.array_equal(got, want))
                if not ok:
                    err = float(np.max(np.abs(
                        got.astype(np.float64) - want.astype(np.float64))))
                    divergences.append(
                        f"batch {batch} output {name!r}: max abs diff "
                        f"{err:.3e}"
                        + ("" if tol == 0 else f" (tol {tol})"))
            say(f"batch {batch}: {len(got_sel)} kernel choices, "
                f"{sum(1 for k in io.files if k.startswith('out::'))} "
                f"outputs compared")

    return {
        "bundle": bundle,
        "fingerprint_match": manifest.get("fingerprint") == fp,
        "batches": manifest.get("batches", []),
        "divergences": divergences,
    }


__all__ = ["BundleError", "load_manifest", "replay_bundle", "verify_bundle"]
