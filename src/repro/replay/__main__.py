"""CLI entry point: ``python -m repro.replay <bundle> [--tol F] [--json]``.

Exit codes: 0 = the bundle reproduces bit-identically (or within
``--tol``), 1 = any selection/decision/output divergence, 2 = the bundle
is unreadable or fails its manifest hash check (tampered).
"""

from __future__ import annotations

import argparse
import json
import sys

from . import BundleError, replay_bundle


def main(argv=None) -> int:
    """Parse args, replay the bundle, translate results to exit codes."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.replay",
        description="Re-run a repro capture bundle and diff it against "
                    "the recorded compile.")
    ap.add_argument("bundle", help="path to the capture bundle directory")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="allclose tolerance for output comparison "
                         "(default 0 = bit-exact)")
    ap.add_argument("--json", action="store_true",
                    help="print the result as JSON instead of prose")
    args = ap.parse_args(argv)

    try:
        result = replay_bundle(args.bundle, tol=args.tol,
                               verbose=not args.json)
    except BundleError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.json:
        print(json.dumps(result, indent=2, default=str))
    if result["divergences"]:
        if not args.json:
            for d in result["divergences"]:
                print(f"DIVERGENCE: {d}")
            print(f"replay FAILED: {len(result['divergences'])} "
                  f"divergence(s)")
        return 1
    if not args.json:
        print("replay OK: bundle reproduces the recorded compile")
    return 0


if __name__ == "__main__":
    sys.exit(main())
