"""Async sharded checkpointer with elastic restore.

Layout (one directory per step):

    <root>/step_000123.tmp/     # written here first
        manifest.json           # tree structure, shapes, dtypes, step
        arr_00000.npy ...       # one file per leaf (host-side numpy)
    <root>/step_000123/         # atomic os.rename when complete

Fault-tolerance properties:
* **atomicity** — a crash mid-save leaves only a ``.tmp`` dir, which
  restore ignores and the next save garbage-collects;
* **async** — saving runs on a background thread over host copies of
  the arrays, so the train loop is blocked only for the device→host
  transfer, not the disk write;
* **keep-N** — bounded disk usage;
* **elastic restore** — arrays are stored unsharded (host view); on
  restore they are ``device_put`` with the *current* mesh's
  NamedShardings, so a job restarted on a different mesh shape (e.g.
  256 chips instead of 512 after losing a pod) reshards transparently;
* **preemption hook** — ``install_sigterm_hook`` saves on SIGTERM.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


class Checkpointer:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot `tree` (pytree of arrays) at `step`."""
        self.wait()  # one in-flight save at a time
        # device -> host while still synchronous (cheap vs disk write).
        # Non-numpy dtypes (bfloat16) are stored as same-width uint views
        # (npy can't round-trip ml_dtypes descriptors).
        leaves, treedef = jax.tree.flatten(tree)
        host = []
        for l in leaves:
            a = np.asarray(l)
            if a.dtype.kind not in "biufc":
                a = a.view(np.dtype(f"u{a.dtype.itemsize}"))
            host.append(a)
        spec = jax.tree.unflatten(treedef, list(range(len(host))))

        def work():
            try:
                name = f"step_{step:09d}"
                tmp = os.path.join(self.root, name + ".tmp")
                final = os.path.join(self.root, name)
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                for i, arr in enumerate(host):
                    np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
                manifest = {
                    "step": step,
                    "n_leaves": len(host),
                    "treedef": json.loads(
                        json.dumps(jax.tree.map(int, spec))),
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)        # atomic publish
                self._gc()
            except BaseException as e:  # surfaced by wait()
                self._error = e

        if blocking:
            work()
            self.raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.raise_if_failed()

    def raise_if_failed(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)
        for d in os.listdir(self.root):           # stale tmp dirs
            if d.endswith(".tmp"):
                full = os.path.join(self.root, d)
                if not (self._thread and self._thread.is_alive()):
                    shutil.rmtree(full, ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.root, d,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> Any:
        """Load `step` into the structure of `like`.  With `shardings`
        (pytree of NamedSharding, same structure) the arrays are placed
        sharded on the *current* mesh — the elastic-restart path."""
        path = os.path.join(self.root, f"step_{step:09d}")
        leaves, treedef = jax.tree.flatten(like)
        host = []
        for i, l in enumerate(leaves):
            h = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
            want = np.dtype(l.dtype)
            if want.kind not in "biufc" and h.dtype.kind == "u" \
                    and h.dtype.itemsize == want.itemsize:
                h = h.view(want)            # bf16 round-trip via uint view
            host.append(h)
        for h, l in zip(host, leaves):
            if tuple(h.shape) != tuple(l.shape):
                raise ValueError(
                    f"checkpoint leaf shape {h.shape} != expected {l.shape}")
        if shardings is None:
            arrs = [jax.numpy.asarray(h).astype(l.dtype)
                    for h, l in zip(host, leaves)]
        else:
            shard_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            arrs = [jax.device_put(np.asarray(h, dtype=l.dtype)
                                   if h.dtype != np.dtype(l.dtype) else h, s)
                    for h, l, s in zip(host, leaves, shard_leaves)]
        return jax.tree.unflatten(treedef, arrs)


def install_sigterm_hook(save_fn: Callable[[], None]) -> None:
    """Preemption handling: checkpoint before the scheduler kills us."""
    def handler(signum, frame):
        save_fn()
        raise SystemExit(143)
    signal.signal(signal.SIGTERM, handler)
