from .checkpointer import Checkpointer, install_sigterm_hook
