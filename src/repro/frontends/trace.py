"""``repro.trace`` — compile plain Python functions.

The tracer runs a user callable once over *abstract* arguments
(:class:`TracedTensor`, shape+dtype only, no data) and records every
operation into the graph IR, so a model written as an ordinary function
in this module's small jnp-like namespace compiles through the full
pass/selection/kernel pipeline on every target::

    import numpy as np
    import repro
    from repro.frontends import ops as F

    w = np.random.default_rng(0).standard_normal((3, 4), np.float32)

    def model(image):
        h = F.relu(F.dense(F.global_avg_pool(image), w))
        return {"probs": F.softmax(h)}

    graph = repro.trace(model, (8, 8, 3))          # specs exclude batch
    exe = repro.compile(graph, repro.CompileOptions(target="jit"))
    exe(image=x)["probs"]                           # named I/O end to end

Weights are plain numpy arrays closed over (or passed into) the
function; the tracer interns them as graph params — passing the *same*
array object twice shares one param (weight tying).  Input names come
from the function's parameter names; output names from the returned
dict's keys (a bare tensor becomes ``"output"``, a tuple
``"output_0"``, ``"output_1"``, …) — together they form the graph's
:class:`~repro.core.graph.Signature`.

This is one op-recording abstraction level up from ``jax.make_jaxpr``:
it records *graph-IR* ops (dense/conv2d/…), not lax primitives, so the
result is exactly what ``ModelBuilder`` would have built.
"""

from __future__ import annotations

import inspect
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.graph import ACTIVATIONS, Graph, Signature, TensorSpec


class TraceError(TypeError):
    """A traced function did something the tracer cannot record."""


class TracedTensor:
    """Abstract value flowing through a traced function: a tensor name
    plus its static spec.  Supports ``+``, ``*`` (elementwise, against
    tensors or numpy constants) and ``@`` (dense against a numpy
    kernel); everything else goes through the :mod:`ops <.trace>`
    namespace."""

    __slots__ = ("tracer", "name", "spec")

    # Make numpy defer to __radd__/__rmul__ when a TracedTensor is the
    # RIGHT operand of an ndarray (`w * x`): without this, ndarray.__mul__
    # would broadcast elementwise over the abstract tensor and emit one
    # stray node per element instead of a single op.
    __array_ufunc__ = None

    def __init__(self, tracer: "Tracer", name: str, spec: TensorSpec) -> None:
        self.tracer = tracer
        self.name = name
        self.spec = spec

    # -- numpy-ish surface ---------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.spec.shape

    @property
    def dtype(self) -> str:
        return self.spec.dtype

    @property
    def ndim(self) -> int:
        return len(self.spec.shape)

    def reshape(self, shape: Sequence[int]) -> "TracedTensor":
        return reshape(self, shape)

    def flatten(self) -> "TracedTensor":
        return flatten(self)

    def __add__(self, other):
        return add(self, other)

    __radd__ = __add__

    def __mul__(self, other):
        return mul(self, other)

    __rmul__ = __mul__

    def __matmul__(self, kernel):
        return dense(self, kernel)

    def __repr__(self) -> str:
        return f"TracedTensor({self.name!r}, shape={self.shape}, dtype={self.dtype})"

    def __bool__(self):
        raise TraceError(
            f"cannot branch on the value of abstract tensor {self.name!r}: "
            f"the tracer records a static graph (shapes are available as "
            f"`.shape` for Python-level control flow)")


class Tracer:
    """Records ops emitted on its :class:`TracedTensor`\\ s into a Graph."""

    def __init__(self) -> None:
        self.graph = Graph()
        self._counts: Dict[str, int] = {}
        self._param_memo: Dict[int, str] = {}

    def _name(self, kind: str) -> str:
        n = self._counts.get(kind, 0) + 1
        self._counts[kind] = n
        return f"{kind}_{n}"

    def add_input(self, name: str, spec: TensorSpec) -> TracedTensor:
        self.graph.add_input(name, spec.shape, spec.dtype)
        return TracedTensor(self, name, spec)

    def intern_param(self, node_name: str, role: str, value) -> str:
        """Register a weight array as a graph param; the same array
        *object* maps to the same param (weight tying)."""
        key = id(value)
        if key in self._param_memo:
            return self._param_memo[key][1]
        arr = np.asarray(value, dtype=np.float32)
        pname = self.graph.add_param(f"{node_name}/{role}", arr)
        # The memo is id()-keyed, so it must keep ``value`` alive: a
        # collected temporary's id could be recycled for a *different*
        # array, which would silently alias two distinct weights.
        self._param_memo[key] = (value, pname)
        return pname

    def emit(self, op: str, kind: str, inputs: Sequence[TracedTensor],
             attrs: Optional[dict] = None,
             params: Optional[Dict[str, Any]] = None) -> TracedTensor:
        """Append one IR node; returns the traced output tensor."""
        for t in inputs:
            if t.tracer is not self:
                raise TraceError(
                    f"tensor {t.name!r} belongs to a different trace")
        name = self._name(kind)
        pnames = {role: self.intern_param(name, role, v)
                  for role, v in (params or {}).items()}
        out = self.graph.add_node(op, name, [t.name for t in inputs],
                                  attrs=attrs, params=pnames)
        return TracedTensor(self, out, self.graph.spec(out))


def _as_spec(s) -> TensorSpec:
    if isinstance(s, TensorSpec):
        return s
    if isinstance(s, (tuple, list)) and all(isinstance(d, int) for d in s):
        return TensorSpec(tuple(s))
    raise TypeError(
        f"input spec must be a TensorSpec or a shape tuple (batch dim "
        f"excluded), got {s!r}")


def _input_names(fn, n: int, given: Optional[Sequence[str]]) -> List[str]:
    if given is not None:
        if len(given) != n:
            raise TypeError(f"{len(given)} input_names for {n} specs")
        return list(given)
    try:
        params = [p.name for p in inspect.signature(fn).parameters.values()
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    except (TypeError, ValueError):
        params = []
    if len(params) >= n:
        return params[:n]
    return [f"input_{i}" if n > 1 else "input" for i in range(n)]


def trace(fn, *specs, input_names: Optional[Sequence[str]] = None) -> Graph:
    """Trace ``fn`` over abstract inputs and return the recorded graph.

    Each spec is a :class:`TensorSpec` or a bare shape tuple (the batch
    dimension is excluded, as everywhere in the IR).  ``fn`` receives
    one :class:`TracedTensor` per spec and must return a traced tensor,
    a tuple of them, or a dict of user-chosen output names to tensors —
    the dict form names the outputs in the resulting
    :class:`~repro.core.graph.Signature`.
    """
    if not specs:
        raise TypeError("trace() needs at least one input spec")
    tracer = Tracer()
    names = _input_names(fn, len(specs), input_names)
    args = [tracer.add_input(n, _as_spec(s)) for n, s in zip(names, specs)]
    result = fn(*args)

    if isinstance(result, TracedTensor):
        outputs: List[Tuple[str, TracedTensor]] = [("output", result)]
    elif isinstance(result, dict):
        outputs = list(result.items())
    elif isinstance(result, (tuple, list)):
        outputs = [(f"output_{i}", t) for i, t in enumerate(result)]
    else:
        raise TraceError(
            f"traced function must return a TracedTensor, tuple, or dict "
            f"of them; got {type(result).__name__}")
    for pub, t in outputs:
        if not isinstance(t, TracedTensor):
            raise TraceError(f"output {pub!r} is {type(t).__name__}, "
                             f"not a TracedTensor")
        if t.tracer is not tracer:
            raise TraceError(f"output {pub!r} belongs to a different trace")
    tracer.graph.set_outputs({pub: t.name for pub, t in outputs})
    return tracer.graph


# ---------------------------------------------------------------------------
# The jnp-like namespace (re-exported as ``repro.frontends.ops``).
# Functions mirror ModelBuilder's layer vocabulary 1:1, so a traced
# function and the equivalent builder model produce the same IR.
# ---------------------------------------------------------------------------
def _tracer_of(*tensors) -> Tracer:
    for t in tensors:
        if isinstance(t, TracedTensor):
            return t.tracer
    raise TraceError("expected at least one TracedTensor argument")


def constant(tracer_or_tensor, value, shape: Optional[Tuple[int, ...]] = None
             ) -> TracedTensor:
    """Materialize a numpy value as a graph constant (broadcast to
    ``shape`` if given — scalars become full tensors so elementwise ops
    see matching shapes)."""
    tracer = (tracer_or_tensor.tracer
              if isinstance(tracer_or_tensor, TracedTensor)
              else tracer_or_tensor)
    v = np.asarray(value, dtype=np.float32)
    if shape is not None and tuple(v.shape) != tuple(shape):
        v = np.ascontiguousarray(np.broadcast_to(v, shape))
    return tracer.emit("constant", "const", [], params={"value": v})


def _coerce(x, like: TracedTensor) -> TracedTensor:
    if isinstance(x, TracedTensor):
        return x
    return constant(like.tracer, x, shape=like.shape)


def add(a, b) -> TracedTensor:
    t = _tracer_of(a, b)
    ref = a if isinstance(a, TracedTensor) else b
    return t.emit("add", "add", [_coerce(a, ref), _coerce(b, ref)])


def mul(a, b) -> TracedTensor:
    t = _tracer_of(a, b)
    ref = a if isinstance(a, TracedTensor) else b
    return t.emit("mul", "mul", [_coerce(a, ref), _coerce(b, ref)])


def dense(x: TracedTensor, kernel, bias=None,
          activation: Optional[str] = None) -> TracedTensor:
    """``x @ kernel (+ bias)``; kernel is a numpy array of (cin, cout)."""
    params = {"kernel": kernel}
    if bias is not None:
        params["bias"] = bias
    out = x.tracer.emit("dense", "dense", [x], params=params)
    return _activation(out, activation) if activation else out


def conv2d(x: TracedTensor, kernel, bias=None, strides=(1, 1),
           padding="same", activation: Optional[str] = None) -> TracedTensor:
    """NHWC conv; kernel is (kh, kw, cin, cout)."""
    params = {"kernel": kernel}
    if bias is not None:
        params["bias"] = bias
    out = x.tracer.emit(
        "conv2d", "conv2d", [x],
        attrs={"strides": tuple(strides), "padding": padding}, params=params)
    return _activation(out, activation) if activation else out


def depthwise_conv2d(x: TracedTensor, kernel, bias=None, strides=(1, 1),
                     padding="same",
                     activation: Optional[str] = None) -> TracedTensor:
    """Depthwise NHWC conv; kernel is (kh, kw, c, mult)."""
    params = {"kernel": kernel}
    if bias is not None:
        params["bias"] = bias
    out = x.tracer.emit(
        "depthwise_conv2d", "dwconv2d", [x],
        attrs={"strides": tuple(strides), "padding": padding}, params=params)
    return _activation(out, activation) if activation else out


def batchnorm(x: TracedTensor, gamma, beta, mean, var,
              epsilon: float = 1e-3) -> TracedTensor:
    return x.tracer.emit(
        "batchnorm", "bn", [x], attrs={"epsilon": epsilon},
        params={"gamma": gamma, "beta": beta, "mean": mean, "var": var})


def _activation(x: TracedTensor, fn: str, **attrs) -> TracedTensor:
    if fn not in ACTIVATIONS:
        raise TraceError(f"unknown activation {fn!r}; "
                         f"known: {sorted(ACTIVATIONS)}")
    return x.tracer.emit("activation", f"act_{fn}", [x],
                         attrs={"fn": fn, **attrs})


activation = _activation


def relu(x):
    return _activation(x, "relu")


def relu6(x):
    return _activation(x, "relu6")


def leaky_relu(x, alpha: float = 0.2):
    return _activation(x, "leaky_relu", alpha=alpha)


def sigmoid(x):
    return _activation(x, "sigmoid")


def tanh(x):
    return _activation(x, "tanh")


def elu(x):
    return _activation(x, "elu")


def hard_sigmoid(x):
    return _activation(x, "hard_sigmoid")


def maxpool(x: TracedTensor, pool_size=(2, 2), strides=None,
            padding="valid") -> TracedTensor:
    return x.tracer.emit(
        "maxpool2d", "maxpool", [x],
        attrs={"pool_size": tuple(pool_size),
               "strides": tuple(strides or pool_size), "padding": padding})


def avgpool(x: TracedTensor, pool_size=(2, 2), strides=None,
            padding="valid") -> TracedTensor:
    return x.tracer.emit(
        "avgpool2d", "avgpool", [x],
        attrs={"pool_size": tuple(pool_size),
               "strides": tuple(strides or pool_size), "padding": padding})


def global_avg_pool(x: TracedTensor) -> TracedTensor:
    return x.tracer.emit("global_avg_pool", "gap", [x])


def upsample(x: TracedTensor, factor: int = 2) -> TracedTensor:
    return x.tracer.emit("upsample2d", "up", [x], attrs={"factor": factor})


def zero_pad(x: TracedTensor, padding=((1, 1), (1, 1))) -> TracedTensor:
    return x.tracer.emit("zero_pad2d", "pad", [x],
                         attrs={"padding": tuple(map(tuple, padding))})


def concat(xs: Sequence[TracedTensor], axis: int = -1) -> TracedTensor:
    t = _tracer_of(*xs)
    axis = axis % len(xs[0].shape)
    return t.emit("concat", "concat", list(xs), attrs={"axis": axis})


def reshape(x: TracedTensor, shape: Sequence[int]) -> TracedTensor:
    return x.tracer.emit("reshape", "reshape", [x],
                         attrs={"shape": tuple(shape)})


def flatten(x: TracedTensor) -> TracedTensor:
    return x.tracer.emit("flatten", "flatten", [x])


def softmax(x: TracedTensor, axis: int = -1) -> TracedTensor:
    return x.tracer.emit("softmax", "softmax", [x], attrs={"axis": axis})


def decode_attention(q: TracedTensor, k_cache: TracedTensor,
                     v_cache: TracedTensor,
                     lengths: Optional[TracedTensor] = None,
                     scale: Optional[float] = None) -> TracedTensor:
    ins = [q, k_cache, v_cache] + ([lengths] if lengths is not None else [])
    attrs = {} if scale is None else {"scale": float(scale)}
    return q.tracer.emit("decode_attention", "attn", ins, attrs=attrs)
