"""The ``.npz`` + JSON model container — save/load a Graph with weights.

This is the stand-in for the paper's Keras-HDF5 flow ("the Model class
allows to load a network … as written by the Python library Keras"): a
model authored elsewhere is serialized into a single file and ingested
at runtime, then JIT-compiled.  The format is an ``.npz`` archive whose
``__header__`` member is a JSON description of the graph (inputs,
nodes, outputs, public output names) and whose ``param::*`` members are
the weight arrays.

Moved here from ``repro.core.keras_like`` (which keeps warn-once
shims); the registered ``"container"`` frontend lets
``repro.compile("model.npz")`` ingest a file directly.
"""

from __future__ import annotations

import json

import numpy as np

from ..core.graph import Graph, Node

CONTAINER_SUFFIX = ".npz"


def save_model(graph: Graph, path) -> None:
    """Serialize graph + weights; ``path`` is a filename or file object."""
    header = {
        "inputs": {k: {"shape": v.shape, "dtype": v.dtype}
                   for k, v in graph.inputs.items()},
        "outputs": graph.outputs,
        "output_names": graph.output_names,
        "nodes": [
            {"op": n.op, "name": n.name, "inputs": n.inputs, "output": n.output,
             "attrs": _jsonify(n.attrs), "params": n.params,
             "epilogue": n.epilogue, "epilogue_attrs": _jsonify(n.epilogue_attrs)}
            for n in graph.nodes
        ],
    }
    if graph.quant:
        # Graph-level quantization record (mode + decision counts);
        # the per-site scales live in the node attrs above.
        header["quant"] = _jsonify(graph.quant)
    arrays = {f"param::{k}": v for k, v in graph.params.items()}
    arrays["__header__"] = np.frombuffer(
        json.dumps(header).encode(), dtype=np.uint8)
    np.savez(path, **arrays)


def load_model(path) -> Graph:
    """Load a container back into a :class:`Graph` (public output names
    included; containers written before they existed default them to
    the tensor names)."""
    data = np.load(path, allow_pickle=False)
    header = json.loads(bytes(data["__header__"]).decode())
    g = Graph()
    for name, spec in header["inputs"].items():
        g.add_input(name, spec["shape"], spec["dtype"])
    for k in data.files:
        if k.startswith("param::"):
            g.add_param(k[len("param::"):], data[k])
    for nd in header["nodes"]:
        node = Node(op=nd["op"], name=nd["name"], inputs=nd["inputs"],
                    output=nd["output"], attrs=_tuplify(nd["attrs"]),
                    params=nd["params"], epilogue=nd["epilogue"],
                    epilogue_attrs=_tuplify(nd["epilogue_attrs"]))
        g.nodes.append(node)
    g.rebuild_index()
    if header.get("quant"):
        g.quant = header["quant"]
    names = header.get("output_names")
    if names and names != header["outputs"]:
        g.set_outputs(dict(zip(names, header["outputs"])))
    else:
        g.set_outputs(header["outputs"])
    return g


def _jsonify(obj):
    if isinstance(obj, dict):
        return {k: _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (tuple, list)):
        return [_jsonify(v) for v in obj]
    return obj


def _tuplify(obj):
    """JSON round-trips tuples as lists; the IR uses tuples for shapes
    and paddings, so convert lists (recursively) back to tuples."""
    if isinstance(obj, dict):
        return {k: _tuplify(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return tuple(_tuplify(v) for v in obj)
    return obj
