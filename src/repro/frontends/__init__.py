"""repro.frontends — model ingestion behind a registry.

A *frontend* normalizes some external model description into the
:class:`~repro.core.graph.Graph` IR, mirroring how targets
(``@register_target``), passes (``@register_pass``) and lowerings
(``@register_lowering``) plug into the rest of the compiler::

    from repro.frontends import Frontend, register_frontend

    @register_frontend("my-format")
    class MyFrontend(Frontend):
        def accepts(self, model):
            return isinstance(model, MyModelDescription)
        def to_graph(self, model, **kw):
            return build_graph_from(model)

``repro.compile`` consults the registry for any model it does not
natively understand, so new ingestion paths never edit the dispatch.
Built-ins (registered by :mod:`.builder`): ``"graph"`` (identity),
``"builder"`` (ModelBuilder), ``"container"`` (``.npz`` files, see
:mod:`.container`) and ``"trace"`` (bare callables, see :mod:`.trace` —
the ``repro.trace`` entry point).
"""

from __future__ import annotations

import abc
from typing import Dict, Tuple

from ..core.graph import Graph


class Frontend(abc.ABC):
    """Normalizes one family of model descriptions into the Graph IR."""

    name: str = "?"

    @abc.abstractmethod
    def accepts(self, model) -> bool:
        """Cheap structural test: can this frontend ingest ``model``?"""

    @abc.abstractmethod
    def to_graph(self, model, **kw) -> Graph:
        """Ingest ``model``; keyword args carry frontend-specific
        options (e.g. the trace frontend's ``example_inputs``)."""


_FRONTENDS: Dict[str, Frontend] = {}


def register_frontend(name: str):
    """Decorator: register a :class:`Frontend` subclass (or instance)
    under ``name`` (overwrites).  Resolution tries frontends in
    registration order."""

    def deco(obj):
        frontend = obj() if isinstance(obj, type) else obj
        frontend.name = name
        _FRONTENDS[name] = frontend
        return obj

    return deco


def get_frontend(name: str) -> Frontend:
    try:
        return _FRONTENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown frontend {name!r}; available: {available_frontends()}"
        ) from None


def available_frontends() -> Tuple[str, ...]:
    return tuple(sorted(_FRONTENDS))


def resolve(model, *, frontend: str = None, **kw) -> Graph:
    """Normalize ``model`` to a Graph via the first accepting frontend
    (or the named one).  Raises ``TypeError`` naming the registered
    frontends when nothing accepts the model."""
    if frontend is not None:
        return get_frontend(frontend).to_graph(model, **kw)
    for fe in _FRONTENDS.values():
        if fe.accepts(model):
            return fe.to_graph(model, **kw)
    raise TypeError(
        f"cannot compile {type(model).__name__}: expected a Graph, an "
        f"ArchConfig/Model (with target='engine'), or a model accepted "
        f"by a registered frontend ({', '.join(available_frontends())}). "
        f"Bare callables compile via repro.compile(fn, example_inputs=…) "
        f"or repro.trace(fn, *specs); register new model formats with "
        f"@register_frontend")


from . import builder as _builtin_frontends  # noqa: E402  (self-registration)
from . import trace as ops                   # noqa: E402,F401  (the jnp-like namespace)
from .trace import trace                     # noqa: E402,F401

__all__ = [
    "Frontend",
    "available_frontends",
    "get_frontend",
    "ops",
    "register_frontend",
    "resolve",
    "trace",
]
