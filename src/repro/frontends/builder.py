"""Built-in frontends for models we already speak natively: graph IR,
``ModelBuilder``, the ``.npz`` container, and traced callables.

Each is a :class:`~repro.frontends.Frontend` registered with
``@register_frontend`` — the same plug-in seam third parties use to
teach ``repro.compile`` new model formats.
"""

from __future__ import annotations

import os

from ..core.graph import Graph, TensorSpec
from . import Frontend, register_frontend
from .container import CONTAINER_SUFFIX, load_model
from .trace import trace


@register_frontend("graph")
class GraphFrontend(Frontend):
    """The identity frontend: the model already *is* the IR."""

    def accepts(self, model) -> bool:
        return isinstance(model, Graph)

    def to_graph(self, model) -> Graph:
        return model


@register_frontend("builder")
class BuilderFrontend(Frontend):
    """Accepts a ``ModelBuilder`` whose outputs are set (or passed as
    ``outputs=``), so a builder can go straight into ``repro.compile``
    without the explicit ``.build()`` call."""

    def accepts(self, model) -> bool:
        from ..core.keras_like import ModelBuilder
        return isinstance(model, ModelBuilder)

    def to_graph(self, model, *, outputs=None) -> Graph:
        if outputs is not None:
            return model.build(outputs)
        if not model.graph.outputs:
            raise TypeError(
                "ModelBuilder has no outputs: call .build([...]) first or "
                "pass outputs=[...] to repro.compile")
        return model.graph


@register_frontend("container")
class ContainerFrontend(Frontend):
    """Accepts a path to an ``.npz`` model container — the paper's
    load-a-pretrained-file-then-compile flow."""

    def accepts(self, model) -> bool:
        return (isinstance(model, (str, os.PathLike))
                and os.fspath(model).endswith(CONTAINER_SUFFIX))

    def to_graph(self, model) -> Graph:
        return load_model(os.fspath(model))


@register_frontend("trace")
class TraceFrontend(Frontend):
    """Accepts a bare callable; needs ``specs=`` (batch-less shapes /
    TensorSpecs) or ``example_inputs=`` (arrays *with* a batch dim, as
    the callable would receive at run time) to know the input shapes."""

    def accepts(self, model) -> bool:
        return callable(model) and not isinstance(model, type)

    def to_graph(self, model, *, specs=None, example_inputs=None,
                 input_names=None) -> Graph:
        if specs is None and example_inputs is None:
            raise TypeError(
                "tracing a callable needs specs=(shape-or-TensorSpec, ...) "
                "or example_inputs=(array, ...) — arrays carry a leading "
                "batch dimension, specs do not")
        if specs is None:
            if isinstance(example_inputs, dict):
                input_names = list(example_inputs.keys())
                example_inputs = list(example_inputs.values())
            elif not isinstance(example_inputs, (tuple, list)):
                example_inputs = [example_inputs]
            specs = []
            for a in example_inputs:
                shape, dtype = tuple(a.shape), str(a.dtype)
                if not shape:
                    raise TypeError(
                        f"example input of shape {shape} has no batch "
                        f"dimension to strip")
                specs.append(TensorSpec(shape[1:], dtype))
        elif isinstance(specs, TensorSpec) or (
                isinstance(specs, (tuple, list))
                and all(isinstance(d, int) for d in specs)):
            specs = [specs]    # a single spec, not a list of specs
        return trace(model, *specs, input_names=input_names)
