"""Batched serving engine: prefill/decode with slot-level continuous
batching.

The compile-then-serve flow mirrors the paper's ``CompiledNN``: the
engine owns the cache memory layout (the paper: "input and output
tensors are owned by CompiledNN because it needs control over the
actual memory layout"), compiles `prefill` and `decode_step` once per
shape, and after that serving never interprets model structure.

Design:
* B fixed decode slots; each holds one request's KV/state cache rows.
* New requests are prefilled one at a time (exact prompt length —
  runtime specialization; repeated lengths hit jit's trace cache) and
  their cache is spliced into a free slot.
* One batched decode step advances every active slot; finished slots
  (EOS / max_tokens) are refilled from the queue — continuous batching
  at slot granularity.
* The decode step donates the cache buffers (`donate_argnums`), the
  framework-scale version of the paper's in-place memory planning.
* ``fold_norms`` runs at engine construction (compile-time weight
  rewriting, paper §3.5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model
from .fold_norms import fold_norms


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (s,) int32
    max_new_tokens: int = 32
    eos_id: int = -1              # -1 = never
    temperature: float = 0.0      # 0 = greedy


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]


class Engine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, fold: bool = True, seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        if fold:
            params, self.fold_report = fold_norms(self.cfg, params)
        else:
            self.fold_report = {"folds": 0}
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.key = jax.random.PRNGKey(seed)

        # slot bookkeeping (host side)
        self.active = [False] * slots
        self.remaining = [0] * slots
        self.eos = [-1] * slots
        self.temp = [0.0] * slots
        self.uid = [-1] * slots
        self.generated: Dict[int, List[int]] = {}
        self.queue: List[Request] = []
        self.done: List[Completion] = []
        self.last_token = np.zeros((slots, 1), np.int32)

        # compiled programs (donated cache: in-place buffer reuse)
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c))
        self._splice = jax.jit(self._splice_impl, donate_argnums=(0,),
                               static_argnums=(2,))

    # ------------------------------------------------------------------
    @staticmethod
    def _splice_impl(cache, one_cache, slot: int):
        """Copy the single-row cache `one_cache` into row `slot` of every
        batch-indexed leaf.  Leaves are (L, B, ...) except pos (B,)."""
        def put(dst, src):
            if dst.ndim == 1:                      # pos (B,)
                return dst.at[slot].set(src[0])
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))
        return jax.tree.map(put, cache, one_cache)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _fill_free_slots(self) -> None:
        for s in range(self.slots):
            if self.active[s] or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = np.asarray(req.prompt, np.int32)[None, :]
            batch = {"tokens": jnp.asarray(prompt)}
            if self.cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.n_frames, self.cfg.d_model), jnp.float32)
            if self.cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.num_image_tokens, self.cfg.vit_dim),
                    jnp.float32)
            one = self.model.init_cache(1, self.max_len)
            logits, one = self._prefill(self.params, batch, one)
            self.cache = self._splice(self.cache, one, s)
            tok = self._sample(logits[:, -1], req.temperature)
            self.last_token[s, 0] = int(tok[0])
            self.active[s] = True
            self.remaining[s] = req.max_new_tokens - 1
            self.eos[s] = req.eos_id
            self.temp[s] = req.temperature
            self.uid[s] = req.uid
            self.generated[req.uid] = [int(tok[0])]

    def _sample(self, logits: jnp.ndarray, temperature: float) -> np.ndarray:
        if temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.key, sub = jax.random.split(self.key)
        return np.asarray(
            jax.random.categorical(sub, logits / temperature, axis=-1),
            np.int32)

    def _retire(self, s: int) -> None:
        self.done.append(Completion(self.uid[s], self.generated[self.uid[s]]))
        self.active[s] = False

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: refill slots, one batched decode step.
        Returns the number of active slots advanced."""
        self._fill_free_slots()
        if not any(self.active):
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_token))
        logits = logits[:, 0]
        for s in range(self.slots):
            if not self.active[s]:
                continue
            tok = int(self._sample(logits[s:s + 1], self.temp[s])[0])
            self.generated[self.uid[s]].append(tok)
            self.last_token[s, 0] = tok
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or tok == self.eos[s]:
                self._retire(s)
        return sum(self.active)

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        """Drain the queue; returns completions in finish order."""
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return self.done
