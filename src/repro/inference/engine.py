"""Engine — DEPRECATED shim over ``repro.serve``.

The slot-level continuous-batching loop that lived here was extracted
and generalized into :mod:`repro.serve` (``Scheduler`` +
``SlotManager`` + per-request metrics).  The modern spelling::

    import repro
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    sched = repro.serve(exe, repro.SchedulerOptions(slots=4))

This class survives one deprecation cycle so existing call sites keep
working: the constructor signature, ``submit`` / ``step`` / ``run``,
and the ``cache`` / ``fold_report`` / ``done`` attributes are preserved
by delegating to a :class:`repro.serve.Scheduler`.  A single
``DeprecationWarning`` is emitted per process.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, List

from ..serve import Completion, Request, Scheduler, SchedulerOptions

__all__ = ["Engine", "Request", "Completion"]

_warned = False


def _warn_once() -> None:
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(
            "inference.Engine is deprecated; use repro.serve(executable, "
            "repro.SchedulerOptions(...)) instead",
            DeprecationWarning,
            stacklevel=3,
        )


class Engine:
    """Deprecated: serve a model via the legacy slot-loop surface."""

    def __init__(self, model, params, *, slots: int = 4,
                 max_len: int = 256, fold: bool = True,
                 seed: int = 0) -> None:
        _warn_once()
        self._sched = Scheduler(
            model, params,
            SchedulerOptions(slots=slots, max_len=max_len, fold=fold,
                             seed=seed))

    # -- legacy attribute surface --------------------------------------
    @property
    def scheduler(self) -> Scheduler:
        """The new-API scheduler this shim wraps."""
        return self._sched

    @property
    def cache(self) -> Any:
        return self._sched.slot_manager.cache

    @property
    def fold_report(self) -> Dict[str, Any]:
        return self._sched.fold_report

    @property
    def done(self) -> List[Completion]:
        return self._sched.done

    @property
    def generated(self) -> Dict[int, List[int]]:
        return self._sched.generated

    @property
    def params(self):
        return self._sched.params

    # -- legacy methods ------------------------------------------------
    def submit(self, req: Request) -> None:
        self._sched.submit(req)

    def step(self) -> int:
        return self._sched.step()

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        return self._sched.run(max_steps)
