from .engine import Engine, Request, Completion
from .fold_norms import fold_norms
