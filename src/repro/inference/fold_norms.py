"""Compile-time RMSNorm folding for the transformer zoo (paper §3.5).

The paper folds batch-norm's affine into the adjacent conv/dense weights
("adjusting the weights and biases … so that they already include the
factors of the normalization").  The modern-transformer twin: RMSNorm's
learned diagonal scale ``diag(1+γ)`` commutes into the *following*
projection:

    proj(rms(x) * (1+γ))  ==  rms(x) @ (diag(1+γ) W)

so at model-load time we set γ' = 0 and W' = diag(1+γ)·W.  One
multiplication per feature per layer disappears from every forward pass
— exactly the paper's trade: arithmetic moved from run time to compile
time because the weights are compile-time constants.  Inference-only
(the fold would corrupt gradients w.r.t. the original parametrization).

The fold leaves the *normalization* (rsqrt of the mean square) in place
— only the diagonal scale moves.  Numerics change by float-associativity
only; tests bound the drift against the unfolded oracle the same way the
paper uses SimpleNN.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp


def _scale_rows(w: jnp.ndarray, scale: jnp.ndarray, axis: int) -> jnp.ndarray:
    """w scaled by `scale` along `axis` (the fan-in dim).  2-D scales are
    (L, D) for layer-stacked weights (L on dim 0, D on `axis`)."""
    shape = [1] * w.ndim
    shape[axis] = w.shape[axis]
    if scale.ndim == 2:
        shape[0] = w.shape[0]
    return (w.astype(jnp.float32)
            * scale.reshape(shape).astype(jnp.float32)).astype(w.dtype)


def _fold_layer(cfg, lp: Dict[str, Any]) -> Tuple[Dict[str, Any], int]:
    """Fold ln1 into the attention input projections and ln2 into the
    FFN input projections of one (stacked) layer pytree."""
    lp = dict(lp)
    folds = 0
    s1 = 1.0 + lp["ln1"].astype(jnp.float32)          # (L, D)
    s2 = 1.0 + lp["ln2"].astype(jnp.float32)

    attn = dict(lp["attn"])
    if cfg.mla:
        for k in ("q_down", "kv_down"):
            attn[k] = _scale_rows(attn[k], s1, 1)
            folds += 1
    else:
        for k in ("wq", "wk", "wv"):
            attn[k] = _scale_rows(attn[k], s1, 1)
            folds += 1
    lp["attn"] = attn
    lp["ln1"] = jnp.zeros_like(lp["ln1"])

    ffn = dict(lp["ffn"])
    if cfg.n_experts:
        ffn["router"] = _scale_rows(ffn["router"], s2, 1)
        ffn["wi_gate"] = _scale_rows(ffn["wi_gate"], s2, 2)
        ffn["wi_up"] = _scale_rows(ffn["wi_up"], s2, 2)
        folds += 3
        if cfg.n_shared:
            sh = dict(ffn["shared"])
            sh["wi_gate"] = _scale_rows(sh["wi_gate"], s2, 1)
            sh["wi_up"] = _scale_rows(sh["wi_up"], s2, 1)
            ffn["shared"] = sh
            folds += 2
    else:
        ffn["wi_gate"] = _scale_rows(ffn["wi_gate"], s2, 1)
        ffn["wi_up"] = _scale_rows(ffn["wi_up"], s2, 1)
        folds += 2
    lp["ffn"] = ffn
    lp["ln2"] = jnp.zeros_like(lp["ln2"])
    return lp, folds


def fold_norms(cfg, params: Dict[str, Any]) -> Tuple[Dict[str, Any], Dict]:
    """Inference-time norm fold for transformer-family params.
    Returns (new_params, report).  No-op for families without RMSNorm
    scales adjacent to projections (whisper's LayerNorm has a bias —
    foldable in principle, left as-is; ssm/hybrid handled partially)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        return params, {"folds": 0, "note": f"family {cfg.family}: skipped"}
    params = dict(params)
    layers, folds = _fold_layer(cfg, params["layers"])
    params["layers"] = layers
    # Final norm -> unembedding (untied heads only: with tied embeddings
    # the matrix is shared with the input lookup, which must stay raw).
    if not cfg.tie_embeddings and "head" in params:
        sf = 1.0 + params["ln_f"].astype(jnp.float32)
        params["head"] = _scale_rows(params["head"], sf, 0)
        params["ln_f"] = jnp.zeros_like(params["ln_f"])
        folds += 1
    return params, {"folds": folds}
