"""minicpm-2b — llama-like dense decoder trained with the WSD schedule.
[arXiv:2404.06395]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64,
    rope_theta=1e4, mlp_act="silu", tie_embeddings=True,
    scale_embed=True, lr_schedule="wsd",
)

SMOKE = ArchConfig(
    name="minicpm-2b-smoke", family="dense",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    rope_theta=1e4, mlp_act="silu", tie_embeddings=True,
    scale_embed=True, lr_schedule="wsd", q_chunk=16, kv_chunk=32,
)
