"""recurrentgemma-9b — RG-LRU + local attention hybrid (2:1 pattern).
[arXiv:2402.19427]

long_500k RUNS: RG-LRU state is O(1) and the attention layers are
local-only (window 2048 ring cache).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    lru_width=4096, conv_width=4,
    window=2048, pattern="swa",
    rope_theta=1e4, mlp_act="gelu", tie_embeddings=True,
    scale_embed=True, logit_softcap=30.0,
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=8, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, head_dim=16,
    lru_width=64, conv_width=4,
    window=16, pattern="swa",
    rope_theta=1e4, mlp_act="gelu", tie_embeddings=True,
    scale_embed=True, logit_softcap=30.0, q_chunk=16, kv_chunk=32,
)
