"""internvl2-2b — InternLM2-1.8B backbone + InternViT frontend (STUB).
[arXiv:2404.16821]

Per the assignment spec the ViT is a stub: ``input_specs`` provides
precomputed patch embeddings (B, 256, vit_dim); a learned projection
maps them into the LM embedding space, occupying the first 256
positions of the sequence.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128,
    num_image_tokens=256, vit_dim=1024,
    rope_theta=1e6, mlp_act="silu",
)

SMOKE = ArchConfig(
    name="internvl2-2b-smoke", family="vlm",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    num_image_tokens=8, vit_dim=32,
    rope_theta=1e4, mlp_act="silu", q_chunk=16, kv_chunk=32,
)
