"""deepseek-7b — llama-arch dense decoder (GQA kv=32 == MHA).
[arXiv:2401.02954]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400, head_dim=128,
    rope_theta=1e4, mlp_act="silu",
)

SMOKE = ArchConfig(
    name="deepseek-7b-smoke", family="dense",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=112, vocab=256, head_dim=16,
    rope_theta=1e4, mlp_act="silu", q_chunk=16, kv_chunk=32,
)
