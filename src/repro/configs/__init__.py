"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""

from __future__ import annotations

from typing import Dict, List

from .base import (ArchConfig, ShapeSpec, SHAPES, LONG_CONTEXT_OK,
                   cell_supported, input_specs)
from . import (qwen2_5_14b, deepseek_7b, gemma3_27b, minicpm_2b,
               deepseek_v3_671b, mixtral_8x22b, mamba2_780m,
               internvl2_2b, recurrentgemma_9b, whisper_base)

_MODULES = {
    "qwen2.5-14b": qwen2_5_14b,
    "deepseek-7b": deepseek_7b,
    "gemma3-27b": gemma3_27b,
    "minicpm-2b": minicpm_2b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "mixtral-8x22b": mixtral_8x22b,
    "mamba2-780m": mamba2_780m,
    "internvl2-2b": internvl2_2b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "whisper-base": whisper_base,
}

ARCH_NAMES: List[str] = list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return _MODULES[name].SMOKE if smoke else _MODULES[name].CONFIG


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "LONG_CONTEXT_OK",
           "cell_supported", "input_specs", "get_config", "ARCH_NAMES"]
