"""gemma3-27b — dense GQA, 5:1 local:global attention, QK-norm.
[hf:google/gemma-3-27b-pt; dims per assignment]

long_500k is SKIPPED for this arch: the 1-in-6 global layers are full
attention over the whole context (see DESIGN.md §Arch-applicability).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    window=1024, pattern="gemma3",
    attn_scale=168 ** -0.5,        # query_pre_attn_scalar = d_model/n_heads
    mlp_act="gelu", tie_embeddings=True, scale_embed=True,
)

SMOKE = ArchConfig(
    name="gemma3-27b-smoke", family="dense",
    num_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    qk_norm=True, rope_theta=1e4,
    window=8, pattern="gemma3",
    mlp_act="gelu", tie_embeddings=True, scale_embed=True,
    q_chunk=16, kv_chunk=32,
)
