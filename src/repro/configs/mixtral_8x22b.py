"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088]

All layers are SWA, so the decode KV cache is a ring buffer of the
window — the eviction IS the overwrite (see models/transformer.py).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    n_experts=8, top_k=2, moe_d_ff=16384,
    router_fn="softmax", moe_cf=1.25,
    window=4096, pattern="swa",
    rope_theta=1e6, mlp_act="silu",
)

SMOKE = ArchConfig(
    name="mixtral-8x22b-smoke", family="moe",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    n_experts=4, top_k=2, moe_d_ff=128,
    router_fn="softmax", moe_cf=2.0,
    window=16, pattern="swa",
    rope_theta=1e4, mlp_act="silu", q_chunk=16, kv_chunk=32,
)
