"""mamba2-780m — attention-free SSD (state-space duality) stack.
[arXiv:2405.21060]

long_500k RUNS for this arch: decode state is O(1) in context length.
The paper's attention-related passes are inapplicable (noted in
DESIGN.md §Arch-applicability); the SSD chunk matmuls use the fused
epilogue idea instead.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=0,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    ssm_chunk=256, tie_embeddings=True, rope_theta=0.0,
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke", family="ssm",
    num_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=256, head_dim=0,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
    ssm_chunk=16, tie_embeddings=True, rope_theta=0.0,
)
