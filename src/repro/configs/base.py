"""Architecture config dataclass + the input-shape suite.

Every assigned architecture is a frozen ``ArchConfig``; the four
input-shape cells (train_4k / prefill_32k / decode_32k / long_500k) are
``ShapeSpec``s.  ``input_specs`` produces ShapeDtypeStruct stand-ins so
the dry-run lowers without allocating anything.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | vlm | hybrid | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_scale: Optional[float] = None
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    window: int = 0                # sliding-window width (0 = none)
    pattern: str = "global"        # global | gemma3 (5:1) | swa
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    router_fn: str = "softmax"     # softmax | sigmoid
    moe_cf: float = 1.25           # capacity factor
    moe_aux_alpha: float = 0.01
    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False              # multi-token prediction head
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (recurrentgemma)
    lru_width: int = 0
    conv_width: int = 4
    # enc-dec (whisper)
    encoder_layers: int = 0
    n_frames: int = 0
    # vlm
    num_image_tokens: int = 0
    vit_dim: int = 0
    # misc
    mlp_act: str = "silu"          # silu | gelu
    tie_embeddings: bool = False
    scale_embed: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "float32"   # master param dtype
    q_chunk: int = 512             # flash-attention q block
    kv_chunk: int = 1024           # flash-attention kv block
    remat: str = "full"            # none | full | dots  (train-time only)
    # ---- perf levers (§Perf; baseline keeps the defaults) ----
    attn_compute_dtype: str = "float32"  # float32 | bfloat16 (score/p dtype)
    causal_skip: bool = False      # skip fully-masked kv chunks (lax.cond)
    cache_update: str = "where"    # where | scatter (decode cache insert)
    tp_psum: bool = False          # manual shard_map psum on row-parallel
                                   # output projections: pins the TP
                                   # reduce (and the dW reduce-scatter)
                                   # to the compute dtype
    # training schedule (minicpm uses WSD)
    lr_schedule: str = "cosine"    # cosine | wsd

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))

    # convenience ------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (total; for MoE also see active)."""
        import math
        from ..models import api
        params = jax.eval_shape(
            lambda: api.get_model(self).init(jax.random.PRNGKey(0)))
        return sum(math.prod(l.shape) for l in jax.tree.leaves(params))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        per_expert = 3 * self.d_model * self.moe_d_ff
        inactive = (self.n_experts - self.top_k) * per_expert \
            * self.num_layers
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: archs for which long_500k is runnable (sub-quadratic context cost);
#: pure full-attention archs skip it per the assignment spec.
LONG_CONTEXT_OK = ("mamba2-780m", "recurrentgemma-9b")


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "full-attention arch; long_500k skipped per spec"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.vit_dim), jnp.bfloat16)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return specs


def extra_input_specs(cfg: ArchConfig, batch: int = 1
                      ) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """Named non-token inputs a family's prefill consumes, as
    ``name -> ((batch,)+shape, dtype)``.  The serving scheduler fills
    these with zeros when a request does not supply them, and the
    "engine" executable's Signature lists them."""
    if cfg.family == "audio":
        return {"frames": ((batch, cfg.n_frames, cfg.d_model), "float32")}
    if cfg.family == "vlm":
        return {"patches": ((batch, cfg.num_image_tokens, cfg.vit_dim),
                            "float32")}
    return {}


# needed by input_specs type hints
from typing import Any  # noqa: E402
