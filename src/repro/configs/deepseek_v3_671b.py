"""deepseek-v3-671b — MLA + 1 shared / 256 routed top-8 MoE + MTP.
[arXiv:2412.19437]

The MLA decode path uses the absorbed (latent-space) form — the paper's
compile-time weight-layout trick (Eq. 3) in attention-algebra form.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,                      # dense FFN width (first layers use it;
                                     # modeled uniformly as shared+routed)
    vocab=129280, head_dim=128,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, n_shared=1, moe_d_ff=2048,
    router_fn="sigmoid", moe_cf=1.25,
    mtp=True, rope_theta=1e4, mlp_act="silu",
)

SMOKE = ArchConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, top_k=2, n_shared=1, moe_d_ff=32,
    router_fn="sigmoid", moe_cf=2.0,
    mtp=True, rope_theta=1e4, mlp_act="silu",
    q_chunk=16, kv_chunk=32,
)
