"""qwen2.5-14b — dense GQA decoder with QKV bias.
[hf:Qwen/Qwen2.5-14B; dims per assignment]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6, mlp_act="silu",
)

SMOKE = ArchConfig(
    name="qwen2.5-14b-smoke", family="dense",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    qkv_bias=True, rope_theta=1e4, mlp_act="silu",
    q_chunk=16, kv_chunk=32,
)
