"""whisper-base — encoder-decoder audio backbone; conv frontend STUB.
[arXiv:2212.04356]

``input_specs`` provides precomputed frame embeddings (B, 1500, 512).
Decode shapes exercise the decoder step with the cached encoder output;
the 32k decode depth is structural (beyond Whisper's trained 448
positions — the framework lowers it regardless; see DESIGN.md).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64,
    encoder_layers=6, n_frames=1500,
    rope_theta=0.0, mlp_act="gelu", tie_embeddings=True,
    norm_eps=1e-5,
)

SMOKE = ArchConfig(
    name="whisper-base-smoke", family="audio",
    num_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    encoder_layers=2, n_frames=32,
    rope_theta=0.0, mlp_act="gelu", tie_embeddings=True,
    norm_eps=1e-5, q_chunk=16, kv_chunk=32,
)
