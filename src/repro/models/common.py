"""Shared building blocks for the LM-family model zoo.

Everything here is pure JAX over pytree params.  Sharding is expressed
with *logical axis* annotations (``repro.distributed.sharding.logical``)
so the same code runs on 1 CPU device (tests) and on the production
(pod, data, model) mesh (dry-run) — the paper's compile-time-
specialization philosophy extended to distribution.

Conventions
-----------
* params are dicts of jnp arrays; per-layer params are stacked on a
  leading ``L`` dim and consumed by ``jax.lax.scan`` (HLO size O(1) in
  depth — required to lower 61-layer 671B models in finite time).
* every ``init_*`` has a twin ``*_axes`` returning the same pytree
  structure with tuples of logical axis names per dim; the launcher
  turns those into NamedShardings.
* compute dtype is ``cfg.dtype`` (bf16 by default), params are kept in
  ``cfg.param_dtype``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import current_mesh, logical


# ---------------------------------------------------------------------------
# Parameter initialization helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, fan_in, dtype):
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps):
    """RMSNorm in f32 accumulation (standard practice for bf16 nets)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    # cast each half before the concat so every tensor that survives the
    # op (and any GSPMD reshard of it) is already in the compute dtype —
    # measured: f32 rope intermediates were what the (kv_heads < model)
    # padding gathers moved, at 2× the necessary bytes.
    out = jnp.concatenate([(x1 * cos - x2 * sin).astype(x.dtype),
                           (x1 * sin + x2 * cos).astype(x.dtype)], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Attention — pure-JAX flash-style chunked attention
# ---------------------------------------------------------------------------
# The (B,H,S,S) score matrix is never materialized: the KV sequence is
# processed in chunks with an online softmax (m, l, acc carried through a
# scan).  This is the jnp twin of the Pallas decode kernel, shaped so XLA
# keeps the working set bounded by the chunk size — on TPU the analogous
# fused kernel is kernels/decode_attention.

def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Sk, Hkv, D)
    v: jnp.ndarray,            # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,           # 0 = global; >0 = sliding window width
    window_arr=None,           # traced int32 window (0 = global); wins over `window`
    q_offset: int = 0,         # absolute position of q[0] (prefill chunks)
    kv_chunk: int = 1024,
    q_chunk: int = 512,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    compute_dtype: str = "float32",  # §Perf: bf16 operands, f32 accum
    causal_skip: bool = False,       # §Perf: lax.cond skips masked chunks
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]                # may differ from d (MLA)
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    kv_chunk = min(kv_chunk, sk)
    q_chunk = min(q_chunk, sq)

    # Pad sequence dims to chunk multiples (masked off below).
    pq = (-sq) % q_chunk
    pk = (-sk) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // q_chunk, (sk + pk) // kv_chunk

    # Consistent shardings on every chunked view: without these, GSPMD
    # resolves the (kv_heads < model-axis) padding mismatch by fully
    # all-gathering the score tensors on EVERY kv step (measured: 7.5
    # TiB/device of loop collectives on qwen train_4k).
    _c = lambda t, *ax: logical(t, *ax)
    cdt = jnp.bfloat16 if compute_dtype == "bfloat16" else jnp.float32
    qg = q.reshape(b, nq, q_chunk, hkv, g, d).astype(cdt) \
        * jnp.asarray(scale, cdt)
    kc = k.reshape(b, nk, kv_chunk, hkv, d).astype(cdt)
    vc = v.reshape(b, nk, kv_chunk, hkv, dv).astype(cdt)
    qg = _c(qg, "batch", None, None, "kv_heads", None, None)
    kc = _c(kc, "batch", None, None, "kv_heads", None)
    vc = _c(vc, "batch", None, None, "kv_heads", None)

    q_pos = q_offset + jnp.arange(sq + pq).reshape(nq, q_chunk)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def per_q_chunk(qi, q_blk):
        # q_blk: (B, q_chunk, Hkv, G, D).  Checkpointed: the backward
        # pass recomputes scores per chunk instead of saving the inner
        # scan's per-step residuals (flash-attention backward structure;
        # without this the scan-of-scan residuals are O(S^2/chunk)).
        qpos = q_pos[qi]                              # (q_chunk,)

        def compute_chunk(m, l, acc, kj, k_blk, v_blk):
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            k_blk = _c(k_blk, "batch", None, "kv_heads", None)
            v_blk = _c(v_blk, "batch", None, "kv_heads", None)
            # scores accumulate in f32 regardless of operand dtype
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, k_blk,
                           preferred_element_type=jnp.float32)
            s = _c(s, "batch", None, "kv_heads", None, None)
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window_arr is not None:
                band = qpos[:, None] - kpos[None, :] < window_arr
                mask &= (window_arr == 0) | band
            elif window:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= (kpos < sk)[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # rows with no valid key yet keep m = -inf; guard the exp
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, :, None, None, :], p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(cdt), v_blk,
                preferred_element_type=jnp.float32)
            return m_new, l, acc

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            if causal_skip and causal:
                # Chunks entirely above the diagonal (or entirely below
                # the window) contribute nothing: branch them away so
                # neither the FLOPs nor the buffers exist at run time.
                visible = kj * kv_chunk <= qpos[-1]
                if window_arr is not None:
                    below = (window_arr > 0) & (
                        kj * kv_chunk + kv_chunk - 1 < qpos[0]
                        - window_arr + 1)
                    visible &= ~below
                m, l, acc = jax.lax.cond(
                    visible,
                    lambda op: compute_chunk(*op),
                    lambda op: (op[0], op[1], op[2]),
                    (m, l, acc, kj, k_blk, v_blk))
            else:
                m, l, acc = compute_chunk(m, l, acc, kj, k_blk, v_blk)
            return (m, l, acc), None

        m0 = jnp.full((b, q_chunk, hkv, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, hkv, g, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        l = jnp.where(l == 0.0, 1.0, l)
        return acc / l[..., None]

    out = jax.lax.map(
        lambda args: per_q_chunk(*args),
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq + pq, h, dv)[:, :sq]
    return out.astype(q.dtype)


def decode_attention_jnp(
    q: jnp.ndarray,          # (B, H, D) — one new token per sequence
    k_cache: jnp.ndarray,    # (B, S, Hkv, D)
    v_cache: jnp.ndarray,    # (B, S, Hkv, D)
    lengths: jnp.ndarray,    # (B,) valid context length per sequence
    *,
    window: int = 0,
    window_arr=None,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    compute_dtype: str = "float32",
) -> jnp.ndarray:
    """Single-token attention against a KV cache (GEMV-shaped — the
    paper's "most important operation" in its LLM-decode incarnation)."""
    b, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    cdt = jnp.bfloat16 if compute_dtype == "bfloat16" else jnp.float32
    # flash-decoding layout: KV stream sharded over the sequence dim;
    # the softmax stats and the (B,H,dv) output reduce over it.
    k_cache = logical(k_cache, "batch", "kv_seq", None, None)
    v_cache = logical(v_cache, "batch", "kv_seq", None, None)
    qg = q.reshape(b, hkv, g, d).astype(cdt) * jnp.asarray(scale, cdt)
    # bf16 mode streams the cache WITHOUT materializing an f32 copy —
    # at 32k context the f32 cast alone is 2× the cache in HBM traffic.
    kf = k_cache.astype(cdt)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, kf,
                        preferred_element_type=jnp.float32)
    scores = logical(scores, "batch", None, None, "kv_seq")
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    pos = jnp.arange(s)[None, :]                    # (1, S)
    valid = pos < lengths[:, None]
    if window_arr is not None:
        band = pos >= (lengths[:, None] - window_arr)
        valid &= (window_arr == 0) | band
    elif window:
        valid &= pos >= (lengths[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(cdt),
                     v_cache.astype(cdt),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, v_cache.shape[-1]).astype(q.dtype)


def maybe_remat(cfg, fn):
    """Activation-checkpoint policy for a scanned layer body (training).
    "full" recomputes everything in backward (min memory), "dots" saves
    matmul outputs (the usual TPU sweet spot), "none" saves all."""
    if cfg.remat == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return fn


def ring_insert(cache: jnp.ndarray, new: jnp.ndarray,
                pos: jnp.ndarray, mode: str = "where") -> jnp.ndarray:
    """Write ``new`` (B, ...) into ``cache`` (B, S, ...) at slot
    ``pos % S`` per batch element.  When S covers the whole context the
    slot equals the absolute position; when S is a sliding window the
    ring overwrite implements the window eviction.

    mode="where" rewrites the whole cache through a select (baseline);
    mode="scatter" lowers to a scatter that touches only the written
    row — §Perf: the where form costs a cache-sized read+write per
    layer per token."""
    b, s = cache.shape[:2]
    slot = pos % s
    if mode == "scatter":
        return cache.at[jnp.arange(b), slot].set(new.astype(cache.dtype))
    oh = jnp.arange(s)[None, :] == slot[:, None]          # (B, S)
    oh = oh.reshape(b, s, *([1] * (cache.ndim - 2)))
    return jnp.where(oh, new[:, None].astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    x = jnp.take(table, tokens, axis=0).astype(dtype)
    return logical(x, "batch", "seq", "embed")


def lm_logits(x: jnp.ndarray, head: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,D) @ head: (D,V) -> (B,S,V); f32 logits for a stable loss."""
    y = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                   head.astype(jnp.float32))
    return logical(y, "batch", "seq", "vocab")


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore_id: int = -1) -> jnp.ndarray:
    """Mean token cross-entropy; logits (B,S,V) f32, labels (B,S) int."""
    m = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(m, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Row-parallel output projection (TP reduce pinned to the compute dtype)
# ---------------------------------------------------------------------------
def row_parallel_out(h, w, tp_psum: bool = False):
    """y = h @ w where h's last dim is model-sharded (row-parallel).

    With ``tp_psum`` and an active mesh, the contraction runs inside a
    ``shard_map`` with an explicit ``psum("model")`` — pinning the TP
    all-reduce (forward) and the dW reduce-scatter (backward) to ``h``'s
    dtype.  Left to GSPMD, XLA sinks the reduce past the rms-norm f32
    convert and moves 2× the bytes (measured on qwen train_4k: the f32
    dx/dy all-reduces were >60% of all collective traffic).
    """
    mesh = current_mesh()
    f = h.shape[-1]
    usable = (tp_psum and mesh is not None and "model" in mesh.axis_names
              and mesh.shape["model"] > 1 and f % mesh.shape["model"] == 0)
    if usable:
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names and mesh.shape[a] > 1)
        nb = 1
        for a in batch_axes:
            nb *= mesh.shape[a]
        bspec = batch_axes if (batch_axes and h.shape[0] % nb == 0) \
            else None
        from jax.sharding import PartitionSpec as P
        wc = w.astype(h.dtype)

        def fn(hl, wl):
            y = jnp.einsum("bsf,fd->bsd", hl, wl,
                           preferred_element_type=hl.dtype)
            return jax.lax.psum(y, "model")

        return _shard_map(fn, mesh=mesh,
                          in_specs=(P(bspec, None, "model"),
                                    P("model", None)),
                          out_specs=P(bspec, None, None),
                          **{_CHECK_KW: False})(h, wc)
    return jnp.einsum("bsf,fd->bsd", h, w.astype(h.dtype),
                      preferred_element_type=h.dtype)


try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_CHECK_KW = ("check_vma" if "check_vma" in
             _inspect.signature(_shard_map).parameters else "check_rep")


def col_parallel_in(x, weights, tp_psum: bool = False):
    """[x @ w for w in weights] with outputs model-sharded
    (column-parallel).  Under ``tp_psum`` all projections sharing ``x``
    run in ONE shard_map, so the backward emits a single fused
    ``psum("model")`` for dx — in x's dtype.  Left to GSPMD, the dx
    all-reduces sink past the rms-norm f32 convert and each projection
    reduces separately (measured: the f32 dx reduces were the largest
    single collective on qwen train_4k)."""
    mesh = current_mesh()
    usable = (tp_psum and mesh is not None and "model" in mesh.axis_names
              and mesh.shape["model"] > 1
              and all(w.shape[-1] % mesh.shape["model"] == 0
                      for w in weights))
    if not usable:
        return [jnp.einsum("bsd,dn->bsn", x, w.astype(x.dtype))
                for w in weights]
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names and mesh.shape[a] > 1)
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    bspec = batch_axes if (batch_axes and x.shape[0] % nb == 0) else None
    from jax.sharding import PartitionSpec as P

    def fn(xl, *wl):
        return tuple(jnp.einsum("bsd,dn->bsn", xl, w,
                                preferred_element_type=xl.dtype)
                     for w in wl)

    outs = _shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, None, None),) + (P(None, "model"),) * len(weights),
        out_specs=(P(bspec, None, "model"),) * len(weights),
        **{_CHECK_KW: False})(x, *[w.astype(x.dtype) for w in weights])
    return list(outs)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU family)
# ---------------------------------------------------------------------------
def gated_mlp(x, wi_gate, wi_up, wo, act: str = "silu",
              tp_psum: bool = False):
    """x: (B,S,D); wi_*: (D,F); wo: (F,D)."""
    h_gate, h_up = col_parallel_in(x, (wi_gate, wi_up), tp_psum)
    h_gate = logical(h_gate, "batch", "seq", "mlp")
    if act == "silu":
        h = jax.nn.silu(h_gate) * h_up
    elif act == "gelu":
        h = jax.nn.gelu(h_gate, approximate=True) * h_up
    else:
        raise NotImplementedError(act)
    y = row_parallel_out(h, wo, tp_psum)
    return logical(y, "batch", "seq", "embed")


def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), d_model, dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), d_model, dtype),
        "wo": dense_init(k3, (d_ff, d_model), d_ff, dtype),
    }


def mlp_axes():
    return {
        "wi_gate": ("fsdp", "mlp"),
        "wi_up": ("fsdp", "mlp"),
        "wo": ("mlp", "fsdp"),
    }
