"""Decoder-only transformer assembly.

Covers the dense archs (qwen2.5, deepseek-7b, gemma3, minicpm, the
internvl2 LM backbone) and — via pluggable FFN/attention modules — the
MoE archs (mixtral, deepseek-v3/MLA).  The recurrentgemma hybrid lives
in ``rglru.py`` and reuses the attention/MLP pieces here.

Heterogeneous layer patterns (gemma3's 5:1 local:global, mixtral's SWA)
are expressed as a *stacked per-layer window array* consumed inside one
``lax.scan`` body: local vs global attention differ only in the band
mask, so a single scanned body serves every pattern with zero duplicated
compute — the compile-time-constant pattern baked into the program, the
way the paper bakes layer structure into its instruction stream.

KV caches are ring buffers: when every layer is sliding-window
(mixtral), the cache allocates only the window and the ring overwrite
implements eviction; otherwise the cache covers the full context and
local layers mask by window.  ``cache["pos"]`` counts absolute tokens.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import logical
from . import common as C
from . import mla as mla_mod
from . import moe as moe_mod


# ---------------------------------------------------------------------------
# Per-layer static window pattern
# ---------------------------------------------------------------------------
def layer_windows(cfg) -> np.ndarray:
    """Per-layer sliding-window width; 0 = global attention."""
    L = cfg.num_layers
    if cfg.pattern == "gemma3":            # 5 local : 1 global
        w = [0 if (i + 1) % 6 == 0 else cfg.window for i in range(L)]
    elif cfg.pattern == "swa":             # all layers sliding-window
        w = [cfg.window] * L
    else:                                   # all global
        w = [0] * L
    return np.asarray(w, np.int32)


def cache_len(cfg, max_len: int) -> int:
    """Ring caches allocate only the window when no layer is global."""
    w = layer_windows(cfg)
    if (w == 0).any():
        return max_len
    return min(max_len, int(w.max()))


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def attn_init(key, cfg):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = C.split_keys(key, 4)
    dt = cfg.param_dtype
    p = {
        "wq": C.dense_init(ks[0], (d, h * hd), d, dt),
        "wk": C.dense_init(ks[1], (d, hkv * hd), d, dt),
        "wv": C.dense_init(ks[2], (d, hkv * hd), d, dt),
        "wo": C.dense_init(ks[3], (h * hd, d), h * hd, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def attn_axes(cfg):
    p = {"wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
         "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp")}
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    if cfg.qk_norm:
        p.update({"q_norm": (None,), "k_norm": (None,)})
    return p


def _qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = C.col_parallel_in(x, (p["wq"], p["wk"], p["wv"]),
                                cfg.tp_psum)
    if cfg.qkv_bias:
        q, k, v = (q + p["bq"].astype(x.dtype), k + p["bk"].astype(x.dtype),
                   v + p["bv"].astype(x.dtype))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = C.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = C.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = logical(q, "batch", "seq", "heads", "head_dim")
    k = logical(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical(v, "batch", "seq", "kv_heads", "head_dim")
    if cfg.rope_theta:
        q = C.apply_rope(q, positions, cfg.rope_theta)
        k = C.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, cfg, x, positions, window):
    """Full-sequence attention; window is a traced int32 (0 = global).
    Returns (out, (k, v)) — the cache slices for this layer."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = C.chunked_attention(
        q, k, v, causal=True, window_arr=window,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        compute_dtype=cfg.attn_compute_dtype,
        causal_skip=cfg.causal_skip)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = C.row_parallel_out(out, p["wo"], cfg.tp_psum)
    return logical(y, "batch", "seq", "embed"), (k, v)


def attn_decode(p, cfg, x, k_cache, v_cache, pos, window):
    """One-token decode; x (B,1,D), caches (B,S,Hkv,D), pos (B,)."""
    b = x.shape[0]
    s_cache = k_cache.shape[1]
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    k_cache = C.ring_insert(k_cache, k[:, 0], pos, cfg.cache_update)
    v_cache = C.ring_insert(v_cache, v[:, 0], pos, cfg.cache_update)
    eff_len = jnp.minimum(pos + 1, s_cache)
    # All-local models get a ring cache: eviction is the overwrite, so no
    # window mask is needed (static property of the config).
    ring = bool((layer_windows(cfg) > 0).all())
    out = C.decode_attention_jnp(
        q[:, 0], k_cache, v_cache, eff_len,
        window_arr=None if ring else window,
        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        compute_dtype=cfg.attn_compute_dtype)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = C.row_parallel_out(out, p["wo"], cfg.tp_psum)
    return logical(y, "batch", "seq", "embed"), (k_cache, v_cache)


# ---------------------------------------------------------------------------
# One decoder layer
# ---------------------------------------------------------------------------
def layer_init(key, cfg):
    k_attn, k_ffn = jax.random.split(key)
    p: Dict[str, Any] = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "attn": (mla_mod.mla_init if cfg.mla else attn_init)(k_attn, cfg),
        "ffn": (moe_mod.moe_init(k_ffn, cfg) if cfg.n_experts
                else C.mlp_init(k_ffn, cfg.d_model, cfg.d_ff,
                                cfg.param_dtype)),
    }
    return p


def layer_axes(cfg):
    return {
        "ln1": (None,), "ln2": (None,),
        "attn": mla_mod.mla_axes(cfg) if cfg.mla else attn_axes(cfg),
        "ffn": moe_mod.moe_axes(cfg) if cfg.n_experts else C.mlp_axes(),
    }


def _ffn(p, cfg, x):
    if cfg.n_experts:
        return moe_mod.moe_apply(p, cfg, x)
    return C.gated_mlp(x, p["wi_gate"], p["wi_up"], p["wo"],
                       act=cfg.mlp_act,
                       tp_psum=cfg.tp_psum), jnp.float32(0.0)


def layer_apply(p, cfg, x, positions, window):
    flavor = mla_mod.mla_apply if cfg.mla else attn_apply
    h, slices = flavor(p["attn"], cfg, C.rms_norm(x, p["ln1"], cfg.norm_eps),
                       positions, window)
    x = x + h
    h, aux = _ffn(p["ffn"], cfg, C.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + h, slices, aux


def layer_decode(p, cfg, x, c1, c2, pos, window):
    flavor = mla_mod.mla_decode if cfg.mla else attn_decode
    h, (c1, c2) = flavor(p["attn"], cfg,
                         C.rms_norm(x, p["ln1"], cfg.norm_eps),
                         c1, c2, pos, window)
    x = x + h
    h, _ = _ffn(p["ffn"], cfg, C.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + h, c1, c2


# ---------------------------------------------------------------------------
# Whole model: params
# ---------------------------------------------------------------------------
def init_params(cfg, key) -> Dict[str, Any]:
    k_emb, k_layers, k_head, k_mtp, k_img = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    p = {
        "embed": C.dense_init(k_emb, (cfg.vocab, cfg.d_model),
                              cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = C.dense_init(k_head, (cfg.d_model, cfg.vocab),
                                 cfg.d_model, cfg.param_dtype)
    if cfg.mtp:
        p["mtp"] = {
            "proj": C.dense_init(k_mtp, (2 * cfg.d_model, cfg.d_model),
                                 2 * cfg.d_model, cfg.param_dtype),
            "block": layer_init(k_mtp, cfg),
            "ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        }
    if cfg.num_image_tokens:
        p["img_proj"] = C.dense_init(k_img, (cfg.vit_dim, cfg.d_model),
                                     cfg.vit_dim, cfg.param_dtype)
    return p


def param_axes(cfg) -> Dict[str, Any]:
    is_ax = lambda x: isinstance(x, tuple)
    stack = lambda t: jax.tree.map(lambda ax: ("layers",) + ax, t,
                                   is_leaf=is_ax)
    p = {
        "embed": ("vocab", "fsdp"),
        "layers": stack(layer_axes(cfg)),
        "ln_f": (None,),
    }
    if not cfg.tie_embeddings:
        p["head"] = ("fsdp", "vocab")
    if cfg.mtp:
        p["mtp"] = {"proj": ("fsdp", None), "block": layer_axes(cfg),
                    "ln": (None,)}
    if cfg.num_image_tokens:
        p["img_proj"] = (None, "fsdp")
    return p


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------
def _embed_in(cfg, params, tokens, patches=None):
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    if cfg.num_image_tokens and patches is not None:
        img = jnp.einsum("bnd,de->bne", patches.astype(cfg.dtype),
                         params["img_proj"].astype(cfg.dtype))
        x = jnp.concatenate([img, x[:, cfg.num_image_tokens:]], axis=1)
    return x


def _head(cfg, params, x):
    x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = C.lm_logits(x, head)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def forward(cfg, params, tokens, patches=None):
    """Training forward: tokens (B,S) -> (logits (B,S,V), extras)."""
    b, s = tokens.shape
    x = _embed_in(cfg, params, tokens, patches)
    positions = jnp.arange(s)[None, :]
    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, xs):
        x, aux = carry
        lp, w = xs
        x, _, a = layer_apply(lp, cfg, x, positions, w)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(C.maybe_remat(cfg, body),
                               (x, jnp.float32(0.0)),
                               (params["layers"], windows))
    logits = _head(cfg, params, x)
    extras = {"aux_loss": aux * cfg.moe_aux_alpha}
    if cfg.mtp:
        extras["mtp_hidden"] = x
    return logits, extras


def mtp_logits(cfg, params, hidden, tokens):
    """DeepSeek-V3 multi-token-prediction head (depth 1): combine the
    final hidden at t with the embedding of token t+1 to predict t+2
    through one extra block sharing the unembedding."""
    p = params["mtp"]
    emb_next = _embed_in(cfg, params, jnp.roll(tokens, -1, axis=1))
    h = jnp.concatenate(
        [C.rms_norm(hidden, p["ln"], cfg.norm_eps), emb_next], axis=-1)
    h = jnp.einsum("bsd,de->bse", h, p["proj"].astype(h.dtype))
    positions = jnp.arange(tokens.shape[1])[None, :]
    h, _, _ = layer_apply(p["block"], cfg, h, positions, jnp.int32(0))
    return _head(cfg, params, h)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int):
    s = cache_len(cfg, max_len)
    L = cfg.num_layers
    if cfg.mla:
        shapes = ((batch, s, 1, cfg.kv_lora_rank),
                  (batch, s, 1, cfg.qk_rope_dim))
    else:
        shapes = ((batch, s, cfg.n_kv_heads, cfg.head_dim),) * 2
    return {
        "c1": jnp.zeros((L,) + shapes[0], cfg.dtype),
        "c2": jnp.zeros((L,) + shapes[1], cfg.dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg):
    kv = (None, None) if cfg.mla else ("kv_heads", "head_dim")
    return {
        "c1": ("layers", "batch", "kv_seq") + kv,
        "c2": ("layers", "batch", "kv_seq") + kv,
        "pos": ("batch",),
    }


def prefill(cfg, params, tokens, cache, patches=None):
    """Run the prompt, fill the cache, return last-position logits."""
    b, s = tokens.shape
    x = _embed_in(cfg, params, tokens, patches)
    positions = jnp.arange(s)[None, :]
    windows = jnp.asarray(layer_windows(cfg))
    slen = cache["c1"].shape[2]

    def fit(t):
        """Store the last `slen` positions, ring-aligned."""
        if s > slen:
            t = t[:, -slen:]
            return jnp.roll(t, shift=s % slen, axis=1)
        if s < slen:
            pad = [(0, 0)] * t.ndim
            pad[1] = (0, slen - s)
            return jnp.pad(t, pad)
        return t

    def body(x, xs):
        lp, w = xs
        x, (c1, c2), _ = layer_apply(lp, cfg, x, positions, w)
        return x, (fit(c1.astype(cfg.dtype)), fit(c2.astype(cfg.dtype)))

    x, (c1s, c2s) = jax.lax.scan(body, x, (params["layers"], windows))
    cache = {"c1": c1s, "c2": c2s,
             "pos": jnp.full((b,), s, jnp.int32)}
    return _head(cfg, params, x[:, -1:]), cache


def attn_chunk(p, cfg, x, k_cache, v_cache, positions, window, start):
    """Prefill one chunk against an existing cache: write the chunk's
    K/V into rows ``[start, start+C)`` and attend the chunk's queries
    over the cache prefix plus the chunk itself (``q_offset`` keeps the
    causal/window masks absolute).  Rows the chunk's pad positions
    write are causally invisible to every real query and are
    overwritten (or masked by ``pos``) before decode can see them."""
    b, c, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), start, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), start, axis=1)
    out = C.chunked_attention(
        q, k_cache, v_cache, causal=True, window_arr=window,
        q_offset=start,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        compute_dtype=cfg.attn_compute_dtype,
        causal_skip=cfg.causal_skip)
    out = out.reshape(b, c, cfg.n_heads * cfg.head_dim)
    y = C.row_parallel_out(out, p["wo"], cfg.tp_psum)
    return logical(y, "batch", "seq", "embed"), (k_cache, v_cache)


def layer_chunk(p, cfg, x, c1, c2, positions, window, start):
    """One decoder layer over a prefill chunk (the chunk twin of
    ``layer_apply``/``layer_decode``)."""
    h, (c1, c2) = attn_chunk(p["attn"], cfg,
                             C.rms_norm(x, p["ln1"], cfg.norm_eps),
                             c1, c2, positions, window, start)
    x = x + h
    h, _ = _ffn(p["ffn"], cfg, C.rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + h, c1, c2


def prefill_chunk(cfg, params, tokens, cache, start, length):
    """Incremental prefill: process ``length`` (≤ C) prompt tokens at
    absolute positions ``[start, start+length)`` against an existing
    cache.

    ``tokens`` is a fixed-size (B, C) chunk (pad beyond ``length``);
    ``start``/``length`` are traced, so ONE compiled program serves
    every chunk of every prompt.  Returns the logits of the last *real*
    token and the updated cache (``pos = start+length``) — both
    bit-identical to the corresponding positions of one full-sequence
    ``prefill`` (tested), because the chunk queries see exactly the
    same keys in the same order: the cache prefix holds the earlier
    chunks' K/V at their absolute rows and ``q_offset`` keeps the
    causal/window masks absolute.

    Requires a full-context cache (``cache_len == max_len``): with a
    ring cache the chunk's absolute row indices would alias, so callers
    gate chunked prefill off for all-sliding-window models (the
    scheduler does).  MLA caches store latents, not K/V, and are not
    supported — ``repro.serve`` falls back to whole-prompt prefill.
    """
    if cfg.mla:
        raise NotImplementedError(
            "chunked prefill is not supported for MLA caches")
    b, c = tokens.shape
    x = _embed_in(cfg, params, tokens)
    positions = start + jnp.arange(c)[None, :]
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, xs):
        lp, w, c1, c2 = xs
        x, c1, c2 = layer_chunk(lp, cfg, x, c1, c2, positions, w, start)
        return x, (c1, c2)

    x, (c1s, c2s) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["c1"], cache["c2"]))
    new_cache = {"c1": c1s, "c2": c2s,
                 "pos": jnp.full_like(cache["pos"], start + length)}
    last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
    return _head(cfg, params, last), new_cache


def decode_step(cfg, params, cache, tokens):
    """One decode step: tokens (B,1) -> (logits (B,1,V), updated cache)."""
    x = _embed_in(cfg, params, tokens)
    pos = cache["pos"]
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, xs):
        lp, w, c1, c2 = xs
        x, c1, c2 = layer_decode(lp, cfg, x, c1, c2, pos, w)
        return x, (c1, c2)

    x, (c1s, c2s) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["c1"], cache["c2"]))
    new_cache = {"c1": c1s, "c2": c2s, "pos": pos + 1}
    return _head(cfg, params, x), new_cache
