"""Mamba-2 (SSD — state-space duality) blocks.

The SSD algorithm computes the selective-SSM recurrence

    h_t = a_t * h_{t-1} + dt_t * (B_t ⊗ x_t)      y_t = C_t · h_t + D x_t

in *chunked matmul form*: intra-chunk terms become (Q×Q) masked matmuls
(MXU-friendly — the hardware adaptation of the paper's "make the compute
unit, not the memory system, the limit") and inter-chunk states are
carried by a short scan over S/Q chunks.  Decode is the O(1)-state
recurrent update — the SSM analogue of the paper's matrix-vector hot
loop, with the state playing the role of the register-resident batch.

Shapes: d_inner = expand * d_model, nheads = d_inner / head_dim,
B/C shared across heads within a group (ngroups = 1 here).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import logical
from . import common as C


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def ssm_init(key, cfg):
    d = cfg.d_model
    di, nh, ds = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    conv_dim = di + 2 * ds                      # x + B + C share the conv
    ks = C.split_keys(key, 4)
    dt = cfg.param_dtype
    return {
        # in_proj emits [z (di), x+B+C (conv_dim), dt (nh)]
        "in_proj": C.dense_init(ks[0], (d, di + conv_dim + nh), d, dt),
        "conv_w": C.dense_init(ks[1], (cfg.ssm_conv, conv_dim),
                               cfg.ssm_conv, dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dt),
        "dt_bias": jnp.zeros((nh,), dt),
        "d_skip": jnp.ones((nh,), dt),
        "norm": jnp.zeros((di,), dt),
        "out_proj": C.dense_init(ks[3], (di, d), di, dt),
    }


def ssm_axes(cfg):
    return {
        "in_proj": ("fsdp", "mlp"),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "a_log": (None,),
        "dt_bias": (None,),
        "d_skip": (None,),
        "norm": ("mlp",),
        "out_proj": ("mlp", "fsdp"),
    }


def _split_proj(cfg, zxbcdt):
    di, nh, ds = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    conv_dim = di + 2 * ds
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_dim]
    dt = zxbcdt[..., di + conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d, width K: y_t = sum_k w_k * x_{t-K+1+k}."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
            for i in range(k))
    return jax.nn.silu(y + b.astype(xbc.dtype))


def _segsum(a):
    """log-space cumulative decay matrix: L[i,j] = sum_{j<k<=i} a_k for
    j <= i, -inf above the diagonal.  a: (..., Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    dif = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, dif, -jnp.inf)


def ssd_scan(cfg, x, dt, B, Cc, a_log):
    """Chunked SSD.  x: (b,S,nh,dh); dt: (b,S,nh); B/C: (b,S,ds).
    Returns y (b,S,nh,dh) and the final state (b,nh,dh,ds)."""
    b, s, nh, dh = x.shape
    ds = B.shape[-1]
    Q = min(cfg.ssm_chunk, s)
    pad = (-s) % Q
    nc = (s + pad) // Q

    A = -jnp.exp(a_log.astype(jnp.float32))              # (nh,) negative
    dtf = jax.nn.softplus(dt.astype(jnp.float32))        # (b,S,nh)
    da = dtf * A                                          # log decay
    xdt = x.astype(jnp.float32) * dtf[..., None]          # (b,S,nh,dh)
    if pad:
        # Pad AFTER discretization: da=0 (decay 1) and xdt=0 make padded
        # steps identities, so the final state equals the state at s-1.
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))

    xc = xdt.reshape(b, nc, Q, nh, dh)
    dac = da.reshape(b, nc, Q, nh)
    Bc = B.reshape(b, nc, Q, ds).astype(jnp.float32)
    Cck = Cc.reshape(b, nc, Q, ds).astype(jnp.float32)

    # Intra-chunk (diagonal block): Y = (C B^T ⊙ L) @ xdt
    L = jnp.exp(_segsum(jnp.moveaxis(dac, -1, -2)))       # (b,nc,nh,Q,Q)
    cb = jnp.einsum("bnqs,bnks->bnqk", Cck, Bc)           # (b,nc,Q,Q)
    y_diag = jnp.einsum("bnhqk,bnkhd->bnqhd",
                        cb[:, :, None] * L, xc)

    # Chunk-final states: S_n = sum_i decay_to_end_i * B_i ⊗ xdt_i
    cum = jnp.cumsum(dac, axis=2)
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (b,nc,Q,nh)
    states = jnp.einsum("bnqs,bnqh,bnqhd->bnhsd",
                        Bc, decay_end, xc)                # (b,nc,nh,ds,dh)

    # Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (b,nc,nh)

    def step(h, inp):
        st, dec = inp
        h = h * dec[..., None, None] + st
        return h, h

    h0 = jnp.zeros((b, nh, ds, dh), jnp.float32)
    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(states, 1, 0),
                          jnp.moveaxis(chunk_decay, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1)                           # (b,nc,nh,ds,dh)
    h_prev = jnp.concatenate([h0[:, None], hs[:, :-1]], axis=1)

    # Inter-chunk output: y += C_t · (decay_from_start * h_prev)
    decay_in = jnp.exp(cum)                                # (b,nc,Q,nh)
    y_off = jnp.einsum("bnqs,bnqh,bnhsd->bnqhd",
                       Cck, decay_in, h_prev)

    y = (y_diag + y_off).reshape(b, s + pad, nh, dh)[:, :s]
    final = hs[:, -1]                                      # (b,nh,ds,dh)
    return y.astype(x.dtype), final


def ssm_apply(p, cfg, x):
    """Full-sequence block: x (B,S,D) -> (y (B,S,D), final_state)."""
    zxbcdt = jnp.einsum("bsd,dn->bsn", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    di, ds = d_inner(cfg), cfg.ssm_state
    xi = xbc[..., :di]
    B = xbc[..., di:di + ds]
    Cc = xbc[..., di + ds:]
    nh, dh = n_heads(cfg), cfg.ssm_head_dim
    b, s, _ = x.shape
    y, final = ssd_scan(cfg, xi.reshape(b, s, nh, dh),
                        dt + p["dt_bias"].astype(dt.dtype), B, Cc,
                        p["a_log"])
    y = y + xi.reshape(b, s, nh, dh) * p["d_skip"].astype(x.dtype)[:, None]
    y = y.reshape(b, s, di)
    y = C.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsn,nd->bsd", y, p["out_proj"].astype(x.dtype))
    return logical(out, "batch", "seq", "embed"), final


def ssm_prefill(p, cfg, x):
    """Like ssm_apply but also returns the decode caches (conv tail +
    final SSM state)."""
    zxbcdt = jnp.einsum("bsd,dn->bsn", x, p["in_proj"].astype(x.dtype))
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    k = cfg.ssm_conv
    b, s, _ = x.shape
    # conv tail: last K-1 *pre-activation* inputs, for decode continuity
    tail = jnp.pad(xbc_raw, ((0, 0), (max(0, k - 1 - s), 0), (0, 0)))[:, -(k - 1):]
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    di, ds = d_inner(cfg), cfg.ssm_state
    xi, B, Cc = (xbc[..., :di], xbc[..., di:di + ds], xbc[..., di + ds:])
    nh, dh = n_heads(cfg), cfg.ssm_head_dim
    y, final = ssd_scan(cfg, xi.reshape(b, s, nh, dh),
                        dt + p["dt_bias"].astype(dt.dtype), B, Cc,
                        p["a_log"])
    y = y + xi.reshape(b, s, nh, dh) * p["d_skip"].astype(x.dtype)[:, None]
    y = y.reshape(b, s, di)
    y = C.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsn,nd->bsd", y, p["out_proj"].astype(x.dtype))
    return logical(out, "batch", "seq", "embed"), (tail, final)


def ssm_decode(p, cfg, x, conv_tail, state):
    """One-token recurrent update.  x: (B,1,D); conv_tail: (B,K-1,conv);
    state: (B,nh,ds,dh)."""
    b = x.shape[0]
    di, ds, k = d_inner(cfg), cfg.ssm_state, cfg.ssm_conv
    nh, dh = n_heads(cfg), cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,dn->bsn", x, p["in_proj"].astype(x.dtype))
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate([conv_tail, xbc_new], axis=1)  # (B,K,conv)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))[:, None]
    xi, B, Cc = (xbc[..., :di], xbc[..., di:di + ds], xbc[..., di + ds:])

    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,nh)
    a = jnp.exp(dtf * A)                                       # (B,nh)
    xh = xi[:, 0].reshape(b, nh, dh).astype(jnp.float32)
    # h <- a h + dt (B ⊗ x)
    state = (state * a[..., None, None]
             + jnp.einsum("bs,bhd,bh->bhsd", B[:, 0], xh, dtf))
    y = jnp.einsum("bs,bhsd->bhd", Cc[:, 0], state)            # (B,nh,dh)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = C.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsn,nd->bsd", y, p["out_proj"].astype(x.dtype))
    return (logical(out, "batch", "seq", "embed"),
            window[:, 1:], state)


# ---------------------------------------------------------------------------
# Whole-model assembly (attention-free stack)
# ---------------------------------------------------------------------------
def init_params(cfg, key):
    k_emb, k_layers = jax.random.split(key)
    lks = jax.random.split(k_layers, cfg.num_layers)

    def one(k):
        return {"ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
                "mixer": ssm_init(k, cfg)}

    return {
        "embed": C.dense_init(k_emb, (cfg.vocab, cfg.d_model),
                              cfg.d_model, cfg.param_dtype),
        "layers": jax.vmap(one)(lks),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def param_axes(cfg):
    is_ax = lambda x: isinstance(x, tuple)
    layer = {"ln": (None,), "mixer": ssm_axes(cfg)}
    return {
        "embed": ("vocab", "fsdp"),
        "layers": jax.tree.map(lambda ax: ("layers",) + ax, layer,
                               is_leaf=is_ax),
        "ln_f": (None,),
    }


def _head(cfg, params, x):
    x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return C.lm_logits(x, params["embed"].T)     # mamba ties embeddings


def forward(cfg, params, tokens, patches=None):
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)

    def body(x, lp):
        h, _ = ssm_apply(lp["mixer"], cfg,
                         C.rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + h, None

    x, _ = jax.lax.scan(C.maybe_remat(cfg, body), x, params["layers"])
    return _head(cfg, params, x), {"aux_loss": jnp.float32(0.0)}


def init_cache(cfg, batch, max_len):
    nh, dh, ds = n_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = d_inner(cfg) + 2 * ds
    L = cfg.num_layers
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
        "state": jnp.zeros((L, batch, nh, ds, dh), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg):
    return {"conv": ("layers", "batch", None, "mlp"),
            "state": ("layers", "batch", None, "state", None),
            "pos": ("batch",)}


def prefill(cfg, params, tokens, cache, patches=None):
    b, s = tokens.shape
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)

    def body(x, lp):
        h, (tail, final) = ssm_prefill(lp["mixer"], cfg,
                                       C.rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + h, (tail.astype(cfg.dtype), final)

    x, (tails, finals) = jax.lax.scan(body, x, params["layers"])
    cache = {"conv": tails, "state": finals,
             "pos": jnp.full((b,), s, jnp.int32)}
    return _head(cfg, params, x[:, -1:]), cache


def decode_step(cfg, params, cache, tokens):
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)

    def body(x, xs):
        lp, conv, state = xs
        h, conv, state = ssm_decode(lp["mixer"], cfg,
                                    C.rms_norm(x, lp["ln"], cfg.norm_eps),
                                    conv, state)
        return x + h, (conv.astype(cfg.dtype), state)

    x, (convs, states) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["state"]))
    return _head(cfg, params, x), {"conv": convs, "state": states,
                                   "pos": cache["pos"] + 1}
