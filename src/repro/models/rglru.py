"""RecurrentGemma hybrid: RG-LRU recurrent blocks + local attention, 2:1.

Block pattern (Griffin): (recurrent, recurrent, local-attention) repeated.
38 layers = 12 super-blocks of 3 + 2 trailing recurrent layers.  Scanning
*super-blocks* (not layers) keeps the two block kinds in separate scan
bodies — no wasted dual computation, while HLO stays O(1) in depth.

The RG-LRU recurrence  h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)
is evaluated with ``lax.associative_scan`` for train/prefill (log-depth,
TPU-friendly) and as the O(1) update for decode.  Like Mamba, its decode
state is tiny and position-independent — which is why this arch *runs*
the long_500k shape while full-attention archs skip it.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import logical
from . import common as C

_C_RGLRU = 8.0  # RG-LRU "a" sharpness constant (Griffin paper)


# ---------------------------------------------------------------------------
# RG-LRU temporal-mixing block
# ---------------------------------------------------------------------------
def rec_init(key, cfg):
    d, dr = cfg.d_model, cfg.lru_width
    ks = C.split_keys(key, 6)
    dt = cfg.param_dtype
    return {
        "wx": C.dense_init(ks[0], (d, dr), d, dt),
        "wy": C.dense_init(ks[1], (d, dr), d, dt),       # gate branch
        "conv_w": C.dense_init(ks[2], (cfg.conv_width, dr),
                               cfg.conv_width, dt),
        "conv_b": jnp.zeros((dr,), dt),
        "w_rg": C.dense_init(ks[3], (dr, dr), dr, dt),   # recurrence gate
        "w_in": C.dense_init(ks[4], (dr, dr), dr, dt),   # input gate
        "a_param": jnp.full((dr,), -1.0, dt),            # lambda init
        "wo": C.dense_init(ks[5], (dr, d), dr, dt),
    }


def rec_axes(cfg):
    return {"wx": ("fsdp", "mlp"), "wy": ("fsdp", "mlp"),
            "conv_w": (None, "mlp"), "conv_b": ("mlp",),
            "w_rg": ("fsdp", "mlp"), "w_in": ("fsdp", "mlp"),
            "a_param": ("mlp",), "wo": ("mlp", "fsdp")}


def _gates(p, x):
    """r, i gates and log-decay from the conv'd branch x (B,S,dr)."""
    r = jax.nn.sigmoid(jnp.einsum("bsr,rn->bsn", x, p["w_rg"].astype(x.dtype))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rn->bsn", x, p["w_in"].astype(x.dtype))
                       .astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(
        p["a_param"].astype(jnp.float32)) * r             # (B,S,dr)
    return i, log_a


def rec_apply(p, cfg, x, conv_state=None):
    """x: (B,S,D).  Returns (out, (conv_tail, h_final))."""
    b, s, _ = x.shape
    xb = jnp.einsum("bsd,dr->bsr", x, p["wx"].astype(x.dtype))
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, p["wy"].astype(x.dtype)),
        approximate=True)
    k = cfg.conv_width
    tail = jnp.pad(xb, ((0, 0), (max(0, k - 1 - s), 0), (0, 0)))[:, -(k - 1):]
    # causal depthwise conv
    padded = jnp.pad(xb, ((0, 0), (k - 1, 0), (0, 0)))
    xc = sum(padded[:, i:i + s] * p["conv_w"][i].astype(xb.dtype)
             for i in range(k)) + p["conv_b"].astype(xb.dtype)

    i_g, log_a = _gates(p, xc)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * i_g * xc.astype(jnp.float32)

    # h_t = a_t h_{t-1} + bx_t  via associative scan (parallel prefix).
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = (h.astype(x.dtype) * gate)
    out = C.row_parallel_out(y, p["wo"], cfg.tp_psum)
    return (logical(out, "batch", "seq", "embed"),
            (tail, h[:, -1]))


def rec_decode(p, cfg, x, conv_tail, h):
    """One-step recurrent update.  x (B,1,D); conv_tail (B,K-1,dr);
    h (B,dr) f32."""
    xb = jnp.einsum("bsd,dr->bsr", x, p["wx"].astype(x.dtype))
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, p["wy"].astype(x.dtype)),
        approximate=True)
    window = jnp.concatenate([conv_tail.astype(xb.dtype), xb], axis=1)
    xc = (jnp.einsum("bkr,kr->br", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32))
          + p["conv_b"].astype(jnp.float32))[:, None]     # (B,1,dr)
    i_g, log_a = _gates(p, xc)
    a = jnp.exp(log_a[:, 0])
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a[:, 0]), 1e-12))
    h = a * h + beta * i_g[:, 0] * xc[:, 0]
    y = (h[:, None].astype(x.dtype) * gate)
    out = jnp.einsum("bsr,rd->bsd", y, p["wo"].astype(x.dtype))
    return (logical(out, "batch", "seq", "embed"),
            window[:, 1:], h)


# ---------------------------------------------------------------------------
# Super-block assembly:  [rec, rec, local-attn] × n  + trailing recs
# ---------------------------------------------------------------------------
from . import transformer as T  # attention + MLP pieces (after defs above)


def _sub_init(key, cfg, kind):
    k1, k2 = jax.random.split(key)
    mixer = rec_init(k1, cfg) if kind == "rec" else T.attn_init(k1, cfg)
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "mixer": mixer,
            "ffn": C.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype)}


def _sub_axes(cfg, kind):
    return {"ln1": (None,), "ln2": (None,),
            "mixer": rec_axes(cfg) if kind == "rec" else T.attn_axes(cfg),
            "ffn": C.mlp_axes()}


def n_superblocks(cfg) -> Tuple[int, int]:
    nb = cfg.num_layers // 3
    tail = cfg.num_layers - nb * 3
    return nb, tail


def init_params(cfg, key):
    k_emb, kb, kt = jax.random.split(key, 3)
    nb, tail = n_superblocks(cfg)

    def block(k):
        ks = jax.random.split(k, 3)
        return {"rec0": _sub_init(ks[0], cfg, "rec"),
                "rec1": _sub_init(ks[1], cfg, "rec"),
                "attn": _sub_init(ks[2], cfg, "attn")}

    p = {
        "embed": C.dense_init(k_emb, (cfg.vocab, cfg.d_model),
                              cfg.d_model, cfg.param_dtype),
        "blocks": jax.vmap(block)(jax.random.split(kb, nb)),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if tail:
        p["tail"] = jax.vmap(lambda k: _sub_init(k, cfg, "rec"))(
            jax.random.split(kt, tail))
    return p


def param_axes(cfg):
    is_ax = lambda x: isinstance(x, tuple)
    stack = lambda t: jax.tree.map(lambda ax: ("layers",) + ax, t,
                                   is_leaf=is_ax)
    nb, tail = n_superblocks(cfg)
    block = {"rec0": _sub_axes(cfg, "rec"), "rec1": _sub_axes(cfg, "rec"),
             "attn": _sub_axes(cfg, "attn")}
    p = {"embed": ("vocab", "fsdp"), "blocks": stack(block), "ln_f": (None,)}
    if tail:
        p["tail"] = stack(_sub_axes(cfg, "rec"))
    return p


def _mlp_sub(p, cfg, x):
    return C.gated_mlp(C.rms_norm(x, p["ln2"], cfg.norm_eps),
                       p["ffn"]["wi_gate"], p["ffn"]["wi_up"],
                       p["ffn"]["wo"], act=cfg.mlp_act,
                       tp_psum=cfg.tp_psum)


def _rec_sub(p, cfg, x):
    h, caches = rec_apply(p["mixer"], cfg,
                          C.rms_norm(x, p["ln1"], cfg.norm_eps))
    x = x + h
    return x + _mlp_sub(p, cfg, x), caches


def _attn_sub(p, cfg, x, positions):
    h, (k, v) = T.attn_apply(p["mixer"], cfg,
                             C.rms_norm(x, p["ln1"], cfg.norm_eps),
                             positions, jnp.int32(cfg.window))
    x = x + h
    return x + _mlp_sub(p, cfg, x), (k, v)


def _head(cfg, params, x):
    x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = C.lm_logits(x, params["embed"].T)   # tied embeddings
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def forward(cfg, params, tokens, patches=None):
    b, s = tokens.shape
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    positions = jnp.arange(s)[None, :]

    def body(x, bp):
        x, _ = _rec_sub(bp["rec0"], cfg, x)
        x, _ = _rec_sub(bp["rec1"], cfg, x)
        x, _ = _attn_sub(bp["attn"], cfg, x, positions)
        return x, None

    x, _ = jax.lax.scan(C.maybe_remat(cfg, body), x, params["blocks"])
    if "tail" in params:
        x, _ = jax.lax.scan(
            C.maybe_remat(cfg, lambda x, lp: (_rec_sub(lp, cfg, x)[0], None)),
            x, params["tail"])
    return _head(cfg, params, x), {"aux_loss": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_cache(cfg, batch, max_len):
    nb, tail = n_superblocks(cfg)
    dr, k = cfg.lru_width, cfg.conv_width
    s = min(max_len, cfg.window)                 # attn layers are local-only
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cache = {
        "rec_conv": jnp.zeros((nb, 2, batch, k - 1, dr), cfg.dtype),
        "rec_h": jnp.zeros((nb, 2, batch, dr), jnp.float32),
        "attn_k": jnp.zeros((nb, batch, s, hkv, hd), cfg.dtype),
        "attn_v": jnp.zeros((nb, batch, s, hkv, hd), cfg.dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if tail:
        cache["tail_conv"] = jnp.zeros((tail, batch, k - 1, dr), cfg.dtype)
        cache["tail_h"] = jnp.zeros((tail, batch, dr), jnp.float32)
    return cache


def cache_axes(cfg):
    nb, tail = n_superblocks(cfg)
    axes = {
        "rec_conv": ("layers", None, "batch", None, "mlp"),
        "rec_h": ("layers", None, "batch", "mlp"),
        "attn_k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "attn_v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "pos": ("batch",),
    }
    if tail:
        axes["tail_conv"] = ("layers", "batch", None, "mlp")
        axes["tail_h"] = ("layers", "batch", "mlp")
    return axes


def prefill(cfg, params, tokens, cache, patches=None):
    b, s = tokens.shape
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    positions = jnp.arange(s)[None, :]
    slen = cache["attn_k"].shape[2]

    def fit(t):
        if s > slen:
            t = t[:, -slen:]
            return jnp.roll(t, shift=s % slen, axis=1)
        if s < slen:
            pad = [(0, 0)] * t.ndim
            pad[1] = (0, slen - s)
            return jnp.pad(t, pad)
        return t

    def body(x, bp):
        x, (ct0, h0) = _rec_sub(bp["rec0"], cfg, x)
        x, (ct1, h1) = _rec_sub(bp["rec1"], cfg, x)
        x, (k, v) = _attn_sub(bp["attn"], cfg, x, positions)
        return x, (jnp.stack([ct0, ct1]).astype(cfg.dtype),
                   jnp.stack([h0, h1]),
                   fit(k.astype(cfg.dtype)), fit(v.astype(cfg.dtype)))

    x, (convs, hs, ks, vs) = jax.lax.scan(body, x, params["blocks"])
    new = {"rec_conv": convs, "rec_h": hs, "attn_k": ks, "attn_v": vs,
           "pos": jnp.full((b,), s, jnp.int32)}
    if "tail" in params:
        def tbody(x, lp):
            x, (ct, h) = _rec_sub(lp, cfg, x)
            return x, (ct.astype(cfg.dtype), h)
        x, (tconvs, ths) = jax.lax.scan(tbody, x, params["tail"])
        new["tail_conv"], new["tail_h"] = tconvs, ths
    return _head(cfg, params, x[:, -1:]), new


def decode_step(cfg, params, cache, tokens):
    x = C.embed_tokens(params["embed"], tokens, cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    pos = cache["pos"]

    def rec_step(p, x, conv, h):
        h_out, conv, h = rec_decode(p["mixer"], cfg,
                                    C.rms_norm(x, p["ln1"], cfg.norm_eps),
                                    conv, h)
        x = x + h_out
        return x + _mlp_sub(p, cfg, x), conv, h

    def body(x, xs):
        bp, conv, h, kc, vc = xs
        x, c0, h0 = rec_step(bp["rec0"], x, conv[0], h[0])
        x, c1, h1 = rec_step(bp["rec1"], x, conv[1], h[1])
        ao, (kc, vc) = T.attn_decode(
            bp["attn"]["mixer"], cfg,
            C.rms_norm(x, bp["attn"]["ln1"], cfg.norm_eps), kc, vc, pos,
            jnp.int32(cfg.window))
        x = x + ao
        x = x + _mlp_sub(bp["attn"], cfg, x)
        return x, (jnp.stack([c0, c1]).astype(cfg.dtype),
                   jnp.stack([h0, h1]), kc, vc)

    x, (convs, hs, ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], cache["rec_conv"], cache["rec_h"],
                  cache["attn_k"], cache["attn_v"]))
    new = {"rec_conv": convs, "rec_h": hs, "attn_k": ks, "attn_v": vs,
           "pos": pos + 1}
    if "tail" in params:
        def tbody(x, xs):
            lp, conv, h = xs
            x, conv, h = rec_step(lp, x, conv, h)
            return x, (conv.astype(cfg.dtype), h)
        x, (tc, th) = jax.lax.scan(
            tbody, x, (params["tail"], cache["tail_conv"], cache["tail_h"]))
        new["tail_conv"], new["tail_h"] = tc, th
    return _head(cfg, params, x), new
