"""Multi-head Latent Attention (DeepSeek-V3).

MLA compresses K/V into a small latent ``c_kv`` (rank 512 + a 64-dim
shared RoPE key) and re-expands per head.  Two execution forms:

* **train/prefill** — naive expansion: k/v materialized per head and fed
  to the shared chunked-attention (matmul-heavy, MXU-friendly);
* **decode** — the *absorbed* form: ``W_UK`` is folded into the query
  projection and ``W_UV`` into the output projection at compile time, so
  attention runs entirely in the 576-dim latent space and the KV cache
  stores only the latent.  This is precisely the paper's Eq. 3 move —
  "the elements of the matrix are parameters known at compile time, so
  the memory layout can be chosen arbitrarily" — promoted from a
  register-shuffle trick to an attention-algebra rewrite.

Cache slices (4-D to match the generic transformer cache):
    c_kv   (B, S, 1, kv_rank)
    k_rope (B, S, 1, rope_dim)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical
from . import common as C


def mla_init(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = C.split_keys(key, 8)
    dt = cfg.param_dtype
    return {
        "q_down": C.dense_init(ks[0], (d, qr), d, dt),
        "q_norm": jnp.zeros((qr,), dt),
        "q_up": C.dense_init(ks[1], (qr, h * (dn + dr)), qr, dt),
        "kv_down": C.dense_init(ks[2], (d, kvr + dr), d, dt),
        "kv_norm": jnp.zeros((kvr,), dt),
        "k_up": C.dense_init(ks[3], (kvr, h * dn), kvr, dt),
        "v_up": C.dense_init(ks[4], (kvr, h * dv), kvr, dt),
        "wo": C.dense_init(ks[5], (h * dv, d), h * dv, dt),
    }


def mla_axes(cfg):
    return {
        "q_down": ("fsdp", None),
        "q_norm": (None,),
        "q_up": (None, "heads"),
        "kv_down": ("fsdp", None),
        "kv_norm": (None,),
        "k_up": (None, "heads"),
        "v_up": (None, "heads"),
        "wo": ("heads", "fsdp"),
    }


def _latent(p, cfg, x, positions):
    """Shared front: queries (nope+rope) and the compressed KV latent."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dr->bsr", x, p["q_down"].astype(x.dtype))
    q = C.rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rn->bsn", q, p["q_up"].astype(x.dtype))
    q = q.reshape(b, s, h, dn + dr)
    q = logical(q, "batch", "seq", "heads", None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = C.apply_rope(q_rope, positions, cfg.rope_theta)

    ckr = jnp.einsum("bsd,dr->bsr", x, p["kv_down"].astype(x.dtype))
    c_kv = C.rms_norm(ckr[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = C.apply_rope(ckr[..., None, cfg.kv_lora_rank:], positions,
                          cfg.rope_theta)          # (B,S,1,dr), shared
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p, cfg, x, positions, window):
    """Full-sequence form: expand K/V per head, run chunked attention.
    Returns (out, (c_kv_4d, k_rope_4d)) cache slices."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _latent(p, cfg, x, positions)

    k_nope = jnp.einsum("bsr,rn->bsn", c_kv,
                        p["k_up"].astype(x.dtype)).reshape(b, s, h, dn)
    v = jnp.einsum("bsr,rn->bsn", c_kv,
                   p["v_up"].astype(x.dtype)).reshape(b, s, h, dv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = logical(k, "batch", "seq", "heads", None)
    v = logical(v, "batch", "seq", "heads", None)

    out = C.chunked_attention(
        q, k, v, causal=True, window_arr=window,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        scale=(dn + dr) ** -0.5,
        compute_dtype=cfg.attn_compute_dtype,
        causal_skip=cfg.causal_skip)
    out = out.reshape(b, s, h * dv)
    y = C.row_parallel_out(out, p["wo"], cfg.tp_psum)
    return (logical(y, "batch", "seq", "embed"),
            (c_kv[:, :, None, :], k_rope))


def mla_decode(p, cfg, x, c_cache, r_cache, lengths, window):
    """Absorbed decode: x (B,1,D); c_cache (B,S,1,kvr); r_cache
    (B,S,1,dr); lengths (B,) tokens already cached."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    positions = lengths[:, None]
    q_nope, q_rope, c_kv, k_rope = _latent(p, cfg, x, positions)

    # Insert the new latent at each sequence's slot.
    c_cache = C.ring_insert(c_cache, c_kv[:, 0, None, :], lengths,
                            cfg.cache_update)
    r_cache = C.ring_insert(r_cache, k_rope[:, 0], lengths,
                            cfg.cache_update)

    # Absorb W_UK into q: q_abs = q_nope @ W_UK^T  -> latent space.
    k_up = p["k_up"].astype(jnp.float32).reshape(kvr, h, dn)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       k_up)                               # (B,H,kvr)
    q_full = jnp.concatenate(
        [q_abs, q_rope[:, 0].astype(jnp.float32)], axis=-1)  # (B,H,kvr+dr)
    kv_full = jnp.concatenate([c_cache[:, :, 0], r_cache[:, :, 0]],
                              axis=-1)                      # (B,S,kvr+dr)
    out_lat = C.decode_attention_jnp(
        q_full.astype(x.dtype), kv_full[:, :, None, :],
        c_cache[:, :, 0][:, :, None, :], lengths + 1,
        window_arr=window, scale=(dn + dr) ** -0.5,
        compute_dtype=cfg.attn_compute_dtype)               # (B,H,kvr)

    # Absorb W_UV into the output projection.
    v_up = p["v_up"].astype(jnp.float32).reshape(kvr, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(jnp.float32), v_up)
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    y = C.row_parallel_out(out, p["wo"], cfg.tp_psum)
    return logical(y, "batch", "seq", "embed"), (c_cache, r_cache)
