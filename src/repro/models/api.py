"""Model API — one uniform surface over all architecture families.

``get_model(cfg)`` returns a ``Model`` whose methods dispatch to the
family implementation.  The launcher, trainer, serving engine, tests and
dry-run all speak only this protocol:

    init(key)                 -> params pytree
    param_axes()              -> logical-axis pytree (same structure)
    forward(params, batch)    -> (logits, extras)          [train]
    init_cache(batch, max_len)-> cache pytree              [serve]
    cache_axes()              -> logical axes for the cache
    prefill(params, batch, cache) -> (logits, cache)
    decode_step(params, cache, tokens) -> (logits, cache)
    loss(params, batch)       -> scalar loss               [train]
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import common as C
from . import rglru, ssm, transformer, whisper

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": rglru,
    "audio": whisper,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    mod: Any

    # -- params --------------------------------------------------------
    def init(self, key) -> Any:
        return self.mod.init_params(self.cfg, key)

    def param_axes(self) -> Any:
        return self.mod.param_axes(self.cfg)

    # -- training ------------------------------------------------------
    def forward(self, params, batch: Dict[str, jnp.ndarray]):
        extra = {}
        if self.cfg.family == "vlm":
            extra["patches"] = batch.get("patches")
        if self.cfg.family == "audio":
            return self.mod.forward(self.cfg, params, batch["tokens"],
                                    frames=batch.get("frames"))
        return self.mod.forward(self.cfg, params, batch["tokens"], **extra)

    def loss(self, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        logits, extras = self.forward(params, batch)
        loss = C.cross_entropy(logits, batch["labels"])
        loss = loss + extras.get("aux_loss", 0.0)
        if self.cfg.mtp and "mtp_hidden" in extras:
            mtp = self.mod.mtp_logits(self.cfg, params,
                                      extras["mtp_hidden"], batch["tokens"])
            mtp_labels = jnp.roll(batch["labels"], -1, axis=1)
            loss = loss + 0.1 * C.cross_entropy(mtp, mtp_labels)
        return loss

    # -- serving -------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        return self.mod.init_cache(self.cfg, batch, max_len)

    def cache_axes(self):
        return self.mod.cache_axes(self.cfg)

    def prefill(self, params, batch: Dict[str, jnp.ndarray], cache):
        kw = {}
        if self.cfg.family == "vlm":
            kw["patches"] = batch.get("patches")
        if self.cfg.family == "audio":
            kw["frames"] = batch.get("frames")
        return self.mod.prefill(self.cfg, params, batch["tokens"], cache,
                                **kw)

    def decode_step(self, params, cache, tokens):
        return self.mod.decode_step(self.cfg, params, cache, tokens)

    def supports_chunked_prefill(self) -> bool:
        """True when the family implements incremental ``prefill_chunk``.

        Transformer-family models (dense/moe) qualify unless they use an
        MLA latent cache or need extra prefill inputs (vlm patches,
        audio frames).  Callers must additionally check that the cache
        is full-context (not a ring) — see ``repro.serve``.
        """
        if not hasattr(self.mod, "prefill_chunk"):
            return False
        if self.cfg.mla:
            return False
        if self.cfg.family in ("vlm", "audio"):
            return False
        return True

    def prefill_chunk(self, params, tokens, cache, start, length):
        """Prefill one fixed-size chunk of a prompt at absolute offset
        ``start`` (see ``transformer.prefill_chunk``)."""
        return self.mod.prefill_chunk(self.cfg, params, tokens, cache,
                                      start, length)


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise KeyError(f"unknown family {cfg.family!r}")
    return Model(cfg, _FAMILY_MODULES[cfg.family])
