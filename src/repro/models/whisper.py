"""Whisper-style encoder-decoder (audio backbone; conv frontend STUB).

Per the assignment spec, the modality frontend is a stub: ``input_specs``
provides precomputed frame embeddings (B, n_frames, D) — the output of
Whisper's two conv layers — and the encoder runs the 6-layer
bidirectional transformer on them.  The decoder is a standard causal
stack with cross-attention; cross-attention K/V are computed ONCE at
prefill from the encoder output and cached — compile-time-known reuse,
the paper's specialization idea applied to the enc-dec topology.

Positions are sinusoidal (computed on the fly) rather than a learned
table so the structural 32k/500k decode shapes don't inflate the param
count beyond the real architecture (noted in DESIGN.md).
Whisper uses plain LayerNorm + non-gated GELU MLPs + MHA (no RoPE).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import logical
from . import common as C


def _sinusoid(positions, d):
    """positions (...,S) -> (...,S,d) standard sinusoidal embedding."""
    half = d // 2
    freq = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(1, half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Plain MHA (LayerNorm models, no RoPE) + standard MLP
# ---------------------------------------------------------------------------
def _mha_init(key, cfg, kv_dim=None):
    d = cfg.d_model
    kv_dim = kv_dim or d
    ks = C.split_keys(key, 4)
    dt = cfg.param_dtype
    n = cfg.n_heads * cfg.head_dim
    return {"wq": C.dense_init(ks[0], (d, n), d, dt),
            "bq": jnp.zeros((n,), dt),
            "wk": C.dense_init(ks[1], (kv_dim, n), kv_dim, dt),
            "wv": C.dense_init(ks[2], (kv_dim, n), kv_dim, dt),
            "bv": jnp.zeros((n,), dt),
            "wo": C.dense_init(ks[3], (n, d), n, dt),
            "bo": jnp.zeros((d,), dt)}


def _mha_axes():
    return {"wq": ("fsdp", "heads"), "bq": ("heads",),
            "wk": ("fsdp", "heads"), "wv": ("fsdp", "heads"),
            "bv": ("heads",), "wo": ("heads", "fsdp"), "bo": (None,)}


def _proj_kv(p, cfg, src):
    b, s, _ = src.shape
    h, hd = cfg.n_heads, cfg.head_dim
    k = jnp.einsum("bsd,dn->bsn", src, p["wk"].astype(src.dtype))
    v = (jnp.einsum("bsd,dn->bsn", src, p["wv"].astype(src.dtype))
         + p["bv"].astype(src.dtype))
    return (logical(k.reshape(b, s, h, hd), "batch", "seq", "heads", None),
            logical(v.reshape(b, s, h, hd), "batch", "seq", "heads", None))


def _proj_q(p, cfg, x):
    b, s, _ = x.shape
    q = (jnp.einsum("bsd,dn->bsn", x, p["wq"].astype(x.dtype))
         + p["bq"].astype(x.dtype))
    return q.reshape(b, s, cfg.n_heads, cfg.head_dim)


def _out(p, cfg, o):
    b, s = o.shape[:2]
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return (C.row_parallel_out(o, p["wo"], cfg.tp_psum)
            + p["bo"].astype(o.dtype))


def _mlp_init(key, cfg):
    k1, k2 = jax.random.split(key)
    dt = cfg.param_dtype
    return {"w1": C.dense_init(k1, (cfg.d_model, cfg.d_ff), cfg.d_model, dt),
            "b1": jnp.zeros((cfg.d_ff,), dt),
            "w2": C.dense_init(k2, (cfg.d_ff, cfg.d_model), cfg.d_ff, dt),
            "b2": jnp.zeros((cfg.d_model,), dt)}


def _mlp_axes():
    return {"w1": ("fsdp", "mlp"), "b1": ("mlp",),
            "w2": ("mlp", "fsdp"), "b2": (None,)}


def _mlp(p, cfg, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype))
                    + p["b1"].astype(x.dtype), approximate=True)
    h = logical(h, "batch", "seq", "mlp")
    return (C.row_parallel_out(h, p["w2"], cfg.tp_psum)
            + p["b2"].astype(x.dtype))


def _ln_init(cfg):
    return {"g": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "b": jnp.zeros((cfg.d_model,), cfg.param_dtype)}


def _ln(p, cfg, x):
    return C.layer_norm(x, p["g"], p["b"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_params(cfg, key):
    ke, kd, kemb = jax.random.split(key, 3)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": _ln_init(cfg), "attn": _mha_init(k1, cfg),
                "ln2": _ln_init(cfg), "mlp": _mlp_init(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": _ln_init(cfg), "self": _mha_init(k1, cfg),
                "ln2": _ln_init(cfg), "cross": _mha_init(k2, cfg),
                "ln3": _ln_init(cfg), "mlp": _mlp_init(k3, cfg)}

    return {
        "embed": C.dense_init(kemb, (cfg.vocab, cfg.d_model),
                              cfg.d_model, cfg.param_dtype),
        "enc": jax.vmap(enc_layer)(
            jax.random.split(ke, cfg.encoder_layers)),
        "enc_ln": _ln_init(cfg),
        "dec": jax.vmap(dec_layer)(jax.random.split(kd, cfg.num_layers)),
        "dec_ln": _ln_init(cfg),
    }


def param_axes(cfg):
    is_ax = lambda x: isinstance(x, tuple)
    stack = lambda t: jax.tree.map(lambda ax: ("layers",) + ax, t,
                                   is_leaf=is_ax)
    ln = {"g": (None,), "b": (None,)}
    enc = {"ln1": ln, "attn": _mha_axes(), "ln2": ln, "mlp": _mlp_axes()}
    dec = {"ln1": ln, "self": _mha_axes(), "ln2": ln, "cross": _mha_axes(),
           "ln3": ln, "mlp": _mlp_axes()}
    return {"embed": ("vocab", "fsdp"), "enc": stack(enc), "enc_ln": ln,
            "dec": stack(dec), "dec_ln": ln}


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------
def encode(cfg, params, frames):
    """frames: (B, n_frames, D) precomputed conv-frontend output (stub)."""
    b, s, _ = frames.shape
    x = frames.astype(cfg.dtype) + _sinusoid(jnp.arange(s),
                                             cfg.d_model).astype(cfg.dtype)
    x = logical(x, "batch", "seq", "embed")

    def body(x, lp):
        xn = _ln(lp["ln1"], cfg, x)
        q = _proj_q(lp["attn"], cfg, xn)
        k, v = _proj_kv(lp["attn"], cfg, xn)
        o = C.chunked_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                compute_dtype=cfg.attn_compute_dtype)
        x = x + _out(lp["attn"], cfg, o)
        return x + _mlp(lp["mlp"], cfg, _ln(lp["ln2"], cfg, x)), None

    x, _ = jax.lax.scan(C.maybe_remat(cfg, body), x, params["enc"])
    return _ln(params["enc_ln"], cfg, x)


# ---------------------------------------------------------------------------
# Decoder (train)
# ---------------------------------------------------------------------------
def forward(cfg, params, tokens, frames=None):
    """Teacher-forced training pass: (tokens, frames) -> logits."""
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    x = (C.embed_tokens(params["embed"], tokens, cfg.dtype)
         + _sinusoid(jnp.arange(s), cfg.d_model).astype(cfg.dtype))

    def body(x, lp):
        xn = _ln(lp["ln1"], cfg, x)
        q = _proj_q(lp["self"], cfg, xn)
        k, v = _proj_kv(lp["self"], cfg, xn)
        o = C.chunked_attention(q, k, v, causal=True,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                compute_dtype=cfg.attn_compute_dtype,
                                causal_skip=cfg.causal_skip)
        x = x + _out(lp["self"], cfg, o)
        xn = _ln(lp["ln2"], cfg, x)
        q = _proj_q(lp["cross"], cfg, xn)
        k, v = _proj_kv(lp["cross"], cfg, enc)
        o = C.chunked_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                compute_dtype=cfg.attn_compute_dtype)
        x = x + _out(lp["cross"], cfg, o)
        return x + _mlp(lp["mlp"], cfg, _ln(lp["ln3"], cfg, x)), None

    x, _ = jax.lax.scan(C.maybe_remat(cfg, body), x, params["dec"])
    x = _ln(params["dec_ln"], cfg, x)
    logits = C.lm_logits(x, params["embed"].T)   # whisper ties embeddings
    return logits, {"aux_loss": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_cache(cfg, batch, max_len):
    h, hd, L = cfg.n_heads, cfg.head_dim, cfg.num_layers
    nf = cfg.n_frames
    return {
        "k": jnp.zeros((L, batch, max_len, h, hd), cfg.dtype),
        "v": jnp.zeros((L, batch, max_len, h, hd), cfg.dtype),
        "xk": jnp.zeros((L, batch, nf, h, hd), cfg.dtype),
        "xv": jnp.zeros((L, batch, nf, h, hd), cfg.dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg):
    kv = ("layers", "batch", "kv_seq", "heads", "head_dim")
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": ("batch",)}


def prefill(cfg, params, tokens, cache, frames=None):
    """Encode audio, run the prompt through the decoder, cache both
    self-attention K/V and the (encoder-constant) cross-attention K/V."""
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    slen = cache["k"].shape[2]
    x = (C.embed_tokens(params["embed"], tokens, cfg.dtype)
         + _sinusoid(jnp.arange(s), cfg.d_model).astype(cfg.dtype))

    def fit(t):
        if s < slen:
            return jnp.pad(t, ((0, 0), (0, slen - s), (0, 0), (0, 0)))
        return t[:, -slen:]

    def body(x, lp):
        xn = _ln(lp["ln1"], cfg, x)
        q = _proj_q(lp["self"], cfg, xn)
        k, v = _proj_kv(lp["self"], cfg, xn)
        o = C.chunked_attention(q, k, v, causal=True,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                compute_dtype=cfg.attn_compute_dtype,
                                causal_skip=cfg.causal_skip)
        x = x + _out(lp["self"], cfg, o)
        xn = _ln(lp["ln2"], cfg, x)
        q = _proj_q(lp["cross"], cfg, xn)
        xk, xv = _proj_kv(lp["cross"], cfg, enc)
        o = C.chunked_attention(q, xk, xv, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + _out(lp["cross"], cfg, o)
        x = x + _mlp(lp["mlp"], cfg, _ln(lp["ln3"], cfg, x))
        return x, (fit(k.astype(cfg.dtype)), fit(v.astype(cfg.dtype)),
                   xk.astype(cfg.dtype), xv.astype(cfg.dtype))

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec"])
    x = _ln(params["dec_ln"], cfg, x)
    logits = C.lm_logits(x[:, -1:], params["embed"].T)
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs,
                    "pos": jnp.full((b,), s, jnp.int32)}


def decode_step(cfg, params, cache, tokens):
    b = tokens.shape[0]
    pos = cache["pos"]
    x = (C.embed_tokens(params["embed"], tokens, cfg.dtype)
         + _sinusoid(pos[:, None], cfg.d_model).astype(cfg.dtype))

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        xn = _ln(lp["ln1"], cfg, x)
        q = _proj_q(lp["self"], cfg, xn)
        k, v = _proj_kv(lp["self"], cfg, xn)
        kc = C.ring_insert(kc, k[:, 0], pos, cfg.cache_update)
        vc = C.ring_insert(vc, v[:, 0], pos, cfg.cache_update)
        o = C.decode_attention_jnp(q[:, 0], kc, vc,
                                   jnp.minimum(pos + 1, kc.shape[1]),
                                   compute_dtype=cfg.attn_compute_dtype)
        x = x + _out(lp["self"], cfg, o[:, None])
        xn = _ln(lp["ln2"], cfg, x)
        q = _proj_q(lp["cross"], cfg, xn)
        nf = xk.shape[1]
        o = C.decode_attention_jnp(q[:, 0], xk, xv,
                                   jnp.full((b,), nf, jnp.int32),
                                   compute_dtype=cfg.attn_compute_dtype)
        x = x + _out(lp["cross"], cfg, o[:, None])
        x = x + _mlp(lp["mlp"], cfg, _ln(lp["ln3"], cfg, x))
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = _ln(params["dec_ln"], cfg, x)
    logits = C.lm_logits(x, params["embed"].T)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}
