from .api import Model, get_model

__all__ = ["Model", "get_model"]
