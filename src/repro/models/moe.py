"""Mixture-of-Experts FFN with expert-parallel dispatch.

Covers mixtral (8e top-2, softmax router) and deepseek-v3 (1 shared +
256 routed top-8, sigmoid router with in-group normalization).

Distribution design (the compile-time-layout idea applied to EP):
experts live on the "model" mesh axis; activations entering the FFN are
replicated over "model" (they were just all-reduced by the attention
output projection).  Dispatch therefore needs **no collective at all**:
each model shard scatters the tokens routed to *its own* experts into a
local (E_local, C, D) buffer, runs the expert FFNs as dense matmuls,
gathers back, and one ``psum`` over "model" — the same all-reduce a
dense TP FFN would need — combines expert outputs.  Expressed with
``shard_map``; on a single device (tests) the same local function runs
without a mesh.

Two sharding modes, chosen at compile time from (E, n_model):
* **EP**  (E % n_model == 0): experts split across shards (deepseek-v3:
  256/16 = 16 experts per shard).
* **TP**  (n_model % E == 0): every shard holds all experts but only a
  1/r slice of each expert's hidden width (mixtral: 8 experts on a
  16-way axis -> r = 2).  Dispatch is replicated, the expert matmuls are
  split, the same trailing psum combines partial outputs.

Capacity-based token dropping (capacity factor ``cfg.moe_cf``) keeps
every shape static — the paper's "statically known properties"
requirement in MoE form.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import current_mesh, logical

try:  # jax >= 0.4.35
    from jax import shard_map as _shard_map_mod
    shard_map = _shard_map_mod
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

import inspect as _inspect

#: jax renamed check_rep -> check_vma; pass whichever this version takes.
_CHECK_KW = ("check_vma" if "check_vma" in
             _inspect.signature(shard_map).parameters else "check_rep")


def moe_init(key, cfg):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    init = lambda k, shape, fan: (
        jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan)
    ).astype(cfg.param_dtype)
    p = {
        "router": init(ks[0], (d, e), d),
        "wi_gate": init(ks[1], (e, d, f), d),
        "wi_up": init(ks[2], (e, d, f), d),
        "wo": init(ks[3], (e, f, d), f),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared"] = {
            "wi_gate": init(ks[4], (d, fs), d),
            "wi_up": init(ks[5], (d, fs), d),
            "wo": init(ks[6], (fs, d), fs),
        }
    return p


def moe_axes(cfg):
    # EP mode shards the expert dim; TP mode shards the hidden width.
    ep = True
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        ep = cfg.n_experts % mesh.shape["model"] == 0
    if ep:
        p = {"router": (None, None),
             "wi_gate": ("experts", "fsdp", None),
             "wi_up": ("experts", "fsdp", None),
             "wo": ("experts", None, "fsdp")}
    else:
        p = {"router": (None, None),
             "wi_gate": (None, "fsdp", "mlp"),
             "wi_up": (None, "fsdp", "mlp"),
             "wo": (None, "mlp", "fsdp")}
    if cfg.n_shared:
        p["shared"] = {"wi_gate": ("fsdp", "mlp"), "wi_up": ("fsdp", "mlp"),
                       "wo": ("mlp", "fsdp")}
    return p


# ---------------------------------------------------------------------------
def _route(cfg, x, router):
    """Top-k routing.  x: (T, D) -> idx (T,k), weights (T,k), aux loss."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    if cfg.router_fn == "softmax":            # mixtral
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
    else:                                      # deepseek-v3 sigmoid router
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, cfg.top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, axis=-1, keepdims=True) + 1e-20)
    e = cfg.n_experts
    sel = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(sel, axis=0) * jnp.mean(probs, axis=0))
    return idx, w.astype(jnp.float32), aux


def _positions(cfg, idx):
    """Capacity slot of each (token, choice) within its expert — exact
    counting, computed one choice column at a time so the transient is
    (T, E) instead of (T*k, E)."""
    t, k = idx.shape
    e = cfg.n_experts
    base = jnp.zeros((e,), jnp.int32)
    cols = []
    for j in range(k):
        oh = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)
        pos_j = jnp.cumsum(oh, axis=0) - 1 + base[None, :]
        cols.append(jnp.take_along_axis(pos_j, idx[:, j:j + 1], axis=1)[:, 0])
        base = base + jnp.sum(oh, axis=0)
    return jnp.stack(cols, axis=1)             # (T, k)


def _expert_ffn(cfg, buf, wi_gate, wi_up, wo):
    """buf: (E_l, C, D) -> (E_l, C, D) through per-expert gated MLPs."""
    h_g = jnp.einsum("ecd,edf->ecf", buf, wi_gate.astype(buf.dtype))
    h_u = jnp.einsum("ecd,edf->ecf", buf, wi_up.astype(buf.dtype))
    act = jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu
    h = act(h_g) * h_u
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(buf.dtype))


def _moe_local(cfg, x, router, wi_gate, wi_up, wo, *, e_offset, axis):
    """Per-shard MoE.  x: (T_local, D); expert weights: the local slice
    (owning global experts [e_offset, e_offset + E_local)); psum over
    `axis` (None on a single device)."""
    t, d = x.shape
    e_local = wi_gate.shape[0]
    cap = max(1, int(t * cfg.top_k * cfg.moe_cf / cfg.n_experts))

    idx, w, aux = _route(cfg, x, router)                 # global expert ids
    pos = _positions(cfg, idx)                           # (T, k)

    flat_e = idx.reshape(-1)
    flat_p = pos.reshape(-1)
    mine = ((flat_e >= e_offset) & (flat_e < e_offset + e_local)
            & (flat_p < cap))
    local_e = jnp.clip(flat_e - e_offset, 0, e_local - 1)
    slot = jnp.where(mine, local_e * cap + jnp.clip(flat_p, 0, cap - 1),
                     e_local * cap)                      # overflow row

    xk = jnp.repeat(x, cfg.top_k, axis=0)                # (T*k, D)
    buf = jnp.zeros((e_local * cap + 1, d), x.dtype).at[slot].add(
        jnp.where(mine[:, None], xk, jnp.zeros_like(xk)))
    buf = buf[:-1].reshape(e_local, cap, d)

    out_buf = _expert_ffn(cfg, buf, wi_gate, wi_up, wo)

    gathered = jnp.concatenate(
        [out_buf.reshape(e_local * cap, d), jnp.zeros((1, d), x.dtype)])
    yk = gathered[slot] * (w.reshape(-1, 1) * mine[:, None]).astype(x.dtype)
    y = jnp.sum(yk.reshape(t, cfg.top_k, d), axis=1)

    if axis is not None:
        y = jax.lax.psum(y, axis)
        aux = jax.lax.pmean(aux, axis)
    return y, aux


# ---------------------------------------------------------------------------
def moe_apply(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux-loss scalar)."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    mesh = current_mesh()

    if mesh is None or "model" not in mesh.axis_names:
        y, aux = _moe_local(cfg, flat, p["router"], p["wi_gate"],
                            p["wi_up"], p["wo"], e_offset=0, axis=None)
    else:
        n_model = mesh.shape["model"]
        batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        bspec = batch if batch else None
        ep = cfg.n_experts % n_model == 0
        if not ep and n_model % cfg.n_experts != 0:
            raise ValueError(
                f"n_experts={cfg.n_experts} incompatible with model axis "
                f"{n_model}")
        e_per = cfg.n_experts // n_model if ep else cfg.n_experts

        def shard_fn(flat_l, router, wig, wiu, wo):
            e_off = jax.lax.axis_index("model") * e_per if ep else 0
            return _moe_local(cfg, flat_l, router, wig, wiu, wo,
                              e_offset=e_off, axis="model")

        wspec = (P("model", None, None) if ep else P(None, None, "model"))
        wospec = (P("model", None, None) if ep else P(None, "model", None))
        y, aux = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(bspec, None), P(None, None), wspec, wspec, wospec),
            out_specs=(P(bspec, None), P()),
            **{_CHECK_KW: False},
        )(flat, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
        aux = aux.reshape(())

    y = y.reshape(b, s, d)
    if cfg.n_shared:
        sp = p["shared"]
        h_g = jnp.einsum("bsd,df->bsf", x, sp["wi_gate"].astype(x.dtype))
        h_u = jnp.einsum("bsd,df->bsf", x, sp["wi_up"].astype(x.dtype))
        h = jax.nn.silu(h_g) * h_u
        from .common import row_parallel_out
        y = y + row_parallel_out(h, sp["wo"], cfg.tp_psum)
    return logical(y, "batch", "seq", "embed"), aux
