"""repro — a JIT compiler for neural network inference (JAX/Pallas).

The public surface is one funnel::

    import repro

    exe = repro.compile(graph, repro.CompileOptions(target="jit"))
    out = exe(input=x)

See ``repro.api`` for targets, options and the executable cache;
``repro.core`` for the graph IR, passes and the oracle interpreter.

Attribute access is lazy (PEP 562): ``import repro`` must stay free of
jax so entry points like ``repro.launch.dryrun`` can pin ``XLA_FLAGS``
before jax initializes.
"""

_API_NAMES = (
    "Bucket",
    "BucketPolicy",
    "CompileOptions",
    "Executable",
    "MeshSpec",
    "MeshUnavailableError",
    "SchedulerOptions",
    "Signature",
    "available_frontends",
    "available_targets",
    "compile",
    "deserialize",
    "prune",
    "register_frontend",
    "register_target",
    "serve",
    "trace",
)

__all__ = list(_API_NAMES)


def __getattr__(name):
    if name == "serve":
        # the serve subpackage is a callable module: repro.serve(exe, …)
        # and repro.serve.Scheduler resolve to the same object however
        # the import happens (importing it also binds the attribute)
        import importlib
        return importlib.import_module(".serve", __name__)
    if name in _API_NAMES:
        from . import api
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_API_NAMES))
