#!/usr/bin/env python3
"""Relative-link checker for the repo's markdown (stdlib-only; CI:
docs-gates job).

Scans README.md, ROADMAP.md and everything under docs/ for markdown
links, and verifies that every *relative* target resolves to a file or
directory in the repo (fragments are stripped; ``http(s)://`` and
``mailto:`` targets are skipped — external availability is not this
gate's business).  Also resolves ``path.py:symbol`` code pointers used
throughout docs/ down to the file part.

Usage::

    python scripts/check_links.py            # gate (exit 1 on any broken link)
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Files and directories whose relative links must resolve.
SOURCES = ("README.md", "ROADMAP.md", "docs")

#: ``[text](target)`` — non-greedy target, tolerates titles after a space.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def _markdown_files():
    for src in SOURCES:
        path = os.path.join(REPO, src)
        if os.path.isfile(path):
            yield path
        elif os.path.isdir(path):
            for dirpath, _, names in os.walk(path):
                for name in sorted(names):
                    if name.endswith(".md"):
                        yield os.path.join(dirpath, name)


def check_file(path: str):
    """Yield (lineno, target) for each broken relative link in ``path``."""
    base = os.path.dirname(path)
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(SKIP_SCHEMES):
                    continue
                # strip fragment, then any :symbol / :line suffix
                target = target.split("#", 1)[0]
                if not target:
                    continue
                file_part = target.split(":", 1)[0]
                resolved = os.path.normpath(os.path.join(base, file_part))
                if not os.path.exists(resolved):
                    yield lineno, m.group(1)


def main() -> int:
    """Walk every markdown source; exit 1 when a relative link is broken."""
    broken = []
    n_files = 0
    for path in _markdown_files():
        n_files += 1
        rel = os.path.relpath(path, REPO)
        for lineno, target in check_file(path):
            broken.append((rel, lineno, target))
    for rel, lineno, target in broken:
        print(f"broken link: {rel}:{lineno} -> {target}")
    print(f"link check: {n_files} files scanned, {len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
