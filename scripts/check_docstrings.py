#!/usr/bin/env python3
"""Docstring coverage gate (stdlib-only; CI: docs-gates job).

Walks ``src/repro/api``, ``src/repro/autotune``, ``src/repro/dist``,
``src/repro/kernels``, ``src/repro/launch``, ``src/repro/runtime``,
``src/repro/replay`` and ``src/repro/serve`` with the ``ast`` module,
counts docstrings on
modules, public classes and public functions/methods (names not starting
with ``_``, plus ``__init__`` is exempt), and fails if coverage drops
below the recorded floor.

The floor is a ratchet: raise it when coverage improves, never lower it
to absorb an undocumented addition.

Usage::

    python scripts/check_docstrings.py            # gate (exit 1 below floor)
    python scripts/check_docstrings.py --list     # show undocumented objects
"""

from __future__ import annotations

import argparse
import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Packages whose public surface must be documented.
PACKAGES = ("src/repro/api", "src/repro/autotune", "src/repro/dist",
            "src/repro/kernels", "src/repro/launch", "src/repro/runtime",
            "src/repro/replay", "src/repro/serve")

#: Minimum fraction of public objects with docstrings.  Ratchet only
#: upward.  Recorded at 1.00 in PR 7 (every public object documented);
#: kept a hair under to tolerate a __main__ shim.
FLOOR = 0.97


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def _walk_objects(tree: ast.Module, path: str):
    """Yield (qualname, has_docstring) for the module, public classes,
    and public functions/methods."""
    yield path, ast.get_docstring(tree) is not None

    def visit(node, prefix):
        for child in node.body if hasattr(node, "body") else ():
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_public(child.name):
                    yield (f"{prefix}{child.name}",
                           ast.get_docstring(child) is not None)
            elif isinstance(child, ast.ClassDef):
                if _is_public(child.name):
                    yield (f"{prefix}{child.name}",
                           ast.get_docstring(child) is not None)
                    yield from visit(child, f"{prefix}{child.name}.")

    yield from visit(tree, f"{path}::")


def collect():
    rows = []
    for pkg in PACKAGES:
        root = os.path.join(REPO, pkg)
        for dirpath, _, names in os.walk(root):
            for name in sorted(names):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, REPO)
                with open(path) as f:
                    tree = ast.parse(f.read(), filename=rel)
                rows.extend(_walk_objects(tree, rel))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="list undocumented public objects")
    args = ap.parse_args(argv)

    rows = collect()
    documented = sum(1 for _, ok in rows if ok)
    total = len(rows)
    coverage = documented / total if total else 1.0
    missing = [name for name, ok in rows if not ok]
    if args.list or missing:
        for name in missing:
            print(f"undocumented: {name}")
    print(f"docstring coverage: {documented}/{total} = {coverage:.1%} "
          f"(floor {FLOOR:.0%})")
    if coverage < FLOOR:
        print(f"FAIL: coverage fell below the recorded floor; document "
              f"the objects above (never lower the floor)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
