#!/usr/bin/env bash
# Smoke test for the unified repro.compile() API:
#   1. compile one small CNN per target ("interpret", "jit", "pallas")
#      and check each against the oracle;
#   2. re-compile the "jit" model in a SECOND PROCESS and assert the
#      persistent executable cache hits (no XLA recompilation).
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export REPRO_CACHE_DIR="$(mktemp -d)"
trap 'rm -rf "$REPRO_CACHE_DIR"' EXIT

run_targets() {
python - "$1" <<'EOF'
import sys

import numpy as np

import repro
from repro.core import ModelBuilder

expect_hit = sys.argv[1] == "hit"

mb = ModelBuilder().seed(0)
x = mb.input((16, 16, 3))
h = mb.conv2d(x, 8, (3, 3), activation="relu")
h = mb.batchnorm(h)
h = mb.maxpool(h)
h = mb.global_avg_pool(h)
out = mb.softmax(mb.dense(h, 4))
g = mb.build([out])
img = np.random.default_rng(0).standard_normal((1, 16, 16, 3)).astype(np.float32)

want = np.asarray(
    repro.compile(g, repro.CompileOptions(target="interpret"))(input=img)[out])
for target in ("jit", "pallas"):
    exe = repro.compile(g, repro.CompileOptions(target=target))
    got = np.asarray(exe(input=img)[out])
    err = float(np.abs(want - got).max())
    info = exe.cache_info()
    print(f"[smoke] target={target:<9} max|err|={err:.2e} "
          f"compile={exe.compile_time * 1e3:.0f}ms cache={info}")
    assert err < 1e-4, f"{target} disagrees with the oracle: {err}"
    if expect_hit and target == "jit":
        assert info["hits"] >= 1 and info["misses"] == 0, \
            f"expected a cache hit in the second process, got {info}"
print(f"[smoke] {'cache-hit' if expect_hit else 'cold'} pass OK")
EOF
}

echo "[smoke] pass 1 (cold cache: $REPRO_CACHE_DIR)"
run_targets cold
echo "[smoke] pass 2 (fresh process, cache must hit)"
run_targets hit
echo "[smoke] OK"
