#!/usr/bin/env bash
# Smoke test for the unified repro.compile() API:
#   1. compile one small CNN per target ("interpret", "jit", "pallas")
#      and check each against the oracle;
#   2. trace-compile a plain function (the "trace" frontend) on every
#      target and check its multi-output signature;
#   3. re-run both in a SECOND PROCESS and assert the persistent
#      executable cache hits (no XLA recompilation) — this guards the
#      signature-bearing cache-key schema against churn.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export REPRO_CACHE_DIR="$(mktemp -d)"
trap 'rm -rf "$REPRO_CACHE_DIR"' EXIT

run_targets() {
python - "$1" <<'EOF'
import sys

import numpy as np

import repro
from repro.core import ModelBuilder
from repro.frontends import ops as F

expect_hit = sys.argv[1] == "hit"

mb = ModelBuilder().seed(0)
x = mb.input((16, 16, 3))
h = mb.conv2d(x, 8, (3, 3), activation="relu")
h = mb.batchnorm(h)
h = mb.maxpool(h)
h = mb.global_avg_pool(h)
out = mb.softmax(mb.dense(h, 4))
g = mb.build([out])
img = np.random.default_rng(0).standard_normal((1, 16, 16, 3)).astype(np.float32)

want = np.asarray(
    repro.compile(g, repro.CompileOptions(target="interpret"))(input=img)[out])
for target in ("jit", "pallas"):
    exe = repro.compile(g, repro.CompileOptions(target=target))
    got = np.asarray(exe(input=img)[out])
    err = float(np.abs(want - got).max())
    info = exe.cache_info()
    print(f"[smoke] target={target:<9} max|err|={err:.2e} "
          f"compile={exe.compile_time * 1e3:.0f}ms cache={info}")
    assert err < 1e-4, f"{target} disagrees with the oracle: {err}"
    if expect_hit and target == "jit":
        assert info["hits"] >= 1 and info["misses"] == 0, \
            f"expected a cache hit in the second process, got {info}"

# -- the trace frontend: a plain function, multi-output signature -------
rng = np.random.default_rng(1)
k = rng.standard_normal((3, 3, 3, 8)).astype(np.float32)
w1 = rng.standard_normal((8, 4)).astype(np.float32)
w2 = rng.standard_normal((8, 2)).astype(np.float32)

def two_head(image):
    h = F.global_avg_pool(F.conv2d(image, k, activation="relu"))
    return {"probs": F.softmax(F.dense(h, w1)), "embed": F.dense(h, w2)}

tg = repro.trace(two_head, (16, 16, 3))
ref = repro.compile(tg, repro.CompileOptions(target="interpret"))(img)
assert list(ref) == ["probs", "embed"], f"signature lost: {list(ref)}"
for target in ("jit", "pallas"):
    exe = repro.compile(tg, repro.CompileOptions(target=target))
    got = exe(img)                       # positional, signature-bound
    errs = {n: float(np.abs(np.asarray(ref[n]) - np.asarray(got[n])).max())
            for n in ref}
    info = exe.cache_info()
    print(f"[smoke] trace:{target:<9} max|err|={max(errs.values()):.2e} "
          f"outputs={list(got)} cache={info}")
    assert list(got) == ["probs", "embed"]
    assert max(errs.values()) < 1e-4, f"trace/{target} vs oracle: {errs}"
    if expect_hit:
        assert info["hits"] >= 1 and info["misses"] == 0, \
            f"expected a trace-frontend cache hit (signature-bearing " \
            f"key) in the second process, got {info}"
print(f"[smoke] {'cache-hit' if expect_hit else 'cold'} pass OK")
EOF
}

echo "[smoke] pass 1 (cold cache: $REPRO_CACHE_DIR)"
run_targets cold
echo "[smoke] pass 2 (fresh process, cache must hit)"
run_targets hit
echo "[smoke] OK"
