"""Roofline table from the dry-run artifacts (§Roofline).

Reads benchmarks/artifacts/dryrun/*.json (written by
``repro.launch.dryrun``) and prints the three-term roofline per
(arch × shape × mesh): compute / memory / collective seconds, the
dominant term, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh_kind: str = None) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh_kind and rec.get("mesh_kind") != mesh_kind:
            continue
        rows.append(rec)
    return rows


def fmt_row(r: Dict) -> str:
    if r.get("status") == "skipped":
        return (f"{r['arch']:<18} {r['shape']:<12} "
                f"SKIPPED ({r['reason'][:48]})")
    return (f"{r['arch']:<18} {r['shape']:<12} "
            f"{r['compute_s']:>9.3f} {r['memory_s']:>9.3f} "
            f"{r['collective_s']:>9.3f}  {r['bottleneck']:<10} "
            f"{r['useful_flops_ratio']:>6.2f} "
            f"{r['roofline_fraction']:>7.4f}")


def main() -> None:
    for kind in ("single", "multi"):
        rows = load(kind)
        if not rows:
            continue
        print(f"\n=== mesh: {kind} "
              f"({'16×16=256' if kind == 'single' else '2×16×16=512'} "
              f"chips) ===")
        hdr = (f"{'arch':<18} {'shape':<12} {'compute_s':>9} "
               f"{'memory_s':>9} {'coll_s':>9}  {'bottleneck':<10} "
               f"{'useful':>6} {'rf':>7}")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(fmt_row(r))


if __name__ == "__main__":
    main()
