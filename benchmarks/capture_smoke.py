"""Capture a replayable compile bundle for one Table-1 config.

CI's bench-smoke job runs this for C-BH, then immediately replays the
bundle in the same job (``python -m repro.replay <bundle>``) as a
zero-divergence assert, and uploads it as a build artifact next to
``BENCH_pr.json`` — so any perf or accuracy question about a CI run can
be reproduced offline from the artifact alone.

Usage::

    python -m benchmarks.capture_smoke --config C-BH \
        --out benchmarks/artifacts/capture-C-BH [--autotune full]
"""

from __future__ import annotations

import argparse
import os
from typing import Optional, Sequence

import repro

from .table1_models import SUITE


def capture(config: str, out: str, *, autotune: str = "full",
            budget_ms: float = 1000.0, batch_size: int = 1) -> str:
    """Compile ``config`` with capture enabled; returns the bundle dir."""
    g = SUITE[config]()
    exe = repro.compile(g, repro.CompileOptions(
        target="pallas", autotune=autotune,
        autotune_budget_ms=budget_ms, capture=out))
    exe.ensure_compiled(batch_size)
    return exe.capture_path


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="C-BH",
                    help=f"one of {sorted(SUITE)} (default: C-BH)")
    ap.add_argument("--out", required=True,
                    help="bundle directory to write")
    ap.add_argument("--autotune", default="full",
                    choices=("off", "cached", "full"))
    ap.add_argument("--autotune-budget-ms", type=float, default=1000.0)
    ap.add_argument("--batch-size", type=int, default=1)
    args = ap.parse_args(argv)
    if args.config not in SUITE:
        raise SystemExit(f"unknown config {args.config!r}; "
                         f"choose from {sorted(SUITE)}")
    path = capture(args.config, args.out, autotune=args.autotune,
                   budget_ms=args.autotune_budget_ms,
                   batch_size=args.batch_size)
    n_files = sum(len(f) for _, _, f in os.walk(path))
    print(f"[capture_smoke] wrote bundle {path} ({n_files} files); "
          f"replay with: python -m repro.replay {path}")


if __name__ == "__main__":
    main()
