"""Frontend-layer microbenchmarks: model construction and trace cost.

Two questions the frontend PR answers with numbers:

  1. *Construction scales linearly.*  ``ModelBuilder`` used to re-run
     full-graph shape inference per layer (O(n²) in layers); the
     incremental spec cache makes it O(n).  This script times an
     N-layer MLP build at several depths so a regression back to
     quadratic is obvious (the per-layer cost column would grow with
     depth instead of staying flat).

  2. *Tracing costs what building costs.*  ``repro.trace`` over an
     equivalent plain function should be within noise of the builder —
     both are one ``add_node`` per layer — and the two graphs must
     produce identical compiled outputs.

Usage::

    PYTHONPATH=src python -m benchmarks.frontend_bench [--layers 64 256 1024]
                                                       [--width 64] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional, Sequence

import numpy as np

import repro
from repro.core import ModelBuilder
from repro.frontends import ops as F


def build_mlp(layers: int, width: int):
    mb = ModelBuilder().seed(0)
    h = mb.input((width,))
    for _ in range(layers):
        h = mb.dense(h, width, activation="relu")
    return mb.build([h])


def trace_mlp(params: Dict[str, np.ndarray], layers: int, width: int):
    def fn(input):                                  # noqa: A002 (match builder)
        h = input
        for i in range(layers):
            h = F.dense(h, params[f"dense_{2 * i + 1}/kernel"],
                        params[f"dense_{2 * i + 1}/bias"],
                        activation="relu")
        return h

    return repro.trace(fn, (width,))


def run(layers_list: Sequence[int], width: int) -> Dict[str, Dict[str, float]]:
    rows: Dict[str, Dict[str, float]] = {}
    for layers in layers_list:
        t0 = time.perf_counter()
        g = build_mlp(layers, width)
        t_build = time.perf_counter() - t0

        t0 = time.perf_counter()
        tg = trace_mlp(g.params, layers, width)
        t_trace = time.perf_counter() - t0

        x = np.random.default_rng(0).standard_normal(
            (1, width)).astype(np.float32)
        a = repro.compile(g, target="interpret")(x)
        b = repro.compile(tg, target="interpret")(x)
        err = float(np.abs(np.asarray(list(a.values())[0])
                           - np.asarray(list(b.values())[0])).max())

        rows[str(layers)] = {
            "build_ms": t_build * 1e3,
            "build_us_per_layer": t_build / layers * 1e6,
            "trace_ms": t_trace * 1e3,
            "trace_us_per_layer": t_trace / layers * 1e6,
            "trace_vs_build_err": err,
        }
    return rows


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--layers", type=int, nargs="*", default=[64, 256, 1024])
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--json", metavar="PATH")
    args = ap.parse_args(argv)

    rows = run(args.layers, args.width)
    hdr = (f"{'layers':>7} {'build ms':>9} {'µs/layer':>9} "
           f"{'trace ms':>9} {'µs/layer':>9} {'max err':>9}")
    print(hdr)
    print("-" * len(hdr))
    for n, r in rows.items():
        print(f"{n:>7} {r['build_ms']:>9.1f} {r['build_us_per_layer']:>9.1f} "
              f"{r['trace_ms']:>9.1f} {r['trace_us_per_layer']:>9.1f} "
              f"{r['trace_vs_build_err']:>9.2e}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "frontend", "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
