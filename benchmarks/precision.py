"""Precision of the fast activations (paper §3.4) and of the whole
compiled pipeline vs the SimpleNN oracle — the paper's "approximating
activation functions … impacts the precision" quantified.

The quantization section extends the same question to the calibrated
low-precision modes: for every Table-1 config, the bf16 and int8
compiled outputs are diffed against the f32 oracle (max_abs and
max_rel), which is the accuracy half of the precision gate's contract
(the speed half lives in ``benchmarks/table1.py --precision``)."""

from __future__ import annotations

import argparse
import json
import platform
from typing import Dict, Optional, Sequence

import numpy as np

import repro
from repro.kernels.fast_act import ref as fa

from .table1_models import SUITE


def activation_errors() -> Dict[str, Dict[str, float]]:
    x = np.linspace(-8, 8, 100_001, dtype=np.float32)
    out = {}
    for fn in ("exp", "tanh", "sigmoid"):
        approx = np.asarray(fa.FAST[fn](x))
        exact = np.asarray(fa.EXACT[fn](x))
        denom = np.maximum(np.abs(exact), 1e-6)
        out[fn] = {
            "max_abs": float(np.max(np.abs(approx - exact))),
            "max_rel": float(np.max(np.abs(approx - exact) / denom)),
        }
    # softmax over a batch of logit-ish rows
    rng = np.random.default_rng(0)
    z = rng.standard_normal((256, 64)).astype(np.float32) * 4
    a = np.asarray(fa.fast_softmax(z))
    e = np.asarray(fa.EXACT["softmax"](z, axis=-1))
    out["softmax"] = {"max_abs": float(np.max(np.abs(a - e))),
                      "max_rel": float("nan")}
    return out


def end_to_end_errors() -> Dict[str, Dict[str, float]]:
    rng = np.random.default_rng(1)
    out = {}
    for name in ("C-HTWK", "C-BH", "Segmenter"):
        g = SUITE[name]()
        in_name = next(iter(g.inputs))
        x = rng.standard_normal((2,) + g.inputs[in_name].shape) \
            .astype(np.float32)
        out_name = g.outputs[0]
        oracle = repro.compile(g, repro.CompileOptions(target="interpret"))
        want = np.asarray(oracle(**{in_name: x})[out_name])
        exact = np.asarray(
            repro.compile(g, repro.CompileOptions())(**{in_name: x})[out_name])
        fast = np.asarray(
            repro.compile(g, repro.CompileOptions(precision="fast"))(
                **{in_name: x})[out_name])
        out[name] = {
            "exact_vs_oracle": float(np.max(np.abs(want - exact))),
            "fast_vs_oracle": float(np.max(np.abs(want - fast))),
        }
    return out


def quantization_errors(calibrate: Optional[int] = None
                        ) -> Dict[str, Dict[str, float]]:
    """bf16/int8 compiled outputs vs the f32 oracle, per Table-1
    config: max_abs and max_rel (relative to the oracle's magnitude,
    floored at 1e-6 so near-zero outputs don't blow the ratio up)."""
    rng = np.random.default_rng(2)
    out = {}
    for name in SUITE:
        g = SUITE[name]()
        in_name = next(iter(g.inputs))
        out_name = g.outputs[0]
        x = rng.standard_normal((2,) + g.inputs[in_name].shape) \
            .astype(np.float32)
        oracle = repro.compile(g, repro.CompileOptions(target="interpret"))
        want = np.asarray(oracle(**{in_name: x})[out_name])
        denom = np.maximum(np.abs(want), 1e-6)
        row: Dict[str, float] = {}
        for prec in ("bf16", "int8"):
            got = np.asarray(repro.compile(g, repro.CompileOptions(
                precision=prec, calibrate=calibrate))(
                    **{in_name: x})[out_name])
            row[f"{prec}_max_abs"] = float(np.max(np.abs(want - got)))
            row[f"{prec}_max_rel"] = float(np.max(np.abs(want - got) / denom))
        out[name] = row
    return out


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibrate", type=int, default=None, metavar="N",
                    help="calibration sample batches for the "
                         "quantization section (default: pass default, 4)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write every section as a PRECISION_*.json "
                         "artifact (what the CI precision gate consumes)")
    args = ap.parse_args(argv)

    act = activation_errors()
    print("fast-activation errors (paper §3.4):")
    for fn, e in act.items():
        print(f"  {fn:<8} max_abs={e['max_abs']:.3e} "
              f"max_rel={e['max_rel']:.3e}")
    e2e = end_to_end_errors()
    print("end-to-end compiled vs SimpleNN oracle:")
    for name, e in e2e.items():
        print(f"  {name:<10} exact={e['exact_vs_oracle']:.2e} "
              f"fast={e['fast_vs_oracle']:.2e}")
    quant = quantization_errors(calibrate=args.calibrate)
    print("quantized compiled vs f32 oracle (calibration-driven):")
    for name, e in quant.items():
        print(f"  {name:<12} bf16={e['bf16_max_abs']:.2e} "
              f"(rel {e['bf16_max_rel']:.2e})  "
              f"int8={e['int8_max_abs']:.2e} "
              f"(rel {e['int8_max_rel']:.2e})")
    if args.json:
        import jax
        doc = {
            "bench": "precision",
            "activations": act,
            "end_to_end": e2e,
            "quantization": quant,
            "env": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "python": platform.python_version(),
                "machine": platform.machine(),
            },
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"[precision] wrote {args.json}")


if __name__ == "__main__":
    main()
