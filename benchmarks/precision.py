"""Precision of the fast activations (paper §3.4) and of the whole
compiled pipeline vs the SimpleNN oracle — the paper's "approximating
activation functions … impacts the precision" quantified."""

from __future__ import annotations

from typing import Dict

import numpy as np

import repro
from repro.kernels.fast_act import ref as fa

from .table1_models import SUITE


def activation_errors() -> Dict[str, Dict[str, float]]:
    x = np.linspace(-8, 8, 100_001, dtype=np.float32)
    out = {}
    for fn in ("exp", "tanh", "sigmoid"):
        approx = np.asarray(fa.FAST[fn](x))
        exact = np.asarray(fa.EXACT[fn](x))
        denom = np.maximum(np.abs(exact), 1e-6)
        out[fn] = {
            "max_abs": float(np.max(np.abs(approx - exact))),
            "max_rel": float(np.max(np.abs(approx - exact) / denom)),
        }
    # softmax over a batch of logit-ish rows
    rng = np.random.default_rng(0)
    z = rng.standard_normal((256, 64)).astype(np.float32) * 4
    a = np.asarray(fa.fast_softmax(z))
    e = np.asarray(fa.EXACT["softmax"](z, axis=-1))
    out["softmax"] = {"max_abs": float(np.max(np.abs(a - e))),
                      "max_rel": float("nan")}
    return out


def end_to_end_errors() -> Dict[str, Dict[str, float]]:
    rng = np.random.default_rng(1)
    out = {}
    for name in ("C-HTWK", "C-BH", "Segmenter"):
        g = SUITE[name]()
        in_name = next(iter(g.inputs))
        x = rng.standard_normal((2,) + g.inputs[in_name].shape) \
            .astype(np.float32)
        out_name = g.outputs[0]
        oracle = repro.compile(g, repro.CompileOptions(target="interpret"))
        want = np.asarray(oracle(**{in_name: x})[out_name])
        exact = np.asarray(
            repro.compile(g, repro.CompileOptions())(**{in_name: x})[out_name])
        fast = np.asarray(
            repro.compile(g, repro.CompileOptions(precision="fast"))(
                **{in_name: x})[out_name])
        out[name] = {
            "exact_vs_oracle": float(np.max(np.abs(want - exact))),
            "fast_vs_oracle": float(np.max(np.abs(want - fast))),
        }
    return out


def main() -> None:
    print("fast-activation errors (paper §3.4):")
    for fn, e in activation_errors().items():
        print(f"  {fn:<8} max_abs={e['max_abs']:.3e} "
              f"max_rel={e['max_rel']:.3e}")
    print("end-to-end compiled vs SimpleNN oracle:")
    for name, e in end_to_end_errors().items():
        print(f"  {name:<10} exact={e['exact_vs_oracle']:.2e} "
              f"fast={e['fast_vs_oracle']:.2e}")


if __name__ == "__main__":
    main()
