"""Benchmark aggregator: one section per paper table/figure plus the
roofline report.  ``PYTHONPATH=src python -m benchmarks.run``"""

from __future__ import annotations


def main() -> None:
    from . import ablations, precision, roofline, table1

    print("=" * 72)
    print("Table 1 — compiled vs interpreted inference + compile time")
    print("=" * 72)
    table1.main()

    print()
    print("=" * 72)
    print("§3.4 — fast-activation / end-to-end precision")
    print("=" * 72)
    precision.main()

    print()
    print("=" * 72)
    print("§3 — pass ablations")
    print("=" * 72)
    ablations.main()

    print()
    print("=" * 72)
    print("§Roofline — dry-run derived terms (see EXPERIMENTS.md)")
    print("=" * 72)
    roofline.main()


if __name__ == "__main__":
    main()
