"""Paper Table 1: compiled vs interpreted inference time + compile time.

The paper's interpreters (frugally-deep, RoboDNN, TF-Lite, tiny-dnn)
walk the network structure on every call; our interpreted baseline is
the ``"interpret"`` target stepped op-by-op from Python (each jnp op
dispatched eagerly), and the compiled row is the ``"jit"`` target — one
specialized XLA program with every pass applied.  Both rows go through
``repro.compile``; the last reproduces the paper's "Compilation Time".
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, Optional, Sequence

import numpy as np

import jax

import repro

from .table1_models import SUITE


def _time_call(fn, *args, reps=20, warmup=3) -> float:
    """Min of per-rep wall times: robust to the scheduler hiccups and
    GC pauses that dominate sub-millisecond means on shared CI runners
    (the perf gate depends on this estimator being stable)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def _active_decisions(exe) -> Optional[Dict]:
    """Compact record of the graph-level decisions a tuned executable is
    running with: per-site winner + source, and the chosen pipeline —
    the attribution line for trajectory artifacts."""
    rep = exe.cost_summary().get("graph_decisions")
    if rep is None:
        return None
    return {
        "pipeline": rep.get("pipeline"),
        "sites": [
            {"kind": r["kind"], "node": r["node"],
             "winner": r.get("winner"), "source": r.get("source")}
            for r in rep.get("sites", [])
        ],
        "spent_ms": rep.get("spent_ms"),
    }


def run(reps: int = 20,
        configs: Optional[Sequence[str]] = None,
        autotune: bool = False,
        autotune_budget_ms: float = 250.0,
        precision: Optional[str] = None,
        calibrate: Optional[int] = None,
        ) -> Dict[str, Dict[str, float]]:
    if configs:
        unknown = sorted(set(configs) - set(SUITE))
        if unknown:
            raise SystemExit(f"unknown configs {unknown}; "
                             f"choose from {sorted(SUITE)}")
        suite = {n: SUITE[n] for n in configs}
    else:
        suite = SUITE
    rng = np.random.default_rng(0)
    rows: Dict[str, Dict[str, float]] = {}
    for name, build in suite.items():
        g = build()
        in_name = next(iter(g.inputs))
        out_name = g.outputs[0]
        shape = (1,) + g.inputs[in_name].shape
        x = rng.standard_normal(shape).astype(np.float32)

        oracle = repro.compile(g, repro.CompileOptions(target="interpret"))
        t_simple = _time_call(
            lambda x=x: oracle(**{in_name: x})[out_name],
            reps=max(3, reps // 4))

        exe = repro.compile(g, repro.CompileOptions(target="jit"))
        # Time the raw specialized program (as the paper does), not the
        # Executable's per-call Python veneer — on sub-ms models the
        # dict plumbing would dominate the measurement.
        fn = exe.ensure_compiled(batch_size=1)
        t_compiled = _time_call(lambda x=x: fn(x), reps=reps)

        # numerics vs oracle (the paper's SimpleNN role)
        want = np.asarray(oracle(**{in_name: x})[out_name])
        got = np.asarray(exe(**{in_name: x})[out_name])
        err = float(np.max(np.abs(want - got)))

        rows[name] = {
            "interpreted_ms": t_simple * 1e3,
            "compiled_ms": t_compiled * 1e3,
            "speedup": t_simple / t_compiled,
            "compile_time_ms": (exe.compile_time or 0) * 1e3,
            "max_abs_err": err,
        }

        if precision:
            # Low-precision row pair: the f32 pallas path vs the same
            # target compiled at --precision, same estimator and reps —
            # the precision gate consumes this speedup ratio, and the
            # error column is measured against the f32 oracle output.
            pal = repro.compile(g, repro.CompileOptions(target="pallas"))
            fn_p = pal.ensure_compiled(batch_size=1)
            t_pal = _time_call(lambda x=x: fn_p(x), reps=reps)

            q = repro.compile(g, repro.CompileOptions(
                target="pallas", precision=precision, calibrate=calibrate))
            fn_q = q.ensure_compiled(batch_size=1)
            t_q = _time_call(lambda x=x: fn_q(x), reps=reps)

            q_out = np.asarray(q(**{in_name: x})[out_name])
            q_err = float(np.max(np.abs(want - q_out)))
            rows[name].update({
                "precision": precision,
                "f32_pallas_ms": t_pal * 1e3,
                "quant_ms": t_q * 1e3,
                "quant_speedup": t_pal / t_q,
                "quant_max_abs_err": q_err,
                "quant_decisions": q.cost_summary().get("quant"),
            })

        if autotune:
            # Both pallas modes side by side: the heuristic selector's
            # program vs. the profile-guided (autotune="full") one.
            # Same reps for both rows — the min-of-reps estimator only
            # drops with more samples, so unequal reps would bias the
            # comparison toward whichever row got more.
            heur = repro.compile(g, repro.CompileOptions(target="pallas"))
            fn_h = heur.ensure_compiled(batch_size=1)
            t_heur = _time_call(lambda x=x: fn_h(x), reps=reps)

            tuned = repro.compile(g, repro.CompileOptions(
                target="pallas", autotune="full",
                autotune_budget_ms=autotune_budget_ms))
            fn_t = tuned.ensure_compiled(batch_size=1)
            t_tuned = _time_call(lambda x=x: fn_t(x), reps=reps)

            tuned_out = np.asarray(tuned(**{in_name: x})[out_name])
            tuned_err = float(np.max(np.abs(want - tuned_out)))
            rows[name].update({
                "pallas_heuristic_ms": t_heur * 1e3,
                "pallas_autotuned_ms": t_tuned * 1e3,
                "autotune_speedup": t_simple / t_tuned,
                "autotune_max_abs_err": tuned_err,
                # Which graph-level decisions the tuned compile actually
                # ran with — without this a tuned-fusion run is
                # indistinguishable from heuristic in the artifact.
                "graph_decisions": _active_decisions(tuned),
                # the gate's numeric ceiling covers whichever path the
                # run actually exercised
                "max_abs_err": max(err, tuned_err),
            })
    return rows


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", nargs="*", metavar="NAME",
                    help=f"subset of {sorted(SUITE)} (default: all)")
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--autotune", action="store_true",
                    help="also run the pallas target in both modes — "
                         "heuristic selector vs autotune='full' — so the "
                         "table reads side by side")
    ap.add_argument("--autotune-budget-ms", type=float, default=250.0,
                    help="per-compile measurement budget for --autotune "
                         "(default 250); set $REPRO_CACHE_DIR to persist "
                         "tactics across runs")
    ap.add_argument("--precision", choices=("bf16", "int8", "mixed"),
                    help="also compile the pallas target at this "
                         "precision and report it against the f32 pallas "
                         "path (speedup + max_abs_err vs the f32 oracle)")
    ap.add_argument("--calibrate", type=int, default=None, metavar="N",
                    help="calibration sample batches for --precision "
                         "(default: the quantize pass's default, 4)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows + environment as a BENCH_*.json "
                         "artifact (the CI perf-trajectory format)")
    args = ap.parse_args(argv)

    rows = run(reps=args.reps, configs=args.configs,
               autotune=args.autotune,
               autotune_budget_ms=args.autotune_budget_ms,
               precision=args.precision, calibrate=args.calibrate)
    hdr = f"{'model':<12} {'interp ms':>10} {'compiled ms':>12} " \
          f"{'speedup':>8} {'compile ms':>11} {'max err':>9}"
    if args.autotune:
        hdr += f" {'pallas ms':>10} {'tuned ms':>9} {'tuned x':>8}"
    if args.precision:
        hdr += f" {'f32 ms':>8} {args.precision + ' ms':>9} " \
               f"{'q-x':>6} {'q-err':>9}"
    print(hdr)
    print("-" * len(hdr))
    for name, r in rows.items():
        line = (f"{name:<12} {r['interpreted_ms']:>10.3f} "
                f"{r['compiled_ms']:>12.3f} {r['speedup']:>8.1f} "
                f"{r['compile_time_ms']:>11.1f} {r['max_abs_err']:>9.2e}")
        if args.autotune:
            line += (f" {r['pallas_heuristic_ms']:>10.3f} "
                     f"{r['pallas_autotuned_ms']:>9.3f} "
                     f"{r['autotune_speedup']:>8.1f}")
        if args.precision:
            line += (f" {r['f32_pallas_ms']:>8.3f} {r['quant_ms']:>9.3f} "
                     f"{r['quant_speedup']:>6.2f} "
                     f"{r['quant_max_abs_err']:>9.2e}")
        print(line)
    if args.json:
        doc = {
            "bench": "table1",
            "autotune": bool(args.autotune),
            "precision": args.precision,
            "rows": rows,
            "env": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "python": platform.python_version(),
                "machine": platform.machine(),
            },
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"[table1] wrote {args.json}")


if __name__ == "__main__":
    main()
