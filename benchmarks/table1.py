"""Paper Table 1: compiled vs interpreted inference time + compile time.

The paper's interpreters (frugally-deep, RoboDNN, TF-Lite, tiny-dnn)
walk the network structure on every call; our interpreted baseline is
the ``"interpret"`` target stepped op-by-op from Python (each jnp op
dispatched eagerly), and the compiled row is the ``"jit"`` target — one
specialized XLA program with every pass applied.  Both rows go through
``repro.compile``; the last reproduces the paper's "Compilation Time".
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from typing import Dict, Optional, Sequence

import numpy as np

import jax

import repro

from .table1_models import SUITE


def _time_call(fn, *args, reps=20, warmup=3) -> float:
    """Min of per-rep wall times: robust to the scheduler hiccups and
    GC pauses that dominate sub-millisecond means on shared CI runners
    (the perf gate depends on this estimator being stable)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.min(times))


def run(reps: int = 20,
        configs: Optional[Sequence[str]] = None
        ) -> Dict[str, Dict[str, float]]:
    if configs:
        unknown = sorted(set(configs) - set(SUITE))
        if unknown:
            raise SystemExit(f"unknown configs {unknown}; "
                             f"choose from {sorted(SUITE)}")
        suite = {n: SUITE[n] for n in configs}
    else:
        suite = SUITE
    rng = np.random.default_rng(0)
    rows: Dict[str, Dict[str, float]] = {}
    for name, build in suite.items():
        g = build()
        in_name = next(iter(g.inputs))
        out_name = g.outputs[0]
        shape = (1,) + g.inputs[in_name].shape
        x = rng.standard_normal(shape).astype(np.float32)

        oracle = repro.compile(g, repro.CompileOptions(target="interpret"))
        t_simple = _time_call(
            lambda x=x: oracle(**{in_name: x})[out_name],
            reps=max(3, reps // 4))

        exe = repro.compile(g, repro.CompileOptions(target="jit"))
        # Time the raw specialized program (as the paper does), not the
        # Executable's per-call Python veneer — on sub-ms models the
        # dict plumbing would dominate the measurement.
        fn = exe.ensure_compiled(batch_size=1)
        t_compiled = _time_call(lambda x=x: fn(x), reps=reps)

        # numerics vs oracle (the paper's SimpleNN role)
        want = np.asarray(oracle(**{in_name: x})[out_name])
        got = np.asarray(exe(**{in_name: x})[out_name])
        err = float(np.max(np.abs(want - got)))

        rows[name] = {
            "interpreted_ms": t_simple * 1e3,
            "compiled_ms": t_compiled * 1e3,
            "speedup": t_simple / t_compiled,
            "compile_time_ms": (exe.compile_time or 0) * 1e3,
            "max_abs_err": err,
        }
    return rows


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", nargs="*", metavar="NAME",
                    help=f"subset of {sorted(SUITE)} (default: all)")
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows + environment as a BENCH_*.json "
                         "artifact (the CI perf-trajectory format)")
    args = ap.parse_args(argv)

    rows = run(reps=args.reps, configs=args.configs)
    hdr = f"{'model':<12} {'interp ms':>10} {'compiled ms':>12} " \
          f"{'speedup':>8} {'compile ms':>11} {'max err':>9}"
    print(hdr)
    print("-" * len(hdr))
    for name, r in rows.items():
        print(f"{name:<12} {r['interpreted_ms']:>10.3f} "
              f"{r['compiled_ms']:>12.3f} {r['speedup']:>8.1f} "
              f"{r['compile_time_ms']:>11.1f} {r['max_abs_err']:>9.2e}")
    if args.json:
        doc = {
            "bench": "table1",
            "rows": rows,
            "env": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "python": platform.python_version(),
                "machine": platform.machine(),
            },
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"[table1] wrote {args.json}")


if __name__ == "__main__":
    main()
