"""Sharded-compilation smoke benchmark (CI: bench-smoke, shard-smoke)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.shard_bench --smoke \\
        --out SHARD.json

Two sections, both hard gates (exit 1 on violation):

* **compile** — a Table-1 config plus a dense MLP block compiled
  unsharded and with ``CompileOptions(mesh=...)``; outputs must be
  **bit-identical** (sharding is placement, never math), and the report
  records the propagated placement + per-axis collective estimates from
  ``cost_summary()["sharding"]``.
* **serve** — the engine smoke config served once on a single device
  and once on a ``data×model`` mesh over the same request trace; greedy
  tokens must match uid for uid, ``summary()["faults"]`` must be empty,
  and the report carries the per-axis collective counts / bytes parsed
  from the decode program's post-optimization HLO.

The mesh shrinks to whatever the visible device set supports (CI forces
8 virtual host devices via ``XLA_FLAGS``), so the bench also runs — as
a pure 1-device identity check — on a bare machine.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from .perf_gate import append_trajectory


def _pick_mesh(want: str):
    """The requested mesh if the device set can fill it, else the
    1×1 fallback (still a valid identity check)."""
    import jax
    import repro

    spec = repro.MeshSpec.parse(want)
    if spec.size <= len(jax.devices()):
        return spec
    return repro.MeshSpec.parse("data=1,model=1")


def _mlp_graph():
    from repro.core import ModelBuilder

    mb = ModelBuilder().seed(11)
    x = mb.input((64,))
    h = mb.dense(x, 128, activation="relu")
    h = mb.dense(h, 64)
    return mb.build([h])


def bench_compile(mesh_spec, batch: int) -> dict:
    """Unsharded vs sharded compile of the MLP block + one Table-1
    config.  A 1-device mesh must be **bit**-identical (the acceptance
    bar); the real mesh must stay allclose — a row-parallel psum may
    legally reassociate the contraction's float reduction across
    devices, but placement never changes the math beyond that."""
    import repro
    from repro.api.capture import seeded_inputs
    from .table1_models import SUITE

    one = repro.MeshSpec.parse("data=1,model=1")
    out = {}
    for name, graph in (("mlp-block", _mlp_graph()),
                        ("C-BH", SUITE["C-BH"]())):
        inputs = seeded_inputs(graph, batch)
        base = repro.compile(graph, repro.CompileOptions())(**inputs)
        single = repro.compile(graph,
                               repro.CompileOptions(mesh=one))(**inputs)
        identical = all(
            np.array_equal(np.asarray(base[k]), np.asarray(single[k]))
            for k in base)
        t0 = time.perf_counter()
        exe = repro.compile(graph, repro.CompileOptions(mesh=mesh_spec))
        sharded = exe(**inputs)
        wall = time.perf_counter() - t0
        close = all(
            np.allclose(np.asarray(base[k]), np.asarray(sharded[k]),
                        rtol=1e-5, atol=1e-6)
            for k in base)
        max_diff = max(
            float(np.max(np.abs(np.asarray(base[k], dtype=np.float64)
                                - np.asarray(sharded[k], dtype=np.float64))))
            for k in base)
        sh = exe.cost_summary()["sharding"]
        out[name] = {
            "bit_identical_1dev": identical,
            "allclose_mesh": close,
            "max_abs_diff_mesh": max_diff,
            "compile_and_run_s": round(wall, 3),
            "tensors": sh["tensors"],
            "collectives": sh["collectives"],
        }
    return out


def bench_serve(mesh_spec, args) -> dict:
    """Single-device vs meshed scheduler over one trace: token identity,
    faults, throughput, and the HLO-derived per-axis collectives."""
    import repro
    from repro.configs import get_config
    from repro.serve import Request

    cfg = get_config(args.arch, smoke=True)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            int(rng.integers(3, args.max_len // 4)))
               for _ in range(args.requests)]

    def run(mesh):
        exe = repro.compile(cfg, repro.CompileOptions(target="engine",
                                                      mesh=mesh))
        sched = repro.serve(exe, repro.SchedulerOptions(
            slots=args.slots, max_len=args.max_len))
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            sched.submit(Request(uid=i, prompt=p,
                                 max_new_tokens=args.max_new))
        done = sched.run()
        wall = time.perf_counter() - t0
        summary = sched.summary()
        sched.shutdown()
        return {c.uid: list(c.tokens) for c in done}, summary, wall

    ref, _, wall_1dev = run(None)
    got, summary, wall_mesh = run(mesh_spec)

    # Bucketed meshed wave: warm up, then the steady wave must serve
    # with ZERO request-path compile stalls (the engine-cache contract
    # holds under a mesh too) and the oracle token stream.
    exe = repro.compile(cfg, repro.CompileOptions(target="engine",
                                                  mesh=mesh_spec))
    policy = repro.BucketPolicy.default(max_batch=args.slots,
                                        max_len=args.max_len)
    sched = repro.serve(exe, repro.SchedulerOptions(
        slots=args.slots, max_len=args.max_len, buckets=policy))
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=100_000 + i, prompt=p,
                             max_new_tokens=args.max_new))
    sched.run()
    sched.wait_warm()
    stalls0 = sched.summary()["runtime"]["compile_stalls"]
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new_tokens=args.max_new))
    # run() may re-report warm-wave completions; keep the steady uids
    steady = {c.uid: list(c.tokens) for c in sched.run()
              if c.uid < 100_000}
    bsummary = sched.summary()
    sched.shutdown()
    steady_stalls = bsummary["runtime"]["compile_stalls"] - stalls0

    return {
        "mesh": mesh_spec.describe(),
        "devices": mesh_spec.size,
        "tokens_identical": got == ref,
        "mismatched_uids": sorted(u for u in ref if got.get(u) != ref[u]),
        "bucketed_tokens_identical": steady == ref,
        "steady_state_stalls": steady_stalls,
        "faults": summary.get("faults", []) + bsummary.get("faults", []),
        "sharding": summary.get("sharding"),
        "wall_s_single": round(wall_1dev, 3),
        "wall_s_mesh": round(wall_mesh, 3),
        "tok_s_mesh": summary.get("tokens_per_s"),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (implied by the defaults; kept "
                         "for symmetry with the other benches)")
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--mesh", default="data=2,model=2",
                    help="requested serve mesh; shrinks to 1x1 when the "
                         "device set is too small")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2,
                    help="batch size for the compile-section identity run")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="do not append this run to the perf trajectory")
    args = ap.parse_args(argv)

    import jax

    mesh_spec = _pick_mesh(args.mesh)
    print(f"[shard_bench] {len(jax.devices())} device(s) visible; "
          f"mesh {mesh_spec.describe()}", flush=True)

    report = {
        "bench": "shard_smoke",
        "requested_mesh": args.mesh,
        "mesh": mesh_spec.describe(),
        "devices_visible": len(jax.devices()),
        "compile": bench_compile(mesh_spec, args.batch),
        "serve": bench_serve(mesh_spec, args),
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    if not args.no_trajectory:
        append_trajectory({"bench": "shard_smoke",
                           "mesh": report["mesh"],
                           "serve": {k: report["serve"][k]
                                     for k in ("tokens_identical",
                                               "wall_s_mesh",
                                               "tok_s_mesh")}})

    for name, row in report["compile"].items():
        print(f"[shard_bench] compile {name:<9} 1dev-bit-identical="
              f"{row['bit_identical_1dev']} mesh-allclose="
              f"{row['allclose_mesh']} "
              f"(max diff {row['max_abs_diff_mesh']:.2e}) collectives="
              f"{row['collectives']['counts'] or '{}'}", flush=True)
    srv = report["serve"]
    per = (srv["sharding"] or {}).get("collectives", {}).get("per_axis", {})
    per_str = {a: f"{v['count']}x/{v['bytes'] / 1e3:.1f}KB"
               for a, v in per.items()}
    print(f"[shard_bench] serve mesh {srv['mesh']}: tokens_identical="
          f"{srv['tokens_identical']} bucketed="
          f"{srv['bucketed_tokens_identical']} "
          f"steady_stalls={srv['steady_state_stalls']} "
          f"faults={len(srv['faults'])} "
          f"single {srv['wall_s_single']}s vs mesh {srv['wall_s_mesh']}s "
          f"per-axis {per_str or 'none'}", flush=True)

    failures = []
    for name, row in report["compile"].items():
        if not row["bit_identical_1dev"]:
            failures.append(f"compile {name}: 1-device mesh is not "
                            f"bit-identical to unsharded")
        if not row["allclose_mesh"]:
            failures.append(f"compile {name}: meshed output diverges "
                            f"beyond float reassociation "
                            f"(max {row['max_abs_diff_mesh']:.2e})")
    if not srv["tokens_identical"]:
        failures.append(f"serve: meshed tokens diverge for uids "
                        f"{srv['mismatched_uids']}")
    if not srv["bucketed_tokens_identical"]:
        failures.append("serve: bucketed meshed tokens diverge from the "
                        "single-device oracle")
    if srv["steady_state_stalls"]:
        failures.append(f"serve: {srv['steady_state_stalls']} compile "
                        f"stall(s) on the request path in steady state")
    if srv["faults"]:
        failures.append(f"serve: unexpected mesh faults {srv['faults']}")
    for msg in failures:
        print(f"[shard_bench] FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
