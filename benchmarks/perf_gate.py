"""CI perf-trajectory gate: compare a fresh BENCH_*.json against the
checked-in baseline and fail on regression.

    PYTHONPATH=src python -m benchmarks.perf_gate BENCH_pr.json \
        benchmarks/artifacts/baseline.json --max-regression 0.25

The gated metric is the compiled-vs-interpreted **speedup ratio**, not
absolute milliseconds: both rows of the ratio run on the same machine
in the same process, so it transfers between the laptop that seeded the
baseline and whatever CI runner executes the gate, while a regression
in the compiled path (a pass stops firing, a lowering falls off the
jit path) still shows up directly.  Numerical correctness is gated too:
``max_abs_err`` must stay within the oracle tolerance.
"""

from __future__ import annotations

import argparse
import json
import sys

ERR_CEILING = 1e-4     # same oracle tolerance the smoke script enforces


def gate(current: dict, baseline: dict, max_regression: float) -> list:
    failures = []
    for name, base in baseline["rows"].items():
        cur = current["rows"].get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        floor = base["speedup"] * (1.0 - max_regression)
        verdict = "OK" if cur["speedup"] >= floor else "REGRESSION"
        print(f"[gate] {name:<12} speedup {cur['speedup']:7.1f}x "
              f"(baseline {base['speedup']:7.1f}x, floor {floor:7.1f}x) "
              f"err {cur['max_abs_err']:.2e}  {verdict}")
        if cur["speedup"] < floor:
            failures.append(
                f"{name}: speedup {cur['speedup']:.1f}x fell more than "
                f"{max_regression:.0%} below baseline {base['speedup']:.1f}x")
        if cur["max_abs_err"] > ERR_CEILING:
            failures.append(
                f"{name}: max_abs_err {cur['max_abs_err']:.2e} exceeds "
                f"the {ERR_CEILING:.0e} oracle ceiling")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_*.json from this run")
    ap.add_argument("baseline", help="checked-in baseline.json")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional speedup drop (default 0.25)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = gate(current, baseline, args.max_regression)
    if failures:
        for msg in failures:
            print(f"[gate] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[gate] OK — perf trajectory holds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
