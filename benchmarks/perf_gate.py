"""CI perf-trajectory gate + baseline reseeding.

Gate mode — compare a fresh BENCH_*.json against the checked-in
baseline and fail on regression::

    PYTHONPATH=src python -m benchmarks.perf_gate BENCH_pr.json \
        benchmarks/artifacts/baseline.json --max-regression 0.25

The gated metric is the compiled-vs-interpreted **speedup ratio**, not
absolute milliseconds: both rows of the ratio run on the same machine
in the same process, so it transfers between the laptop that seeded the
baseline and whatever CI runner executes the gate, while a regression
in the compiled path (a pass stops firing, a lowering falls off the
jit path) still shows up directly.  Numerical correctness is gated too:
``max_abs_err`` must stay within the oracle tolerance.
``--speedup-key autotune_speedup`` gates the autotuned pallas path of a
``table1 --autotune`` run against the same baseline floor — the tuned
path must not lose to the heuristic jit floor.

Reseed mode — regenerate the baseline as the documented min-over-N
procedure (no more by-hand ritual)::

    PYTHONPATH=src python -m benchmarks.perf_gate --reseed 10 \
        --configs C-HTWK C-BH --reps 50

Each run's rows are kept, the per-config **minimum** speedup across the
N runs becomes the new baseline floor (the same estimator-of-estimators
the original baseline documented), and the result overwrites
``benchmarks/artifacts/baseline.json``.

Both modes append a summary of every run to the perf trajectory at
``benchmarks/artifacts/trajectory/`` (one ``BENCH_*.json`` per run), so
the history CI uploads as artifacts also accumulates wherever the gate
actually executes.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

ERR_CEILING = 1e-4     # same oracle tolerance the smoke script enforces

TRAJECTORY_DIR = os.path.join(os.path.dirname(__file__), "artifacts",
                              "trajectory")


def gate(current: dict, baseline: dict, max_regression: float,
         speedup_key: str = "speedup") -> list:
    """Failures list; ``speedup_key`` selects which speedup column of
    the *current* rows to gate (the baseline floor is always its
    ``speedup``)."""
    failures = []
    for name, base in baseline["rows"].items():
        cur = current["rows"].get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        if speedup_key not in cur:
            failures.append(f"{name}: no {speedup_key!r} in current run "
                            "(was table1 run with the matching flags?)")
            continue
        floor = base["speedup"] * (1.0 - max_regression)
        verdict = "OK" if cur[speedup_key] >= floor else "REGRESSION"
        print(f"[gate] {name:<12} {speedup_key} {cur[speedup_key]:7.1f}x "
              f"(baseline {base['speedup']:7.1f}x, floor {floor:7.1f}x) "
              f"err {cur['max_abs_err']:.2e}  {verdict}")
        if cur[speedup_key] < floor:
            failures.append(
                f"{name}: {speedup_key} {cur[speedup_key]:.1f}x fell more "
                f"than {max_regression:.0%} below baseline "
                f"{base['speedup']:.1f}x")
        if cur["max_abs_err"] > ERR_CEILING:
            failures.append(
                f"{name}: max_abs_err {cur['max_abs_err']:.2e} exceeds "
                f"the {ERR_CEILING:.0e} oracle ceiling")
    return failures


def append_trajectory(doc: dict, trajectory_dir=TRAJECTORY_DIR) -> str:
    """Append one run summary to the perf trajectory (best-effort: the
    trajectory must never fail a build on its own).  ``None`` disables."""
    if not trajectory_dir:
        return ""
    try:
        os.makedirs(trajectory_dir, exist_ok=True)
        stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
            "%Y%m%dT%H%M%S%f")
        path = os.path.join(trajectory_dir, f"BENCH_{stamp}-{os.getpid()}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"[trajectory] appended {path}")
        return path
    except OSError as e:  # pragma: no cover - fs trouble only
        print(f"[trajectory] skipped ({e})", file=sys.stderr)
        return ""


def reseed(n: int, reps: int, configs, out_path: str,
           trajectory_dir=TRAJECTORY_DIR) -> dict:
    """Min-over-N baseline: run table1 N times, floor each config at its
    minimum speedup, write the result to ``out_path``."""
    import jax
    import platform

    from .table1 import run as run_table1

    all_rows = []
    for i in range(n):
        rows = run_table1(reps=reps, configs=configs)
        all_rows.append(rows)
        line = ", ".join(f"{name}: {r['speedup']:.1f}x"
                         for name, r in rows.items())
        print(f"[reseed] run {i + 1}/{n}: {line}")
        append_trajectory({"bench": "table1", "mode": "reseed",
                           "run": i + 1, "of": n, "rows": rows},
                          trajectory_dir)

    baseline_rows = {}
    for name in all_rows[0]:
        runs = [rows[name] for rows in all_rows]
        floor = min(runs, key=lambda r: r["speedup"])
        baseline_rows[name] = {**floor, "speedup": round(floor["speedup"], 1)}
    doc = {
        "bench": "table1",
        "rows": baseline_rows,
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "machine": platform.machine(),
        },
        "note": (f"seeded by `python -m benchmarks.perf_gate --reseed {n}` "
                 f"as the per-config MINIMUM speedup over {n} runs "
                 f"(reps={reps}, min-of-reps estimator); the perf gate "
                 "allows a further fractional drop, so only a structural "
                 "regression — a pass not firing, an op falling off the "
                 "jit path — trips it"),
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"[reseed] wrote {out_path}")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?",
                    help="fresh BENCH_*.json from this run (gate mode)")
    ap.add_argument("baseline", nargs="?",
                    default="benchmarks/artifacts/baseline.json",
                    help="checked-in baseline.json")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional speedup drop (default 0.25)")
    ap.add_argument("--speedup-key", default="speedup",
                    help="speedup column of the current rows to gate "
                         "(e.g. autotune_speedup for table1 --autotune runs)")
    ap.add_argument("--reseed", type=int, metavar="N",
                    help="regenerate the baseline as min-over-N table1 runs "
                         "instead of gating")
    ap.add_argument("--configs", nargs="*", metavar="NAME",
                    help="configs for --reseed (default: the CI bench-smoke "
                         "pair, C-HTWK C-BH — the baseline must cover "
                         "exactly the rows CI produces, or the gate fails "
                         "every build with 'missing from current run')")
    ap.add_argument("--reps", type=int, default=50,
                    help="table1 reps per --reseed run (default 50)")
    ap.add_argument("--out", default="benchmarks/artifacts/baseline.json",
                    help="where --reseed writes the new baseline "
                         "(default: benchmarks/artifacts/baseline.json)")
    ap.add_argument("--trajectory-dir", default=TRAJECTORY_DIR,
                    help="perf-trajectory directory (default "
                         "benchmarks/artifacts/trajectory)")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="do not append this run to the trajectory")
    args = ap.parse_args(argv)

    if args.reseed is not None:
        if args.reseed < 1:
            ap.error("--reseed must be >= 1")
        # Default to the configs CI actually gates: baseline rows CI
        # never reproduces would fail every subsequent build.
        configs = args.configs if args.configs else ["C-HTWK", "C-BH"]
        reseed(args.reseed, args.reps, configs, args.out,
               None if args.no_trajectory else args.trajectory_dir)
        return 0

    if not args.current:
        ap.error("gate mode needs a current BENCH_*.json (or use --reseed N)")
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = gate(current, baseline, args.max_regression, args.speedup_key)
    if not args.no_trajectory:
        append_trajectory({
            **current,
            "gate": {
                "baseline": args.baseline,
                "speedup_key": args.speedup_key,
                "max_regression": args.max_regression,
                "verdict": "fail" if failures else "ok",
                "failures": failures,
            },
        }, args.trajectory_dir)
    if failures:
        for msg in failures:
            print(f"[gate] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[gate] OK — perf trajectory holds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
