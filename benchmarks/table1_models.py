"""The paper's Table-1 network suite, re-created in the graph IR.

Same families/topologies as the paper's six benchmarks; spatial sizes
and widths are reduced where noted so the *interpreted* baseline stays
CPU-tractable (the paper ran 2019-era C++ on a NAO; our oracle is a
Python-stepped interpreter).  Reductions are applied uniformly to both
the compiled and interpreted runs, so the compiled/interpreted ratio —
the paper's claim — is preserved.
"""

from __future__ import annotations

from repro.core import Graph, ModelBuilder


def htwk_classifier() -> Graph:
    """Nao-Team HTWK's small patch classifier (C-HTWK)."""
    mb = ModelBuilder().seed(1)
    x = mb.input((16, 16, 1))
    h = mb.conv2d(x, 4, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 8, (3, 3), strides=(2, 2), activation="relu")
    h = mb.flatten(h)
    h = mb.dense(h, 16, activation="relu")
    h = mb.dense(h, 4)
    h = mb.softmax(h)
    return mb.build([h])


def bhuman_ball() -> Graph:
    """B-Human's ball candidate classifier (C-BH)."""
    mb = ModelBuilder().seed(2)
    x = mb.input((32, 32, 1))
    h = mb.conv2d(x, 8, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 16, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 16, (3, 3), activation="relu")
    h = mb.maxpool(h)
    h = mb.flatten(h)
    h = mb.dense(h, 32, activation="relu")
    h = mb.dense(h, 2)
    h = mb.softmax(h)
    return mb.build([h])


def jetnet_detector() -> Graph:
    """JET-Net-style full-image robot detector (grid of box predictions).
    Input reduced 160×120 -> 80×60."""
    mb = ModelBuilder().seed(3)
    x = mb.input((60, 80, 1))
    h = mb.conv2d(x, 8, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 16, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 24, (3, 3), strides=(2, 2), activation="relu")
    h = mb.conv2d(h, 24, (3, 3), activation="relu")
    h = mb.conv2d(h, 10, (1, 1))          # per-cell box + confidence
    return mb.build([h])


def field_segmenter() -> Graph:
    """80×80 field/non-field semantic segmentation (enc-dec with
    upsampling), as in the paper."""
    mb = ModelBuilder().seed(4)
    x = mb.input((80, 80, 1))
    h = mb.conv2d(x, 8, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 16, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 16, (3, 3), activation="relu")
    h = mb.upsample(h, 2)
    h = mb.conv2d(h, 8, (3, 3), activation="relu")
    h = mb.upsample(h, 2)
    h = mb.conv2d(h, 2, (3, 3))
    h = mb.softmax(h)
    return mb.build([h])


def _inverted_residual(mb, x, cin, cout, stride, expand):
    h = mb.conv2d(x, cin * expand, (1, 1), use_bias=False)
    h = mb.batchnorm(h)
    h = mb.activation(h, "relu6")
    h = mb.depthwise_conv2d(h, (3, 3), strides=(stride, stride),
                            use_bias=False)
    h = mb.batchnorm(h)
    h = mb.activation(h, "relu6")
    h = mb.conv2d(h, cout, (1, 1), use_bias=False)
    h = mb.batchnorm(h)
    if stride == 1 and cin == cout:
        h = mb.add(h, x)
    return h


def mobilenet_v2() -> Graph:
    """MobileNetV2 topology (inverted residuals, relu6, BN everywhere);
    96×96 input and α≈0.25 widths for oracle tractability."""
    mb = ModelBuilder().seed(5)
    x = mb.input((96, 96, 3))
    h = mb.conv2d(x, 8, (3, 3), strides=(2, 2), use_bias=False)
    h = mb.batchnorm(h)
    h = mb.activation(h, "relu6")
    h = _inverted_residual(mb, h, 8, 8, 1, 1)
    h = _inverted_residual(mb, h, 8, 12, 2, 6)
    h = _inverted_residual(mb, h, 12, 12, 1, 6)
    h = _inverted_residual(mb, h, 12, 16, 2, 6)
    h = _inverted_residual(mb, h, 16, 16, 1, 6)
    h = _inverted_residual(mb, h, 16, 24, 2, 6)
    h = _inverted_residual(mb, h, 24, 24, 1, 6)
    h = _inverted_residual(mb, h, 24, 32, 2, 6)
    h = mb.conv2d(h, 64, (1, 1), use_bias=False)
    h = mb.batchnorm(h)
    h = mb.activation(h, "relu6")
    h = mb.global_avg_pool(h)
    return mb.build([h])


def vgg19_style() -> Graph:
    """VGG19's conv/pool pattern at 64×64 and 1/8 widths (the paper's
    'particularly large model' regime relative to the rest)."""
    mb = ModelBuilder().seed(6)
    x = mb.input((64, 64, 3))
    h = x
    for block, (width, convs) in enumerate(
            [(8, 2), (16, 2), (32, 4), (64, 4), (64, 4)]):
        for _ in range(convs):
            h = mb.conv2d(h, width, (3, 3), activation="relu")
        h = mb.maxpool(h)
    h = mb.flatten(h)
    h = mb.dense(h, 128, activation="relu")
    h = mb.dense(h, 128, activation="relu")
    h = mb.dense(h, 10)
    h = mb.softmax(h)
    return mb.build([h])


SUITE = {
    "C-HTWK": htwk_classifier,
    "C-BH": bhuman_ball,
    "Detector": jetnet_detector,
    "Segmenter": field_segmenter,
    "MobileNetV2": mobilenet_v2,
    "VGG19": vgg19_style,
}
