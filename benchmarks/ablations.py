"""Pass ablations: each optimization pass toggled off, measuring node
count, memory-plan arena and runtime on the Table-1 suite — the paper's
§3 design claims, quantified one mechanism at a time."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax

import repro
from repro.core.passes import DEFAULT_PIPELINE

from .table1_models import SUITE

VARIANTS = {
    "full": DEFAULT_PIPELINE,
    "no_bn_fold": tuple(p for p in DEFAULT_PIPELINE
                        if p != "fold_batchnorm"),
    "no_act_fusion": tuple(p for p in DEFAULT_PIPELINE
                           if p != "fuse_activation"),
    "no_pad_merge": tuple(p for p in DEFAULT_PIPELINE if p != "fuse_pad"),
    "no_layout": tuple(p for p in DEFAULT_PIPELINE
                       if p != "optimize_layout"),
    "none": ("canonicalize",),
}


def run(models=("C-BH", "MobileNetV2"), reps: int = 15) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    for name in models:
        g = SUITE[name]()
        in_name = next(iter(g.inputs))
        x = rng.standard_normal((1,) + g.inputs[in_name].shape) \
            .astype(np.float32)
        for variant, passes in VARIANTS.items():
            exe = repro.compile(g, repro.CompileOptions(passes=passes))
            fn = exe.ensure_compiled(batch_size=1)  # time the raw program
            for _ in range(3):
                jax.block_until_ready(fn(x))
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(fn(x))
            dt = (time.perf_counter() - t0) / reps
            cost = exe.cost_summary()
            rows.append({
                "model": name,
                "variant": variant,
                "nodes": cost["nodes"],
                "arena_kb": cost["memory_plan"]["arena_bytes"] / 1024,
                "inplace": cost["memory_plan"]["inplace_count"],
                "time_ms": dt * 1e3,
            })
    return rows


def main() -> None:
    rows = run()
    hdr = f"{'model':<12} {'variant':<14} {'nodes':>6} {'arena KB':>9} " \
          f"{'inplace':>8} {'ms/call':>8}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['model']:<12} {r['variant']:<14} {r['nodes']:>6} "
              f"{r['arena_kb']:>9.1f} {r['inplace']:>8} "
              f"{r['time_ms']:>8.3f}")


if __name__ == "__main__":
    main()
