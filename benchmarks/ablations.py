"""Pass ablations: each optimization pass toggled off, measuring node
count, memory-plan arena and runtime on the Table-1 suite — the paper's
§3 design claims, quantified one mechanism at a time.

Variants are registry operations, not hand-edited tuples:
``PassManager.default().without("fold_batchnorm")`` drops every
registered instance of a pass (base-name match, so both
``fuse_activation`` runs disappear together), and the resulting resolved
pipeline is what ``repro.compile`` runs.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax

import repro
from repro.core.passes import PassManager

from .table1_models import SUITE


def variants() -> Dict[str, PassManager]:
    full = PassManager.default()
    return {
        "full": full,
        "no_bn_fold": full.without("fold_batchnorm"),
        "no_act_fusion": full.without("fuse_activation"),
        "no_pad_merge": full.without("fuse_pad"),
        "no_layout": full.without("optimize_layout"),
        "none": PassManager(("canonicalize",)),
    }


def run(models: Sequence[str] = ("C-BH", "MobileNetV2"),
        reps: int = 15) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    for name in models:
        g = SUITE[name]()
        in_name = next(iter(g.inputs))
        x = rng.standard_normal((1,) + g.inputs[in_name].shape) \
            .astype(np.float32)
        for variant, pm in variants().items():
            exe = repro.compile(g, repro.CompileOptions(passes=pm.pipeline))
            fn = exe.ensure_compiled(batch_size=1)  # time the raw program
            for _ in range(3):
                jax.block_until_ready(fn(x))
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(fn(x))
            dt = (time.perf_counter() - t0) / reps
            cost = exe.cost_summary()
            # Which graph-level decisions were actually active in this
            # variant: the pass counters (how many sites fused /
            # re-laid-out) plus any autotuned decision report — so a
            # trajectory entry is attributable to its decisions.
            stats = {}
            for p in cost["passes"]:
                for key in ("fused_activations", "transposed", "padded"):
                    if key in p:
                        stats[key] = stats.get(key, 0) + p[key]
            rows.append({
                "model": name,
                "variant": variant,
                "pipeline": list(cost["pipeline"]),
                "nodes": cost["nodes"],
                "arena_kb": cost["memory_plan"]["arena_bytes"] / 1024,
                "inplace": cost["memory_plan"]["inplace_count"],
                "pass_time_ms": sum(p["time_ms"] for p in cost["passes"]),
                "time_ms": dt * 1e3,
                "decisions": stats,
                "autotune": cost.get("graph_decisions"),
            })
    return rows


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="*", metavar="NAME",
                    default=("C-BH", "MobileNetV2"),
                    help=f"subset of {sorted(SUITE)}")
    ap.add_argument("--reps", type=int, default=15)
    ap.add_argument("--json", metavar="PATH",
                    help="also write the rows as a JSON artifact")
    args = ap.parse_args(argv)
    unknown = sorted(set(args.models) - set(SUITE))
    if unknown:
        raise SystemExit(f"unknown models {unknown}; "
                         f"choose from {sorted(SUITE)}")

    rows = run(models=args.models, reps=args.reps)
    hdr = f"{'model':<12} {'variant':<14} {'nodes':>6} {'arena KB':>9} " \
          f"{'inplace':>8} {'ms/call':>8}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['model']:<12} {r['variant']:<14} {r['nodes']:>6} "
              f"{r['arena_kb']:>9.1f} {r['inplace']:>8} "
              f"{r['time_ms']:>8.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "ablations", "rows": rows}, f,
                      indent=2, sort_keys=True)
        print(f"[ablations] wrote {args.json}")


if __name__ == "__main__":
    main()
