"""CI precision gate + baseline reseeding for the quantized path.

Gate mode — compare a fresh ``table1 --precision int8`` artifact
against the checked-in precision baseline and fail on regression::

    PYTHONPATH=src python -m benchmarks.precision_gate PRECISION_pr.json \
        benchmarks/artifacts/precision_baseline.json --max-regression 0.25

Two contracts are enforced, the speed half and the accuracy half:

* ``quant_speedup`` — the int8-vs-f32 **pallas ratio** from the same
  process (both rows share the machine, so the ratio transfers between
  the box that seeded the baseline and the CI runner).  A drop of more
  than ``--max-regression`` below the baseline floor fails: that means
  the int8 lowering fell off the specialized kernel, the quantize pass
  stopped annotating, or the dequant epilogue stopped fusing.
* ``quant_max_abs_err`` — the int8 output vs the f32 oracle must stay
  within the default precision budget (``--err-budget``, 0.05 — the
  same ``DEFAULT_PRECISION_BUDGET`` the mixed-mode tuner enforces).
  Calibration drift or a broken scale round trip shows up here.

Reseed mode — regenerate the baseline as min-over-N, the same
estimator-of-estimators discipline as ``perf_gate --reseed``::

    PYTHONPATH=src python -m benchmarks.precision_gate --reseed 10 \
        --configs C-HTWK C-BH --reps 50

Every run (gate or reseed) appends to the shared perf trajectory at
``benchmarks/artifacts/trajectory/`` via :func:`perf_gate.append_trajectory`.
"""

from __future__ import annotations

import argparse
import json
import sys

from .perf_gate import TRAJECTORY_DIR, append_trajectory

# Same ceiling the quantize pass's mixed-mode tuner enforces per site
# (DEFAULT_PRECISION_BUDGET): int8 end-to-end error must stay inside it.
ERR_BUDGET = 0.05


def gate(current: dict, baseline: dict, max_regression: float,
         err_budget: float = ERR_BUDGET) -> list:
    """Failures list.  The gated speed metric is ``quant_speedup`` (the
    int8/f32 pallas ratio of the *current* rows vs the baseline floor);
    the gated accuracy metric is ``quant_max_abs_err`` vs the budget."""
    failures = []
    for name, base in baseline["rows"].items():
        cur = current["rows"].get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        if "quant_speedup" not in cur:
            failures.append(f"{name}: no 'quant_speedup' in current run "
                            "(was table1 run with --precision int8?)")
            continue
        floor = base["quant_speedup"] * (1.0 - max_regression)
        ok_speed = cur["quant_speedup"] >= floor
        ok_err = cur["quant_max_abs_err"] <= err_budget
        verdict = "OK" if (ok_speed and ok_err) else "REGRESSION"
        print(f"[precision-gate] {name:<12} int8/f32 "
              f"{cur['quant_speedup']:5.2f}x "
              f"(baseline {base['quant_speedup']:5.2f}x, "
              f"floor {floor:5.2f}x) "
              f"err {cur['quant_max_abs_err']:.2e} "
              f"(budget {err_budget:.0e})  {verdict}")
        if not ok_speed:
            failures.append(
                f"{name}: int8 speedup {cur['quant_speedup']:.2f}x fell "
                f"more than {max_regression:.0%} below baseline "
                f"{base['quant_speedup']:.2f}x")
        if not ok_err:
            failures.append(
                f"{name}: quant_max_abs_err {cur['quant_max_abs_err']:.2e} "
                f"exceeds the {err_budget:.0e} precision budget")
    return failures


def reseed(n: int, reps: int, configs, out_path: str, calibrate: int = 4,
           trajectory_dir=TRAJECTORY_DIR) -> dict:
    """Min-over-N baseline: run ``table1 --precision int8`` N times and
    floor each config at its minimum int8/f32 speedup."""
    import jax
    import platform

    from .table1 import run as run_table1

    all_rows = []
    for i in range(n):
        rows = run_table1(reps=reps, configs=configs,
                          precision="int8", calibrate=calibrate)
        all_rows.append(rows)
        line = ", ".join(f"{name}: {r['quant_speedup']:.2f}x "
                         f"(err {r['quant_max_abs_err']:.1e})"
                         for name, r in rows.items())
        print(f"[reseed] run {i + 1}/{n}: {line}")
        append_trajectory({"bench": "table1", "precision": "int8",
                           "mode": "reseed", "run": i + 1, "of": n,
                           "rows": rows}, trajectory_dir)

    baseline_rows = {}
    for name in all_rows[0]:
        runs = [rows[name] for rows in all_rows]
        floor = min(runs, key=lambda r: r["quant_speedup"])
        baseline_rows[name] = {
            **floor, "quant_speedup": round(floor["quant_speedup"], 2)}
    doc = {
        "bench": "table1",
        "precision": "int8",
        "calibrate": calibrate,
        "rows": baseline_rows,
        "env": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "machine": platform.machine(),
        },
        "note": (f"seeded by `python -m benchmarks.precision_gate "
                 f"--reseed {n}` as the per-config MINIMUM int8/f32 "
                 f"pallas speedup over {n} runs (reps={reps}, min-of-reps "
                 "estimator); the gate allows a further fractional drop, "
                 "so only a structural regression — the int8 lowering "
                 "falling back to f32, the dequant epilogue unfusing — "
                 "trips it"),
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"[reseed] wrote {out_path}")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?",
                    help="fresh PRECISION_*.json from this run (gate mode)")
    ap.add_argument("baseline", nargs="?",
                    default="benchmarks/artifacts/precision_baseline.json",
                    help="checked-in precision_baseline.json")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional int8-speedup drop "
                         "(default 0.25)")
    ap.add_argument("--err-budget", type=float, default=ERR_BUDGET,
                    help="int8 max_abs_err ceiling vs the f32 oracle "
                         f"(default {ERR_BUDGET}, the pass's "
                         "DEFAULT_PRECISION_BUDGET)")
    ap.add_argument("--reseed", type=int, metavar="N",
                    help="regenerate the baseline as min-over-N "
                         "`table1 --precision int8` runs instead of gating")
    ap.add_argument("--configs", nargs="*", metavar="NAME",
                    help="configs for --reseed (default: the CI bench-smoke "
                         "pair, C-HTWK C-BH)")
    ap.add_argument("--reps", type=int, default=50,
                    help="table1 reps per --reseed run (default 50)")
    ap.add_argument("--calibrate", type=int, default=4,
                    help="calibration batches for --reseed (default 4, "
                         "matching the CI invocation)")
    ap.add_argument("--out",
                    default="benchmarks/artifacts/precision_baseline.json",
                    help="where --reseed writes the new baseline")
    ap.add_argument("--trajectory-dir", default=TRAJECTORY_DIR,
                    help="perf-trajectory directory (default "
                         "benchmarks/artifacts/trajectory)")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="do not append this run to the trajectory")
    args = ap.parse_args(argv)

    if args.reseed is not None:
        if args.reseed < 1:
            ap.error("--reseed must be >= 1")
        configs = args.configs if args.configs else ["C-HTWK", "C-BH"]
        reseed(args.reseed, args.reps, configs, args.out, args.calibrate,
               None if args.no_trajectory else args.trajectory_dir)
        return 0

    if not args.current:
        ap.error("gate mode needs a current PRECISION_*.json "
                 "(or use --reseed N)")
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = gate(current, baseline, args.max_regression, args.err_budget)
    if not args.no_trajectory:
        append_trajectory({
            **current,
            "gate": {
                "baseline": args.baseline,
                "kind": "precision",
                "max_regression": args.max_regression,
                "err_budget": args.err_budget,
                "verdict": "fail" if failures else "ok",
                "failures": failures,
            },
        }, args.trajectory_dir)
    if failures:
        for msg in failures:
            print(f"[precision-gate] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[precision-gate] OK — quantized path holds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
