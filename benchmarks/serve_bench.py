"""Serving benchmarks: shape-polymorphic and serve-hot-loop gates.

Legacy mixed-length mode (fixed-shape vs bucketed)::

    PYTHONPATH=src python -m benchmarks.serve_bench --arch qwen2.5-14b \
        --smoke --requests 16 --slots 4 --max-len 64 --out SERVE_BENCH.json

Drives the continuous-batching scheduler twice over the same synthetic
mixed-length request stream — once fixed-shape (``buckets=None``, the
pre-bucketing scheduler) and once bucketed — and emits one JSON artifact
with both summaries.  The bucketed run is split into a *warm-up wave*
(background compiles land here) and a *steady-state wave* after
``wait_warm()``; the bench asserts the steady wave serves with **zero
request-path compile stalls** (the engine-cache contract) and that its
greedy tokens are identical to the fixed-shape scheduler's, request by
request.  Exit code 1 on either violation, so CI can gate on it.

Mixed-SLO trace mode (``--trace mixed-slo``)::

    PYTHONPATH=src python -m benchmarks.serve_bench --trace mixed-slo \
        --arch qwen2.5-14b --smoke --gate --out SERVE_SLO.json

One trace of short interactive requests (tight ``slo_ms``) interleaved
with long batch requests sharing a system-prompt head, served by three
schedulers: ``fixed`` (the token oracle), ``base`` (the PR-7 feature
set: buckets + fcfs) and ``opt`` (buckets + chunked prefill + prefix
cache + deadline admission).  Reports TTFT p50/p99 (overall and for the
interactive class), steady-wave decode tok/s and ``slo_violations``,
asserts token bit-identity and zero steady-state stalls, and appends to
``benchmarks/artifacts/trajectory/``.  ``--gate`` fails on a >25%
regression of the machine-portable opt/base ratios vs the seeded
``benchmarks/artifacts/serve_baseline.json``; ``--reseed N`` rebuilds
that baseline as the worst ratio over N runs (the ``perf_gate.py``
procedure).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from .perf_gate import append_trajectory

SERVE_BASELINE = "benchmarks/artifacts/serve_baseline.json"


def synth_requests(rng, n, vocab, max_len, max_new, uid0=0):
    """Mixed-length stream: prompt lengths spread over [3, max_len/2)."""
    from repro.serve import Request
    hi = max(5, max_len // 2)
    return [Request(uid=uid0 + i,
                    prompt=rng.integers(0, vocab,
                                        int(rng.integers(3, hi))).astype(
                                            np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def drain(sched, reqs):
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    return time.perf_counter() - t0, {c.uid: c.tokens for c in done}


def mixed_slo_requests(rng, n, vocab, max_len, max_new, head, slo_ms,
                       uid0=0):
    """The mixed-SLO trace: even uids are short interactive requests
    with a tight first-token SLO; odd uids are long batch requests (no
    SLO) whose prompts all start with the shared ``head`` (the system
    prompt).  Submitted as one burst, so admission order is exactly
    what the scheduler's policy decides."""
    from repro.serve import Request
    reqs = []
    short_hi = max(5, len(head) // 2)
    tail_hi = max(4, max_len - len(head) - max_new - 1)
    for i in range(n):
        if i % 2 == 0:
            prompt = rng.integers(0, vocab, int(rng.integers(
                3, short_hi))).astype(np.int32)
            slo = slo_ms
        else:
            tail = rng.integers(0, vocab, int(rng.integers(
                3, tail_hi))).astype(np.int32)
            prompt = np.concatenate([head, tail])
            slo = None
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=max_new, slo_ms=slo))
    return reqs


def wave_stats(sched, uids, wall_s):
    """TTFT percentiles (overall + interactive class), SLO violations
    and throughput for one measured wave."""
    from repro.serve.metrics import percentile
    ms = [sched.request_metrics[u] for u in uids]
    ttfts = [m.ttft for m in ms if m.ttft is not None]
    inter = [m for m in ms if m.deadline is not None]
    inter_ttfts = [m.ttft for m in inter if m.ttft is not None]
    new_tokens = sum(m.new_tokens for m in ms)
    return {
        "wall_s": round(wall_s, 3),
        "requests": len(ms),
        "new_tokens": new_tokens,
        "tok_s": round(new_tokens / wall_s, 2) if wall_s > 0 else None,
        "ttft_p50": percentile(ttfts, 50.0),
        "ttft_p99": percentile(ttfts, 99.0),
        "interactive_ttft_p50": percentile(inter_ttfts, 50.0),
        "interactive_ttft_p99": percentile(inter_ttfts, 99.0),
        "slo_violations": sum(1 for m in inter if m.slo_violated),
        "slo_requests": len(inter),
    }


def run_mixed_slo(args) -> dict:
    """One three-scheduler comparison over the same mixed-SLO trace.
    Returns the report dict (no gating here — the caller gates)."""
    import repro
    from repro.configs import get_config

    cfg = get_config(args.arch, smoke=args.smoke)
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    chunk = args.chunk or max(8, args.max_len // 8)
    head_len = 3 * chunk
    policy = repro.BucketPolicy.default(max_batch=args.slots,
                                        max_len=args.max_len)
    head = np.random.default_rng(7).integers(
        0, cfg.vocab, head_len).astype(np.int32)

    def trace(uid0):
        rng = np.random.default_rng(0)
        return mixed_slo_requests(rng, args.requests, cfg.vocab,
                                  args.max_len, args.max_new, head,
                                  args.slo_ms, uid0=uid0)

    common = dict(slots=args.slots, max_len=args.max_len)
    variants = {
        "fixed": repro.SchedulerOptions(**common),
        "base": repro.SchedulerOptions(buckets=policy, **common),
        "opt": repro.SchedulerOptions(buckets=policy,
                                      admission="deadline",
                                      prefill_chunk=chunk,
                                      prefix_cache=8, **common),
    }
    results, tokens = {}, {}
    for name, opts in variants.items():
        sched = repro.serve(exe, opts)
        _, warm_tokens = drain(sched, trace(uid0=100_000))
        sched.wait_warm()
        pre = sched.summary()
        stalls0 = pre.get("runtime", {}).get("compile_stalls", 0)
        steady = trace(uid0=0)
        wall, steady_tokens = drain(sched, steady)
        summ = sched.summary()
        stats = wave_stats(sched, [r.uid for r in steady], wall)
        stats["steady_state_stalls"] = (
            summ.get("runtime", {}).get("compile_stalls", 0) - stalls0)
        results[name] = {"steady": stats, "summary": summ}
        tokens[name] = warm_tokens | steady_tokens
        sched.shutdown()

    mismatched = {
        name: [uid for uid, t in tokens[name].items()
               if tokens["fixed"][uid] != t]
        for name in ("base", "opt")}
    base_s, opt_s = results["base"]["steady"], results["opt"]["steady"]
    ratios = {
        # machine-portable: both sides of each ratio ran on this host
        "interactive_ttft_p99_ratio": round(
            opt_s["interactive_ttft_p99"] / base_s["interactive_ttft_p99"],
            4) if base_s["interactive_ttft_p99"] else None,
        "tok_s_ratio": round(opt_s["tok_s"] / base_s["tok_s"], 4)
        if base_s["tok_s"] else None,
    }
    return {
        "bench": "serve_mixed_slo",
        "arch": args.arch, "smoke": args.smoke, "slots": args.slots,
        "max_len": args.max_len, "requests": args.requests,
        "max_new": args.max_new, "chunk": chunk, "head_len": head_len,
        "slo_ms": args.slo_ms, "policy": policy.to_dict(),
        "results": results,
        "ratios": ratios,
        "tokens_match": not any(mismatched.values()),
        "mismatched_uids": mismatched,
    }


def gate_mixed_slo(report, baseline_path, max_regression) -> list:
    """Failures for the mixed-SLO gate: opt/base ratios must not regress
    more than ``max_regression`` vs the seeded baseline (TTFT ratio up =
    worse; tok/s ratio down = worse)."""
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except FileNotFoundError:
        return [f"no serve baseline at {baseline_path} — seed one with "
                f"`python -m benchmarks.serve_bench --trace mixed-slo "
                f"--reseed N`"]
    failures = []
    cur, ref = report["ratios"], base["ratios"]
    ttft_cur, ttft_ref = (cur["interactive_ttft_p99_ratio"],
                          ref["interactive_ttft_p99_ratio"])
    ceil = ttft_ref * (1.0 + max_regression)
    print(f"[serve-gate] interactive ttft_p99 opt/base {ttft_cur:.3f} "
          f"(baseline {ttft_ref:.3f}, ceiling {ceil:.3f}) "
          f"{'OK' if ttft_cur <= ceil else 'REGRESSION'}")
    if ttft_cur > ceil:
        failures.append(
            f"interactive ttft_p99 ratio {ttft_cur:.3f} rose more than "
            f"{max_regression:.0%} above baseline {ttft_ref:.3f}")
    tok_cur, tok_ref = cur["tok_s_ratio"], ref["tok_s_ratio"]
    floor = tok_ref * (1.0 - max_regression)
    print(f"[serve-gate] steady tok/s opt/base {tok_cur:.3f} "
          f"(baseline {tok_ref:.3f}, floor {floor:.3f}) "
          f"{'OK' if tok_cur >= floor else 'REGRESSION'}")
    if tok_cur < floor:
        failures.append(
            f"tok/s ratio {tok_cur:.3f} fell more than "
            f"{max_regression:.0%} below baseline {tok_ref:.3f}")
    return failures


def reseed_mixed_slo(args) -> dict:
    """Worst-over-N baseline for the mixed-SLO gate (the documented
    ``perf_gate.py --reseed`` procedure): highest TTFT ratio and lowest
    tok/s ratio across N runs become the new floors."""
    import platform

    import jax

    runs = []
    for i in range(args.reseed):
        rep = run_mixed_slo(args)
        runs.append(rep["ratios"])
        print(f"[serve-reseed] run {i + 1}/{args.reseed}: "
              f"ttft_ratio {rep['ratios']['interactive_ttft_p99_ratio']} "
              f"tok_s_ratio {rep['ratios']['tok_s_ratio']}")
        append_trajectory({"bench": "serve_mixed_slo", "mode": "reseed",
                           "run": i + 1, "of": args.reseed,
                           "ratios": rep["ratios"]})
    doc = {
        "bench": "serve_mixed_slo",
        "ratios": {
            "interactive_ttft_p99_ratio": max(
                r["interactive_ttft_p99_ratio"] for r in runs),
            "tok_s_ratio": min(r["tok_s_ratio"] for r in runs),
        },
        "env": {"jax": jax.__version__,
                "backend": jax.default_backend(),
                "python": platform.python_version(),
                "machine": platform.machine()},
        "note": (f"seeded by `python -m benchmarks.serve_bench --trace "
                 f"mixed-slo --reseed {args.reseed}` as the WORST "
                 f"opt/base ratio over {args.reseed} runs; the gate "
                 "allows a further fractional drop, so only a "
                 "structural regression in the serve hot loop trips it"),
    }
    with open(args.baseline, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"[serve-reseed] wrote {args.baseline}")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16,
                    help="total requests; half warm-up, half steady-state")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    ap.add_argument("--allow-stalls", action="store_true",
                    help="report steady-state stalls instead of failing")
    ap.add_argument("--trace", choices=("mixed", "mixed-slo"),
                    default="mixed",
                    help="'mixed' = legacy fixed-vs-bucketed bench; "
                         "'mixed-slo' = interactive+batch trace comparing "
                         "the PR-7 scheduler to the serve-hot-loop one")
    ap.add_argument("--chunk", type=int, default=None,
                    help="prefill chunk for mixed-slo (default max_len//8)")
    ap.add_argument("--slo-ms", type=float, default=300.0,
                    help="first-token SLO for interactive requests (ms)")
    ap.add_argument("--gate", action="store_true",
                    help="mixed-slo: fail on ratio regression vs the "
                         "seeded serve baseline")
    ap.add_argument("--reseed", type=int, metavar="N", default=None,
                    help="mixed-slo: rebuild the serve baseline as the "
                         "worst ratio over N runs instead of gating")
    ap.add_argument("--baseline", default=SERVE_BASELINE,
                    help="serve baseline path for --gate/--reseed")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional ratio regression for --gate")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="do not append this run to the perf trajectory")
    args = ap.parse_args(argv)

    if args.trace == "mixed-slo":
        return main_mixed_slo(args)

    import repro
    from repro.configs import get_config

    cfg = get_config(args.arch, smoke=args.smoke)
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    n_warm = args.requests // 2
    n_steady = args.requests - n_warm

    def requests(uid0=0):
        rng = np.random.default_rng(0)
        reqs = synth_requests(rng, args.requests, cfg.vocab, args.max_len,
                              args.max_new, uid0=uid0)
        return reqs[:n_warm], reqs[n_warm:]

    # -- fixed-shape reference ----------------------------------------
    sched = repro.serve(exe, repro.SchedulerOptions(
        slots=args.slots, max_len=args.max_len))
    warm, steady = requests()
    t_fixed, fixed_tokens = drain(sched, warm + steady)
    fixed_summary = sched.summary()

    # -- bucketed: warm-up wave, then the steady-state wave -----------
    policy = repro.BucketPolicy.default(max_batch=args.slots,
                                       max_len=args.max_len)
    sched = repro.serve(exe, repro.SchedulerOptions(
        slots=args.slots, max_len=args.max_len, buckets=policy))
    warm, steady = requests()
    t_warm, warm_tokens = drain(sched, warm)
    warmed = sched.wait_warm()
    stalls0 = sched.summary()["runtime"]["compile_stalls"]
    t_steady, steady_tokens = drain(sched, steady)
    bucketed_summary = sched.summary()
    sched.shutdown()
    steady_stalls = bucketed_summary["runtime"]["compile_stalls"] - stalls0

    mismatched = [uid for uid, toks in (warm_tokens | steady_tokens).items()
                  if fixed_tokens[uid] != toks]
    report = {
        "arch": args.arch, "smoke": args.smoke, "slots": args.slots,
        "max_len": args.max_len, "requests": args.requests,
        "policy": policy.to_dict(),
        "fixed": {"wall_s": round(t_fixed, 3), "summary": fixed_summary},
        "bucketed": {"warm_wall_s": round(t_warm, 3),
                     "steady_wall_s": round(t_steady, 3),
                     "warmed": warmed,
                     "summary": bucketed_summary},
        "steady_state_stalls": steady_stalls,
        "tokens_match": not mismatched,
        "mismatched_uids": mismatched,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    rt = bucketed_summary["runtime"]
    print(f"[serve_bench] fixed {t_fixed:.2f}s | bucketed warm "
          f"{t_warm:.2f}s steady {t_steady:.2f}s | "
          f"{rt['bucket_hits']} hits / {rt['bucket_misses']} misses / "
          f"{rt['background_compiles']} bg compiles | "
          f"pad waste {rt['pad_waste_frac']:.1%} | "
          f"steady-state stalls {steady_stalls}", flush=True)

    ok = True
    if mismatched:
        print(f"[serve_bench] FAIL: bucketed tokens diverge from "
              f"fixed-shape for uids {mismatched}", file=sys.stderr)
        ok = False
    if steady_stalls and not args.allow_stalls:
        print(f"[serve_bench] FAIL: {steady_stalls} compile stall(s) on "
              f"the request path in steady state", file=sys.stderr)
        ok = False
    return 0 if ok else 1


def main_mixed_slo(args) -> int:
    """Drive the mixed-SLO trace: reseed, or run once and (optionally)
    gate.  Token identity and zero steady-state stalls always fail the
    run; ratio regressions only under ``--gate``."""
    if args.reseed is not None:
        if args.reseed < 1:
            raise SystemExit("--reseed must be >= 1")
        reseed_mixed_slo(args)
        return 0

    report = run_mixed_slo(args)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    for name in ("base", "opt"):
        s = report["results"][name]["steady"]
        print(f"[serve_bench] {name:<5} wall {s['wall_s']:.2f}s "
              f"tok/s {s['tok_s']} "
              f"ttft_p99 {s['ttft_p99']:.3f}s "
              f"(interactive {s['interactive_ttft_p99']:.3f}s) "
              f"slo_violations {s['slo_violations']}/{s['slo_requests']} "
              f"stalls {s['steady_state_stalls']}", flush=True)
    opt = report["results"]["opt"]["summary"]
    print(f"[serve_bench] opt prefix_cache {opt.get('prefix_cache')} "
          f"chunks {opt.get('prefill_chunks')} "
          f"ratios {report['ratios']}", flush=True)

    failures = []
    if not report["tokens_match"]:
        failures.append(f"token streams diverge from the fixed-shape "
                        f"oracle: {report['mismatched_uids']}")
    for name in ("base", "opt"):
        n = report["results"][name]["steady"]["steady_state_stalls"]
        if n and not args.allow_stalls:
            failures.append(f"{name}: {n} compile stall(s) on the "
                            f"request path in steady state")
    if args.gate:
        failures += gate_mixed_slo(report, args.baseline,
                                   args.max_regression)
    if not args.no_trajectory:
        append_trajectory({**report,
                           "gate": {"enabled": args.gate,
                                    "baseline": args.baseline,
                                    "verdict": "fail" if failures else "ok",
                                    "failures": failures}})
    for msg in failures:
        print(f"[serve_bench] FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
