"""Mixed-length serving benchmark: fixed-shape vs shape-polymorphic.

    PYTHONPATH=src python -m benchmarks.serve_bench --arch qwen2.5-14b \
        --smoke --requests 16 --slots 4 --max-len 64 --out SERVE_BENCH.json

Drives the continuous-batching scheduler twice over the same synthetic
mixed-length request stream — once fixed-shape (``buckets=None``, the
pre-bucketing scheduler) and once bucketed — and emits one JSON artifact
with both summaries.  The bucketed run is split into a *warm-up wave*
(background compiles land here) and a *steady-state wave* after
``wait_warm()``; the bench asserts the steady wave serves with **zero
request-path compile stalls** (the engine-cache contract) and that its
greedy tokens are identical to the fixed-shape scheduler's, request by
request.  Exit code 1 on either violation, so CI can gate on it.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def synth_requests(rng, n, vocab, max_len, max_new, uid0=0):
    """Mixed-length stream: prompt lengths spread over [3, max_len/2)."""
    from repro.serve import Request
    hi = max(5, max_len // 2)
    return [Request(uid=uid0 + i,
                    prompt=rng.integers(0, vocab,
                                        int(rng.integers(3, hi))).astype(
                                            np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def drain(sched, reqs):
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    return time.perf_counter() - t0, {c.uid: c.tokens for c in done}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16,
                    help="total requests; half warm-up, half steady-state")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    ap.add_argument("--allow-stalls", action="store_true",
                    help="report steady-state stalls instead of failing")
    args = ap.parse_args(argv)

    import repro
    from repro.configs import get_config

    cfg = get_config(args.arch, smoke=args.smoke)
    exe = repro.compile(cfg, repro.CompileOptions(target="engine"))
    n_warm = args.requests // 2
    n_steady = args.requests - n_warm

    def requests(uid0=0):
        rng = np.random.default_rng(0)
        reqs = synth_requests(rng, args.requests, cfg.vocab, args.max_len,
                              args.max_new, uid0=uid0)
        return reqs[:n_warm], reqs[n_warm:]

    # -- fixed-shape reference ----------------------------------------
    sched = repro.serve(exe, repro.SchedulerOptions(
        slots=args.slots, max_len=args.max_len))
    warm, steady = requests()
    t_fixed, fixed_tokens = drain(sched, warm + steady)
    fixed_summary = sched.summary()

    # -- bucketed: warm-up wave, then the steady-state wave -----------
    policy = repro.BucketPolicy.default(max_batch=args.slots,
                                       max_len=args.max_len)
    sched = repro.serve(exe, repro.SchedulerOptions(
        slots=args.slots, max_len=args.max_len, buckets=policy))
    warm, steady = requests()
    t_warm, warm_tokens = drain(sched, warm)
    warmed = sched.wait_warm()
    stalls0 = sched.summary()["runtime"]["compile_stalls"]
    t_steady, steady_tokens = drain(sched, steady)
    bucketed_summary = sched.summary()
    sched.shutdown()
    steady_stalls = bucketed_summary["runtime"]["compile_stalls"] - stalls0

    mismatched = [uid for uid, toks in (warm_tokens | steady_tokens).items()
                  if fixed_tokens[uid] != toks]
    report = {
        "arch": args.arch, "smoke": args.smoke, "slots": args.slots,
        "max_len": args.max_len, "requests": args.requests,
        "policy": policy.to_dict(),
        "fixed": {"wall_s": round(t_fixed, 3), "summary": fixed_summary},
        "bucketed": {"warm_wall_s": round(t_warm, 3),
                     "steady_wall_s": round(t_steady, 3),
                     "warmed": warmed,
                     "summary": bucketed_summary},
        "steady_state_stalls": steady_stalls,
        "tokens_match": not mismatched,
        "mismatched_uids": mismatched,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    rt = bucketed_summary["runtime"]
    print(f"[serve_bench] fixed {t_fixed:.2f}s | bucketed warm "
          f"{t_warm:.2f}s steady {t_steady:.2f}s | "
          f"{rt['bucket_hits']} hits / {rt['bucket_misses']} misses / "
          f"{rt['background_compiles']} bg compiles | "
          f"pad waste {rt['pad_waste_frac']:.1%} | "
          f"steady-state stalls {steady_stalls}", flush=True)

    ok = True
    if mismatched:
        print(f"[serve_bench] FAIL: bucketed tokens diverge from "
              f"fixed-shape for uids {mismatched}", file=sys.stderr)
        ok = False
    if steady_stalls and not args.allow_stalls:
        print(f"[serve_bench] FAIL: {steady_stalls} compile stall(s) on "
              f"the request path in steady state", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
