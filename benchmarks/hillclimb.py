import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: lower a cell under a series of cumulative
optimization variants and report the three roofline terms per variant.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen-train
"""

import argparse
import dataclasses
import json
import time


# Each series: (name, cfg overrides, train overrides, rules overrides)
# applied CUMULATIVELY on top of the previous variant.
SERIES = {
    # Cell 1: flagship dense training (paper-representative: the whole
    # point of compile-time specialization is the steady-state step).
    "qwen-train": {
        "arch": "qwen2.5-14b", "shape": "train_4k",
        "steps": [
            # NOTE: "baseline" here already contains the unconditional
            # dtype-pinning fixes (bf16 TP reduces / bf16 rope); compare
            # against the dry-run artifact for the original baseline.
            ("baseline", {}, {}, {}),
            ("causal-skip", {"causal_skip": True}, {}, {}),
            ("bf16-attn", {"attn_compute_dtype": "bfloat16"}, {}, {}),
            ("bf16-params", {}, {"cast_params": True}, {}),
            ("colrow-psum", {"tp_psum": True}, {}, {}),
            ("pregather-mb16", {},
             {"pregather_params": True, "microbatches": 16}, {}),
        ],
    },
    # Cell 2: most collective-bound (MoE + MLA at 671B).
    "dsv3-train": {
        "arch": "deepseek-v3-671b", "shape": "train_4k",
        "steps": [
            ("baseline", {}, {}, {}),
            ("causal-skip+bf16-attn",
             {"causal_skip": True, "attn_compute_dtype": "bfloat16"},
             {}, {}),
            ("bf16-params", {}, {"cast_params": True}, {}),
            ("colrow-psum", {"tp_psum": True}, {}, {}),
            ("compress-grads", {}, {"compress_grads": True}, {}),
        ],
    },
    # Cell 3: worst roofline fraction — serving decode (the paper's
    # matrix-vector hot loop at LLM scale).
    "qwen-decode": {
        "arch": "qwen2.5-14b", "shape": "decode_32k",
        "steps": [
            ("baseline", {}, {}, {}),
            ("scatter-cache", {"cache_update": "scatter"}, {}, {}),
            ("bf16-attn", {"attn_compute_dtype": "bfloat16"}, {}, {}),
            ("bf16-params", {"param_dtype": "bfloat16"}, {}, {}),
            ("tp-resident-params", {}, {}, {"fsdp": None}),
            ("tp-psum", {"tp_psum": True}, {}, {}),
        ],
    },
    # gemma3 local-attention prefill: causal+window skip pays double.
    "gemma3-prefill": {
        "arch": "gemma3-27b", "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}, {}),
            ("causal-skip", {"causal_skip": True}, {}, {}),
            ("bf16-attn", {"attn_compute_dtype": "bfloat16"}, {}, {}),
            ("tp-resident-params", {"param_dtype": "bfloat16"}, {},
             {"fsdp": None}),
        ],
    },
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(SERIES))
    ap.add_argument("--out", default="benchmarks/artifacts/perf")
    args = ap.parse_args()

    from repro.configs import SHAPES, get_config
    from repro.launch import cells
    from repro.launch.mesh import make_production_mesh
    from repro.training import TrainConfig

    series = SERIES[args.cell]
    mesh = make_production_mesh()
    shape = SHAPES[series["shape"]]

    cfg_over, tc_over, rules_over = {}, {}, {}
    results = []
    for name, c_o, t_o, r_o in series["steps"]:
        cfg_over.update(c_o)
        tc_over.update(t_o)
        rules_over.update(r_o)
        cfg = dataclasses.replace(get_config(series["arch"]), **cfg_over)
        tc = TrainConfig(**{"microbatches": 8, **tc_over})
        t0 = time.time()
        low = cells.lower_cell(cfg, shape, mesh, tc,
                               rules=rules_over or None)
        comp = low.compile()
        rec = cells.analyze(low, comp, cfg, shape, mesh)
        rec["variant"] = name
        rec["wall_s"] = round(time.time() - t0, 1)
        results.append(rec)
        t = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2 ** 30
        print(f"[{args.cell}] {name:<22} compute={rec['compute_s']:.4f} "
              f"mem={rec['memory_s']:.4f} coll={rec['collective_s']:.4f} "
              f"bneck={rec['bottleneck']:<10} rf={rec['roofline_fraction']:.4f} "
              f"temp={t:.1f}GiB", flush=True)

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"{args.cell}.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
