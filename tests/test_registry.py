"""The registry-driven middle end: pass ordering + verification via
PassManager, per-op lowering rules with target overrides, and the
shape-aware kernel selector."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.api import CompileOptions
from repro.core import (Graph, ModelBuilder, SimpleNN, UnsupportedOpError,
                        execute_graph, register_lowering, select_kernels)
from repro.core.graph import OPS
from repro.core.lowering import _RULES, get_lowering, registered_ops
from repro.core.passes import (DEFAULT_PIPELINE, PassManager,
                               PassOrderingError, PassVerificationError,
                               register_pass, unregister_pass, run_pipeline)


def _cnn(seed=0):
    mb = ModelBuilder().seed(seed)
    x = mb.input((8, 8, 3))
    h = mb.conv2d(x, 8, (3, 3), activation="relu")
    h = mb.batchnorm(h)
    h = mb.global_avg_pool(h)
    h = mb.dense(h, 10)
    out = mb.softmax(h)
    return mb.build([out]), out


# ---------------------------------------------------------------------------
# Pass layer: ordering resolution, ablation surgery, verifier
# ---------------------------------------------------------------------------
def test_default_pipeline_resolution_matches_legacy_order():
    assert DEFAULT_PIPELINE == (
        "canonicalize", "fold_constants", "fuse_pad", "fuse_activation",
        "fold_batchnorm", "fuse_activation.post_bn", "quantize",
        "optimize_layout", "propagate_sharding")


def test_explicit_pipeline_allows_base_names_and_duplicates():
    pm = PassManager(("canonicalize", "fuse_activation", "fold_batchnorm",
                      "fuse_activation"))
    assert pm.pipeline == ("canonicalize", "fuse_activation",
                           "fold_batchnorm", "fuse_activation")
    with pytest.raises(KeyError, match="unknown pass"):
        PassManager(("no_such_pass",))


def test_without_removes_every_instance():
    pm = PassManager.default().without("fuse_activation")
    assert "fuse_activation" not in pm.pipeline
    assert "fuse_activation.post_bn" not in pm.pipeline
    # and the surgery is non-destructive
    assert "fuse_activation" in PassManager.default().pipeline


def test_with_pass_inserts():
    pm = PassManager(("canonicalize",)).with_pass("optimize_layout")
    assert pm.pipeline == ("canonicalize", "optimize_layout")


def test_ordering_cycle_is_a_clear_error():
    register_pass("cyc_a", before=("cyc_b",))(lambda g: (g, {}))
    register_pass("cyc_b", before=("cyc_a",))(lambda g: (g, {}))
    try:
        with pytest.raises(PassOrderingError, match="cycle"):
            PassManager.default()
    finally:
        unregister_pass("cyc_a")
        unregister_pass("cyc_b")


def test_verifier_rejects_shape_breaking_pass():
    def break_shapes(g):
        g = g.copy()
        # Re-point the model output at an intermediate tensor with a
        # different shape — exactly the sort of silent corruption the
        # per-pass verifier exists to catch.
        g.outputs = [g.nodes[0].output]
        return g, {}

    register_pass("break_shapes")(break_shapes)
    try:
        g, _ = _cnn()
        with pytest.raises(PassVerificationError, match="break_shapes"):
            run_pipeline(g, ("canonicalize", "break_shapes"))
    finally:
        unregister_pass("break_shapes")


def test_verifier_rejects_invalid_graph():
    def dangle(g):
        g = g.copy()
        g.nodes[-1].inputs = ["tensor_that_does_not_exist"]
        return g, {}

    register_pass("dangle")(dangle)
    try:
        g, _ = _cnn()
        with pytest.raises(PassVerificationError, match="dangle"):
            run_pipeline(g, ("dangle",))
    finally:
        unregister_pass("dangle")


def test_report_carries_pipeline_and_timings():
    g, _ = _cnn()
    _, report = run_pipeline(g)
    assert report["pipeline"] == DEFAULT_PIPELINE
    assert [p["pass"] for p in report["passes"]] == list(DEFAULT_PIPELINE)
    assert all(p["time_ms"] >= 0 for p in report["passes"])


def test_dump_ir_writes_stage_files(tmp_path):
    g, _ = _cnn()
    exe = repro.compile(g, CompileOptions(dump_ir=str(tmp_path)))
    exe.ensure_compiled(1)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names[0] == "00-input.txt"
    assert f"{len(DEFAULT_PIPELINE):02d}-propagate_sharding.txt" in names
    assert "Graph:" in (tmp_path / "00-input.txt").read_text()


# ---------------------------------------------------------------------------
# Lowering layer: rule registry, target overrides, diagnostics
# ---------------------------------------------------------------------------
def test_unsupported_op_is_a_structured_diagnostic():
    with pytest.raises(UnsupportedOpError) as ei:
        get_lowering("mystery_op", "pallas")
    msg = str(ei.value)
    assert "mystery_op" in msg and "pallas" in msg
    assert "registered ops" in msg and "dense" in msg
    assert "register_lowering" in msg
    assert isinstance(ei.value, NotImplementedError)  # legacy contract


def test_register_lowering_with_target_override(monkeypatch, rng):
    monkeypatch.setitem(OPS, "scale2", ())

    @register_lowering("scale2")
    def _generic(node, ins, ctx):
        return ins[0] * 2.0

    @register_lowering("scale2", target="weird")
    def _weird(node, ins, ctx):
        return ins[0] * 3.0

    try:
        assert "scale2" in registered_ops()
        g = Graph()
        g.add_input("x", (4,))
        g.add_node("scale2", "s", ["x"])
        g.set_outputs(["s:out"])
        x = jnp.asarray(rng.standard_normal((2, 4)).astype(np.float32))
        out = execute_graph(g, {"x": x}, {}, target="jit")["s:out"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)
        out = execute_graph(g, {"x": x}, {}, target="weird")["s:out"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 3.0)
    finally:
        _RULES.pop(("scale2", None))
        _RULES.pop(("scale2", "weird"))


@pytest.mark.parametrize("case", ["dense_act", "conv_bn", "tiny_dense"])
def test_golden_interpret_jit_pallas(case, rng):
    mb = ModelBuilder().seed(7)
    x = mb.input((6, 6, 3))
    if case == "dense_act":
        out = mb.dense(mb.flatten(x), 9, activation="tanh")
    elif case == "conv_bn":
        h = mb.conv2d(x, 5, (3, 3), activation="relu")
        out = mb.batchnorm(h)
    else:  # tiny_dense: the selector's lax fallback path on pallas
        out = mb.dense(mb.dense(mb.global_avg_pool(x), 1), 1)
    g = mb.build([out])
    xv = rng.standard_normal((2, 6, 6, 3)).astype(np.float32)
    outs = {
        t: np.asarray(repro.compile(g, CompileOptions(target=t))(input=xv)[out])
        for t in ("interpret", "jit", "pallas")
    }
    np.testing.assert_allclose(outs["interpret"], outs["jit"],
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(outs["interpret"], outs["pallas"],
                               rtol=2e-5, atol=2e-6)


def test_constant_broadcast_uses_explicit_batch(rng):
    # The input feeds a *later* node than the constant — batch size must
    # come from the lowering context, not from peeking at env entries.
    g = Graph()
    g.add_input("x", (4,))
    g.add_param("c", np.arange(4, dtype=np.float32))
    g.add_node("constant", "const", [], params={"value": "c"})
    g.add_node("activation", "act", ["const:out"], attrs={"fn": "relu"})
    g.add_node("add", "sum", ["act:out", "x"])
    g.set_outputs(["sum:out"])
    want_c = np.maximum(np.arange(4, dtype=np.float32), 0.0)
    for target in ("interpret", "jit", "pallas"):
        for batch in (1, 3):
            x = rng.standard_normal((batch, 4)).astype(np.float32)
            out = repro.compile(g, CompileOptions(target=target))(x=x)["sum:out"]
            np.testing.assert_allclose(np.asarray(out), x + want_c,
                                       rtol=1e-6, err_msg=f"{target}/{batch}")


# ---------------------------------------------------------------------------
# Selection layer: static shape-based kernel choice, surfaced decisions
# ---------------------------------------------------------------------------
def test_selector_picks_pallas_for_real_dense_and_lax_for_degenerate():
    mb = ModelBuilder().seed(0)
    x = mb.input((32,))
    h = mb.dense(x, 8)
    out = mb.dense(h, 1)  # 8x1: sub-granule, ~2000x lane-padding waste
    g = mb.build([out])
    sel = select_kernels(g, batch_size=1, target="pallas")
    kinds = {c.node: c.kernel for c in sel.values()}
    assert kinds["dense_1"] == "pallas.fused_matmul"
    assert kinds["dense_2"] == "lax.dot"
    assert "waste" in sel["dense_2"].reason


def test_selector_is_empty_off_pallas():
    g, _ = _cnn()
    assert select_kernels(g, batch_size=1, target="jit") == {}


def test_cost_summary_surfaces_kernel_selection(rng):
    g, out = _cnn()
    exe = repro.compile(g, CompileOptions(target="pallas"))
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    exe(input=x)
    cost = exe.cost_summary()
    sel = cost["kernel_selection"][2]
    dense = [c for c in sel if c["op"] == "dense"]
    assert dense and dense[0]["kernel"] == "pallas.fused_matmul"
    assert dense[0]["reason"]
    # the jit target records no kernel decisions
    jit_exe = repro.compile(g, CompileOptions(target="jit"))
    jit_exe(input=x)
    assert "kernel_selection" not in jit_exe.cost_summary() or \
        all(not v for v in jit_exe.cost_summary()["kernel_selection"].values())


# ---------------------------------------------------------------------------
# decode_attention: the new op lowers via registered rules on all targets
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d", [64, 128], ids=["d64-ref", "d128-pallas"])
def test_decode_attention_targets_agree(d, rng):
    b, h, hkv, s = 2, 4, 2, 16
    mb = ModelBuilder()
    q = mb.input((h, d), name="q")
    k = mb.input((s, hkv, d), name="k")
    v = mb.input((s, hkv, d), name="v")
    lens = mb.input((), name="lens", dtype="int32")
    out = mb.decode_attention(q, k, v, lens)
    g = mb.build([out])

    qv = rng.standard_normal((b, h, d)).astype(np.float32)
    kv = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    vv = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    lv = np.array([s, s // 2], np.int32)
    feeds = dict(q=qv, k=kv, v=vv, lens=lv)

    from repro.kernels.decode_attention.ref import decode_attention_ref
    want = np.asarray(decode_attention_ref(qv, kv, vv, jnp.asarray(lv)))
    for target in ("interpret", "jit", "pallas"):
        got = np.asarray(
            repro.compile(g, CompileOptions(target=target))(**feeds)[out])
        np.testing.assert_allclose(want, got, rtol=2e-5, atol=2e-6,
                                   err_msg=target)
    sel = select_kernels(g, batch_size=b, target="pallas")
    choice = next(c for c in sel.values() if c.op == "decode_attention")
    assert choice.kernel == ("pallas.decode_attention" if d == 128
                             else "jnp.ref")


def test_plugin_op_end_to_end(rng):
    """The README's "add a new op" recipe: register_op + shape rule +
    one lowering rule makes the op compilable on every target (the
    oracle falls back to the generic rule)."""
    from repro.core import register_op, register_shape_rule
    from repro.core.graph import SHAPE_RULES

    register_op("rmsnorm", ("epsilon",))

    @register_shape_rule("rmsnorm")
    def _rms_shape(node, ins, graph):
        return ins[0]

    @register_lowering("rmsnorm")
    def _rms_lower(node, ins, ctx):
        x = ins[0]
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + node.attrs["epsilon"])

    try:
        g = Graph()
        g.add_input("x", (16,))
        g.add_node("rmsnorm", "norm", ["x"], attrs={"epsilon": 1e-6})
        g.set_outputs(["norm:out"])
        x = rng.standard_normal((3, 16)).astype(np.float32)
        want = x / np.sqrt((x * x).mean(-1, keepdims=True) + 1e-6)
        for target in ("interpret", "jit", "pallas"):
            got = repro.compile(g, CompileOptions(target=target))(x=x)["norm:out"]
            np.testing.assert_allclose(np.asarray(got), want,
                                       rtol=2e-5, atol=2e-6, err_msg=target)
    finally:
        OPS.pop("rmsnorm")
        SHAPE_RULES.pop("rmsnorm")
        _RULES.pop(("rmsnorm", None))


def test_plugin_op_epilogue_not_double_applied(rng):
    """The oracle's plugin-op fallback delegates to the generic rule,
    which (per the documented pattern) applies ctx.epilogue itself; the
    oracle must then NOT apply the epilogue a second time."""
    from repro.core import register_op, register_shape_rule
    from repro.core.graph import SHAPE_RULES

    register_op("double", ())

    @register_shape_rule("double")
    def _shape(node, ins, graph):
        return ins[0]

    @register_lowering("double")
    def _lower(node, ins, ctx):
        return ctx.epilogue(node, ins[0] * 2.0)

    try:
        g = Graph()
        g.add_input("x", (4,))
        g.add_node("double", "d", ["x"])
        g.nodes[0].epilogue = "sigmoid"
        g.set_outputs(["d:out"])
        x = rng.standard_normal((2, 4)).astype(np.float32)
        want = 1.0 / (1.0 + np.exp(-2.0 * x))
        for target in ("interpret", "jit"):
            got = repro.compile(g, CompileOptions(target=target))(x=x)["d:out"]
            np.testing.assert_allclose(np.asarray(got), want,
                                       rtol=2e-5, err_msg=target)
    finally:
        OPS.pop("double")
        SHAPE_RULES.pop("double")
        _RULES.pop(("double", None))


def test_lowering_fingerprint_tracks_rule_edits():
    """The persistent-cache key mixes in the rule-set digest, so editing
    or re-registering a rule invalidates cached executables."""
    from repro.core.lowering import lowering_fingerprint

    fp0 = lowering_fingerprint("jit")
    assert fp0 == lowering_fingerprint("jit")          # deterministic
    assert fp0 != lowering_fingerprint("pallas")       # overrides count

    register_lowering("fp_probe")(lambda node, ins, ctx: ins[0] * 2.0)
    try:
        fp1 = lowering_fingerprint("jit")
        assert fp1 != fp0                              # new rule
        register_lowering("fp_probe")(lambda node, ins, ctx: ins[0] * 3.0)
        assert lowering_fingerprint("jit") not in (fp0, fp1)  # edited body
    finally:
        _RULES.pop(("fp_probe", None))
    assert lowering_fingerprint("jit") == fp0


def test_decode_attention_shape_validation():
    mb = ModelBuilder()
    q = mb.input((5, 16), name="q")      # H=5 not a multiple of Hkv=2
    k = mb.input((8, 2, 16), name="k")
    v = mb.input((8, 2, 16), name="v")
    out = mb.decode_attention(q, k, v)
    g = mb.build([out])
    with pytest.raises(ValueError, match="multiple of Hkv"):
        g.infer_shapes()
