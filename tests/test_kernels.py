"""Pallas kernels: shape/dtype sweeps, interpret=True vs the pure-jnp
oracles (ref.py), per the assignment's per-kernel requirement."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.fast_act import ref as fa_ref
from repro.kernels.fast_act.ops import fast_act, fast_softmax
from repro.kernels.fused_matmul import ref as fm_ref
from repro.kernels.fused_matmul.ops import fused_matmul
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.decode_attention.ops import decode_attention


# ---------------------------------------------------------------------------
# fused matmul + epilogue
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 64, 48), (1, 128, 256),
                                   (100, 30, 17)])
@pytest.mark.parametrize("fn", [None, "relu", "tanh"])
@pytest.mark.parametrize("w_layout", ["io", "oi"])
def test_fused_matmul_sweep(m, k, n, fn, w_layout, rng):
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n) if w_layout == "io" else (n, k)) \
        .astype(np.float32) * 0.1
    b = rng.standard_normal(n).astype(np.float32) * 0.1
    want = fm_ref.fused_matmul_ref(x, w, b, None, None, fn=fn, fast=False,
                                   w_layout=w_layout, attrs={})
    got = fused_matmul(x, w, b, fn=fn, w_layout=w_layout, use_pallas=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-5, atol=2e-5)


def test_fused_matmul_affine_epilogue(rng):
    """Folded-BN scale/offset applied in the kernel epilogue (paper P2+P3)."""
    x = rng.standard_normal((16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 24)).astype(np.float32) * 0.1
    b = rng.standard_normal(24).astype(np.float32)
    s = rng.uniform(0.5, 1.5, 24).astype(np.float32)
    o = rng.standard_normal(24).astype(np.float32)
    want = fm_ref.fused_matmul_ref(x, w, b, s, o, fn="relu", fast=False,
                                   w_layout="io", attrs={})
    got = fused_matmul(x, w, b, s, o, fn="relu", use_pallas=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-5, atol=2e-5)


def test_fused_matmul_higher_rank(rng):
    x = rng.standard_normal((2, 3, 8)).astype(np.float32)
    w = rng.standard_normal((8, 4)).astype(np.float32)
    got = fused_matmul(x, w, None, use_pallas=True)
    want = np.einsum("abk,kn->abn", x, w)
    np.testing.assert_allclose(want, np.asarray(got), rtol=2e-5, atol=2e-5)


def test_fused_matmul_bf16_operands_match_upcast(rng):
    """bf16 operands stay bf16 in the kernel (half the VMEM bytes, as
    tiles.block_vmem_bytes models) and match the f32-upcast reference
    exactly — bf16 products are exact in the f32 accumulator."""
    import jax.numpy as jnp

    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.1, jnp.bfloat16)
    b = rng.standard_normal(32).astype(np.float32)
    got = fused_matmul(x, w, b, fn="relu", use_pallas=True)
    want = fm_ref.fused_matmul_ref(
        np.asarray(x, np.float32), np.asarray(w, np.float32), b, None, None,
        fn="relu", fast=False, w_layout="io", attrs={})
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# fast activations (paper §3.4)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fn", ["exp", "tanh", "sigmoid"])
@pytest.mark.parametrize("shape", [(16,), (4, 33), (2, 3, 5)])
def test_fast_act_kernel_matches_ref(fn, shape, rng):
    x = rng.standard_normal(shape).astype(np.float32) * 3
    want = fa_ref.FAST[fn](x)
    got = fast_act(jnp.asarray(x), fn, use_pallas=True)
    # identical math; one-ULP drift allowed (FMA contraction differs
    # between the interpret-mode kernel and the jnp oracle)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=5e-5, atol=1e-6)


def test_schraudolph_accuracy_envelope(rng):
    """Paper cites ~4% max relative error for the exp bit-trick."""
    x = rng.uniform(-10, 10, 4096).astype(np.float32)
    approx = np.asarray(fa_ref.schraudolph_exp(x))
    exact = np.exp(x)
    rel = np.abs(approx - exact) / exact
    assert rel.max() < 0.05


def test_cf_tanh_accuracy():
    x = np.linspace(-6, 6, 4001, dtype=np.float32)
    approx = np.asarray(fa_ref.cf_tanh(x))
    exact = np.tanh(x)
    assert np.max(np.abs(approx - exact)) < 2e-3
    assert np.all(np.abs(approx) <= 1.0 + 1e-6)


def test_fast_softmax_normalized(rng):
    x = rng.standard_normal((8, 64)).astype(np.float32) * 5
    y = np.asarray(fast_softmax(jnp.asarray(x)))
    np.testing.assert_allclose(y.sum(-1), 1.0, atol=1e-3)
    exact = np.asarray(jax.nn.softmax(jnp.asarray(x), axis=-1))
    assert np.max(np.abs(y - exact)) < 0.02


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,hkv,d,s", [(2, 4, 2, 16, 64), (1, 8, 1, 32, 100),
                                         (3, 6, 6, 8, 48)])
def test_decode_attention_sweep(b, h, hkv, d, s, rng):
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    lengths = np.asarray([s - i * 7 for i in range(b)], np.int32).clip(1)
    want = da_ref.decode_attention_ref(q, k, v, jnp.asarray(lengths))
    got = decode_attention(q, k, v, jnp.asarray(lengths), use_pallas=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_masks_beyond_length(rng):
    """Rows past `length` must not affect the output."""
    b, h, hkv, d, s = 1, 2, 1, 8, 32
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    lengths = jnp.asarray([10], jnp.int32)
    out1 = decode_attention(q, k, v, lengths, use_pallas=True)
    k2, v2 = k.copy(), v.copy()
    k2[:, 10:], v2[:, 10:] = 99.0, -99.0
    out2 = decode_attention(q, k2, v2, lengths, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)
