import os
import sys

# Tests run on the REAL single CPU device (the 512-device override is
# only for the dry-run, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
