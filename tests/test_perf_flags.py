"""§Perf optimization flags: numerical equivalence with the faithful
baseline (the optimized program must compute the same function)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import get_model


def _f32(arch, **kw):
    return dataclasses.replace(get_config(arch, smoke=True),
                               dtype="float32", **kw)


def _logits(cfg, params, toks):
    m = get_model(cfg)
    l, _ = m.forward(params, {"tokens": toks})
    return l


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma3-27b",
                                  "mixtral-8x22b"])
def test_causal_skip_bit_exact(arch):
    cfg0 = _f32(arch)
    cfg1 = _f32(arch, causal_skip=True)
    params = get_model(cfg0).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, cfg0.vocab)
    np.testing.assert_array_equal(
        np.asarray(_logits(cfg0, params, toks)),
        np.asarray(_logits(cfg1, params, toks)))


def test_scatter_cache_matches_where():
    cfg0 = _f32("qwen2.5-14b")
    cfg1 = _f32("qwen2.5-14b", cache_update="scatter")
    m0, m1 = get_model(cfg0), get_model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, cfg0.vocab)
    caches = [m.init_cache(2, 32) for m in (m0, m1)]
    outs = []
    for m, c in zip((m0, m1), caches):
        lg, c = m.prefill(params, {"tokens": toks[:, :16]}, c)
        lg, c = m.decode_step(params, c, toks[:, 16:17])
        lg, c = m.decode_step(params, c, toks[:, 17:18])
        outs.append(np.asarray(lg))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_bf16_attn_close():
    cfg0 = _f32("deepseek-7b")
    cfg1 = _f32("deepseek-7b", attn_compute_dtype="bfloat16")
    params = get_model(cfg0).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg0.vocab)
    l0 = np.asarray(_logits(cfg0, params, toks))
    l1 = np.asarray(_logits(cfg1, params, toks))
    scale = np.abs(l0).max()
    assert np.abs(l0 - l1).max() < 0.01 * max(scale, 1.0)


def test_tp_psum_noop_without_mesh():
    """tp_psum falls back to plain einsum on a single device."""
    cfg0 = _f32("qwen2.5-14b")
    cfg1 = _f32("qwen2.5-14b", tp_psum=True)
    params = get_model(cfg0).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg0.vocab)
    np.testing.assert_array_equal(
        np.asarray(_logits(cfg0, params, toks)),
        np.asarray(_logits(cfg1, params, toks)))


def test_cast_params_training_close():
    from repro.training import OptConfig, TrainConfig, init_state
    from repro.training.train import make_train_step
    cfg = _f32("deepseek-7b")
    m = get_model(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                     cfg.vocab),
    }
    mk = lambda cast: TrainConfig(
        opt=OptConfig(lr=1e-3, total_steps=10, warmup_steps=0),
        cast_params=cast)
    s0 = init_state(m, jax.random.PRNGKey(0))
    s1 = init_state(m, jax.random.PRNGKey(0))
    _, m0 = make_train_step(m, mk(False))(s0, batch)
    _, m1 = make_train_step(m, mk(True))(s1, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 0.02
    assert np.isfinite(float(m1["grad_norm"]))
