"""Graph-level decision tuning (repro.autotune.decisions).

Covers the PR-7 tentpole invariants:
* the graph-region digest is invariant to node naming and insertion
  order, and changes on any shape/dtype/structure edit;
* the pass hooks (tune.fuse / tune.layout / pipeline variants) actually
  steer fuse_activation, optimize_layout and the pipeline;
* tuned decisions persist in the tactic cache and replay cross-process
  with autotune="cached" — bit-identical winners, zero measurement;
* autotune="off" never writes a decision attr (bit-identity guard).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro import CompileOptions
from repro.autotune import (enumerate_sites, extract_region, region_digest,
                            tune_graph_decisions)
from repro.autotune.cache import TacticCache
from repro.core import ModelBuilder
from repro.core.graph import Graph
from repro.core.passes import PassManager, run_pipeline
from repro.core.passes.fuse_activation import TUNE_FUSE_ATTR
from repro.core.passes.layout import TUNE_LAYOUT_ATTR
from repro.core.passes.manager import pipeline_candidates

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp(in_dim=16, hidden=32, out=8):
    mb = ModelBuilder().seed(0)
    x = mb.input((in_dim,))
    h = mb.dense(x, hidden, activation="relu")
    o = mb.dense(h, out)
    return mb.build([o])


def _dense_act_graph(names=("d", "a"), tensors=("t0", "t1"), in_dim=16,
                     out_dim=32, dtype="float32", order="da"):
    """Hand-built dense→relu graph with controllable names/order."""
    rng = np.random.default_rng(0)
    g = Graph()
    g.add_input("x", (in_dim,), dtype)
    g.add_param("w", rng.standard_normal((in_dim, out_dim)))
    g.add_node("dense", names[0], ["x"], output=tensors[0],
               params={"kernel": "w"})
    g.add_node("activation", names[1], [tensors[0]], output=tensors[1],
               attrs={"fn": "relu"})
    g.set_outputs([tensors[1]])
    return g


# ---------------------------------------------------------------------------
# region digest
# ---------------------------------------------------------------------------
def test_digest_invariant_to_node_and_tensor_names():
    a = _dense_act_graph(names=("d", "a"), tensors=("t0", "t1"))
    b = _dense_act_graph(names=("layer7", "omega"), tensors=("u", "v"))
    assert (region_digest(a, [n.name for n in a.nodes])
            == region_digest(b, [n.name for n in b.nodes]))


def test_digest_invariant_to_insertion_order():
    """Two independent dense heads built in opposite order digest the
    same — the digest sorts per-node content hashes, it never sees
    list position."""
    rng = np.random.default_rng(0)

    def build(flip):
        g = Graph()
        g.add_input("x", (16,))
        g.add_param("w1", rng.standard_normal((16, 32)))
        g.add_param("w2", rng.standard_normal((16, 8)))
        heads = [("h1", "w1"), ("h2", "w2")]
        for name, w in (reversed(heads) if flip else heads):
            g.add_node("dense", name, ["x"], params={"kernel": w})
        g.set_outputs([n.output for n in g.nodes])
        return g

    a, b = build(False), build(True)
    assert (region_digest(a, ["h1", "h2"])
            == region_digest(b, ["h1", "h2"]))


@pytest.mark.parametrize("edit", ["shape", "dtype", "structure"])
def test_digest_changes_on_semantic_edits(edit):
    base = _dense_act_graph()
    if edit == "shape":
        other = _dense_act_graph(in_dim=24)
    elif edit == "dtype":
        other = _dense_act_graph(dtype="bfloat16")
    else:   # structure: different activation fn
        other = _dense_act_graph()
        other.nodes[1].attrs["fn"] = "tanh"
    assert (region_digest(base, [n.name for n in base.nodes])
            != region_digest(other, [n.name for n in other.nodes]))


def test_digest_ignores_tune_attrs():
    """Decision attrs must not feed back into the site identity, or a
    tuned graph would never hit the entries measured for it."""
    a = _dense_act_graph()
    b = _dense_act_graph()
    b.nodes[0].attrs[TUNE_LAYOUT_ATTR] = "oi"
    b.nodes[1].attrs[TUNE_FUSE_ATTR] = False
    assert (region_digest(a, [n.name for n in a.nodes])
            == region_digest(b, [n.name for n in b.nodes]))


def test_digest_unknown_node_raises():
    g = _dense_act_graph()
    with pytest.raises(KeyError):
        region_digest(g, ["nope"])


# ---------------------------------------------------------------------------
# site enumeration + region extraction
# ---------------------------------------------------------------------------
def test_enumerate_sites_shapes():
    g = _mlp()
    sites = enumerate_sites(g)
    kinds = [s.kind for s in sites]
    assert kinds.count("layout") == 2        # two dense nodes
    assert kinds.count("fusion") == 1        # one legal dense→relu site
    assert kinds.count("pipeline") == 1
    assert kinds[-1] == "pipeline"           # cheapest sites first
    pipeline_site = sites[-1]
    assert set(pipeline_site.choices) == set(pipeline_candidates())


def test_enumerate_sites_explicit_passes_pins_pipeline():
    g = _mlp()
    sites = enumerate_sites(g, passes=("canonicalize",))
    assert all(s.kind != "pipeline" for s in sites)


def test_extract_region_is_self_contained():
    g = _mlp()
    fusion = [s for s in enumerate_sites(g) if s.kind == "fusion"][0]
    mini = extract_region(g, fusion.region)
    assert len(mini.nodes) == 2
    mini.infer_shapes()          # validates
    # the mini-graph digest matches the site's: entries transfer
    assert (region_digest(mini, [n.name for n in mini.nodes])
            == fusion.digest)


# ---------------------------------------------------------------------------
# pass hooks
# ---------------------------------------------------------------------------
def test_tune_fuse_attr_blocks_fusion():
    g = _dense_act_graph()
    fused, _ = run_pipeline(g)
    assert len(fused.nodes) == 1             # heuristic fuses

    g2 = _dense_act_graph()
    g2.nodes[1].attrs[TUNE_FUSE_ATTR] = False
    unfused, _ = run_pipeline(g2)
    assert len(unfused.nodes) == 2           # hook keeps it unfused
    assert unfused.nodes[0].epilogue is None


def test_tune_layout_attr_overrides_heuristic():
    # rows=1 < SUBLANE_ALIGN → heuristic transposes to "oi"; the tuned
    # attr pins "io" and must win.
    g = _dense_act_graph()
    g.nodes[0].attrs[TUNE_LAYOUT_ATTR] = "io"
    out, _ = run_pipeline(g)
    dense = [n for n in out.nodes if n.op == "dense"][0]
    assert dense.attrs["kernel_layout"] == "io"

    g2 = _dense_act_graph()
    out2, _ = run_pipeline(g2)
    dense2 = [n for n in out2.nodes if n.op == "dense"][0]
    assert dense2.attrs["kernel_layout"] == "oi"    # heuristic baseline


def test_pipeline_candidates_contract():
    variants = pipeline_candidates()
    assert list(variants)[0] == "default"
    assert variants["default"] == PassManager.default().pipeline
    assert not any("fuse_activation" in p for p in variants["no_fusion"])
    assert "optimize_layout" not in variants["no_layout"]


# ---------------------------------------------------------------------------
# tuning + cross-process cached replay
# ---------------------------------------------------------------------------
def test_tune_graph_decisions_cached_replays(tmp_path):
    g = _mlp()
    cache = TacticCache(os.path.join(str(tmp_path), "tactics"))
    _, pipe1, rep1 = tune_graph_decisions(
        g, target="pallas", precision="exact", passes=None,
        mode="full", budget_ms=20_000, cache=cache, batch_size=1)
    assert all(r["source"] == "measured" for r in rep1["sites"])
    assert rep1["entries"]

    _, pipe2, rep2 = tune_graph_decisions(
        g, target="pallas", precision="exact", passes=None,
        mode="cached", budget_ms=None, cache=cache, batch_size=1)
    assert pipe1 == pipe2
    assert ([(r["kind"], r["node"], r["winner"]) for r in rep1["sites"]]
            == [(r["kind"], r["node"], r["winner"]) for r in rep2["sites"]])
    assert all(r["source"] == "cached" for r in rep2["sites"])
    assert rep2["spent_ms"] < 50            # cached mode never measures


def test_cached_mode_without_entries_keeps_heuristics(tmp_path):
    g = _mlp()
    cache = TacticCache(os.path.join(str(tmp_path), "tactics"))
    decided, pipe, rep = tune_graph_decisions(
        g, target="pallas", precision="exact", passes=None,
        mode="cached", budget_ms=None, cache=cache, batch_size=1)
    assert pipe is None
    assert all(r["source"] == "heuristic" and r["winner"] is None
               for r in rep["sites"])
    # no decision attr was written: the decided graph is bit-identical
    assert decided.structure_hash() == g.structure_hash()


def test_autotune_off_writes_no_decision_attrs(tmp_path):
    g = _mlp()
    exe = repro.compile(g, CompileOptions(target="pallas", autotune="off",
                                          cache_dir=str(tmp_path)))
    exe.ensure_compiled(1)
    for node in exe.graph.nodes:
        assert not any(k.startswith("tune.") for k in node.attrs)
    assert exe.cost_summary().get("graph_decisions") is None


def test_decisions_replay_cross_process(tmp_path):
    """Process 1 measures graph decisions; process 2 (autotune="cached")
    resolves the same winners from the tactic cache — bit-identically,
    with zero measurement spend."""
    prog = """
import json, sys
sys.path.insert(0, {src!r})
import repro
from repro.api.options import CompileOptions
from repro.core import ModelBuilder
mb = ModelBuilder().seed(0)
x = mb.input((16,))
h = mb.dense(x, 32, activation="relu")
out = mb.dense(h, 8)
g = mb.build([out])
exe = repro.compile(g, CompileOptions(target="pallas", autotune={mode!r},
                                      autotune_budget_ms=20000,
                                      cache_dir={cache!r}))
exe.ensure_compiled(batch_size=1)
rep = exe.cost_summary()["graph_decisions"]
print(json.dumps({{"sites": [(r["kind"], r["node"], r["winner"])
                             for r in rep["sites"]],
                  "sources": sorted({{r["source"] for r in rep["sites"]}}),
                  "spent_ms": rep["spent_ms"]}}))
"""
    src = os.path.join(REPO, "src")
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = []
    for mode in ("full", "cached"):
        r = subprocess.run(
            [sys.executable, "-c",
             prog.format(src=src, cache=str(tmp_path), mode=mode)],
            capture_output=True, text=True, env=env, check=True)
        out.append(json.loads(r.stdout.strip().splitlines()[-1]))
    first, second = out
    assert first["sites"] == second["sites"]
    assert first["sources"] == ["measured"]
    assert second["sources"] == ["cached"]
    assert second["spent_ms"] < 50
