"""The trip-count-aware HLO analyzer, validated on a program with
analytically known FLOPs (matmul under a scan)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def test_scan_matmul_flops_counted_with_trip_count():
    L, d = 12, 32

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jnp.zeros((L, d, d))
    x = jnp.zeros((4, d))
    lowered = jax.jit(f).lower(ws, x)
    flops, unresolved = H.flops_from_pre(lowered.as_text("hlo"))
    want = L * 2 * 4 * d * d
    assert unresolved == 0
    assert abs(flops - want) / want < 0.01, (flops, want)


def test_nested_scan_multiplies():
    Lo, Li, d = 5, 7, 16

    def f(x):
        def outer(x, _):
            def inner(x, _):
                return x @ jnp.eye(d), None
            x, _ = jax.lax.scan(inner, x, None, length=Li)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=Lo)
        return x

    x = jnp.zeros((2, d))
    flops, unresolved = H.flops_from_pre(jax.jit(f).lower(x).as_text("hlo"))
    want = Lo * Li * 2 * 2 * d * d
    assert unresolved == 0
    assert abs(flops - want) / want < 0.01, (flops, want)


def test_unrolled_matmul_exact():
    a = jnp.zeros((8, 24))
    b = jnp.zeros((24, 40))
    flops, _ = H.flops_from_pre(
        jax.jit(lambda a, b: a @ b).lower(a, b).as_text("hlo"))
    assert flops == 2 * 8 * 24 * 40


def test_parse_hlo_finds_computations():
    def f(x):
        return jax.lax.scan(lambda c, _: (c * 2, None), x, None,
                            length=3)[0]
    text = jax.jit(f).lower(jnp.ones(4)).as_text("hlo")
    comps = H.parse_hlo(text)
    assert len(comps) >= 2     # entry + loop body/cond
    mult, unresolved = H._multipliers(comps)
    assert unresolved == 0
    assert max(mult.values()) == 3.0


def test_collective_parse_from_sharded_program():
    """An explicitly psum-ing shard_map program on 1 device still emits
    an all-reduce in the compiled HLO."""
    import jax
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("x",))

    def f(a):
        return jax.lax.psum(a, "x")

    sm = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P())
    compiled = jax.jit(sm).lower(jnp.ones((4,))).compile()
    hbm, coll, unresolved = H.bytes_from_post(compiled.as_text())
    assert hbm > 0
