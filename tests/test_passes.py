"""Compiler passes: each validated against the SimpleNN oracle, plus
memory-plan invariants (property-based)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis: skip only the
    from _hypothesis_stub import given, settings, st  # property tests

import jax.numpy as jnp

from repro.core import CompiledModel, ModelBuilder, SimpleNN
from repro.core.passes import run_pipeline, plan_memory
from repro.core.simple import random_params_like


def build_cnn(seed=0, act="relu"):
    mb = ModelBuilder().seed(seed)
    x = mb.input((16, 16, 3))
    h = mb.zero_pad(x, ((1, 1), (1, 1)))
    h = mb.conv2d(h, 8, (3, 3), padding="valid")
    h = mb.batchnorm(h)
    h = mb.activation(h, act)
    h = mb.conv2d(h, 8, (3, 3), activation=act)
    h = mb.batchnorm(h)
    h = mb.maxpool(h)
    skip = h
    h = mb.conv2d(h, 8, (3, 3))
    h = mb.add(h, skip)
    h = mb.global_avg_pool(h)
    h = mb.dense(h, 10)
    h = mb.softmax(h)
    return mb.build([h]), h


@pytest.mark.parametrize("act", ["relu", "tanh", "sigmoid", "relu6"])
def test_pipeline_matches_oracle(act, rng):
    g, out = build_cnn(seed=1, act=act)
    inp = rng.standard_normal((2, 16, 16, 3)).astype(np.float32)
    want = SimpleNN(g)(input=inp)[out]
    got = CompiledModel(g).apply(input=inp)[out]
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-5, atol=2e-6)


def test_each_pass_individually(rng):
    g, out = build_cnn(seed=2)
    inp = rng.standard_normal((2, 16, 16, 3)).astype(np.float32)
    want = np.asarray(SimpleNN(g)(input=inp)[out])
    for passes in [(), ("canonicalize",), ("canonicalize", "fuse_pad"),
                   ("canonicalize", "fuse_activation"),
                   ("canonicalize", "fuse_activation", "fold_batchnorm"),
                   ("canonicalize", "fold_constants"),
                   ("canonicalize", "optimize_layout")]:
        got = CompiledModel(g, passes=passes).apply(input=inp)[out]
        np.testing.assert_allclose(want, np.asarray(got),
                                   rtol=2e-5, atol=2e-6,
                                   err_msg=f"passes={passes}")


def test_bn_folding_removes_bn_nodes():
    g, _ = build_cnn(seed=3)
    opt, report = run_pipeline(g)
    assert not any(n.op == "batchnorm" for n in opt.nodes)
    folded = [p for p in report["passes"] if p["pass"] == "fold_batchnorm"]
    assert folded and folded[0]["nodes_after"] < folded[0]["nodes_before"]


def test_activation_fusion_sets_epilogues():
    g, _ = build_cnn(seed=4)
    opt, _ = run_pipeline(g)
    assert any(n.epilogue not in (None, "linear") for n in opt.nodes)
    # lone softmax stays a separate node (two-pass, not fusable)
    assert any(n.op in ("softmax", "activation") and
               n.attrs.get("fn", n.op) == "softmax" for n in opt.nodes)


def test_fast_precision_close():
    g, out = build_cnn(seed=5, act="sigmoid")
    inp = np.random.default_rng(5).standard_normal(
        (2, 16, 16, 3)).astype(np.float32)
    want = np.asarray(SimpleNN(g)(input=inp)[out])
    got = np.asarray(CompiledModel(g, precision="fast").apply(input=inp)[out])
    assert np.max(np.abs(want - got)) < 0.05   # paper: approx trade-off


# ---------------------------------------------------------------------------
# memory planner invariants
# ---------------------------------------------------------------------------
def test_memory_plan_no_lifetime_overlap():
    g, _ = build_cnn(seed=6)
    opt, _ = run_pipeline(g)
    plan = plan_memory(opt)
    order = opt.toposort()
    produced = {n.output: i for i, n in enumerate(order)}
    last_use = dict(produced)
    for i, n in enumerate(order):
        for t in n.inputs:
            last_use[t] = i
    for t in opt.outputs:
        last_use[t] = len(order)
    asg = plan.assignments
    names = list(asg)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            if a not in produced or b not in produced:
                continue
            # in-place aliases intentionally share memory with a tensor
            # whose lifetime ends exactly where theirs begins
            if asg[a].inplace_of == b or asg[b].inplace_of == a:
                continue
            lo = max(produced[a], produced[b])
            hi = min(last_use.get(a, 0), last_use.get(b, 0))
            if lo < hi:   # strictly overlapping lifetimes
                a0, a1 = asg[a].offset, asg[a].offset + asg[a].nbytes
                b0, b1 = asg[b].offset, asg[b].offset + asg[b].nbytes
                assert a1 <= b0 or b1 <= a0, (a, b)


def test_memory_plan_saves_vs_naive():
    g, _ = build_cnn(seed=7)
    opt, report = run_pipeline(g)
    stats = report["memory_plan"]
    assert stats["arena_bytes"] <= stats["naive_bytes"]


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=6), min_size=2,
                max_size=6),
       st.integers(min_value=0, max_value=10_000))
def test_memory_plan_random_chains(widths, seed):
    """Random sequential CNNs: the plan must always be valid and no
    larger than naive."""
    mb = ModelBuilder().seed(seed)
    x = mb.input((8, 8, widths[0]))
    h = x
    for w in widths:
        h = mb.conv2d(h, w, (3, 3), activation="relu")
    g = mb.build([h])
    opt, report = run_pipeline(g)
    stats = report["memory_plan"]
    assert 0 < stats["arena_bytes"] <= stats["naive_bytes"]
