"""Fault tolerance: watchdog, restart driver, data-pipeline determinism
(the skip-on-restart property)."""

import time

import numpy as np
import pytest

from repro.data import DataConfig, SyntheticTokens
from repro.distributed import StragglerWatchdog, run_with_restarts


def test_watchdog_fires_on_slow_step():
    fired = []
    wd = StragglerWatchdog(0.05, on_timeout=lambda s, el: fired.append(s))
    with wd.step(7):
        time.sleep(0.15)
    assert fired == [7]
    assert wd.timeouts and wd.timeouts[0][0] == 7


def test_watchdog_quiet_on_fast_step():
    fired = []
    wd = StragglerWatchdog(0.5, on_timeout=lambda s, el: fired.append(s))
    with wd.step(1):
        pass
    time.sleep(0.05)
    assert fired == []


def test_run_with_restarts_recovers():
    """A step that crashes twice; the driver restarts from the last
    'checkpointed' step and completes."""
    completed = []
    saved = {"step": 0}
    crashes = {"left": 2}

    def make_step():
        def step(i):
            if crashes["left"] and i == 5:
                crashes["left"] -= 1
                raise RuntimeError("simulated node failure")
            completed.append(i)
            saved["step"] = i + 1
        return step

    restarts = run_with_restarts(make_step, n_steps=8, max_restarts=3,
                                 start_step=lambda: saved["step"])
    assert restarts == 2
    assert completed[-1] == 7
    # no step skipped after final restart
    assert sorted(set(completed)) == list(range(8))


def test_run_with_restarts_gives_up():
    def make_step():
        def step(i):
            raise RuntimeError("permafail")
        return step
    with pytest.raises(RuntimeError):
        run_with_restarts(make_step, n_steps=2, max_restarts=1)


# ---------------------------------------------------------------------------
# data pipeline determinism == restart safety
# ---------------------------------------------------------------------------
def test_data_deterministic_per_step():
    d = SyntheticTokens(DataConfig(vocab=100, global_batch=4, seq_len=16))
    a = d.batch(7)
    b = d.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticTokens(DataConfig(vocab=100, global_batch=2, seq_len=16))
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_slices_partition_global_batch():
    d = SyntheticTokens(DataConfig(vocab=100, global_batch=8, seq_len=8))
    full = d.batch(3)
    parts = [d.host_slice(3, h, 4) for h in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], glued)
