"""Checkpointer: roundtrip, atomicity, GC, resume, elastic restore."""

import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer


@pytest.fixture()
def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def _equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, tree, blocking=True)
    out = ck.restore(3, tree)
    _equal(tree, out)
    assert jax.tree.leaves(out)[0].dtype == jnp.float32


def test_async_save_then_wait(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree)
    ck.wait()
    assert ck.all_steps() == [1]


def test_keep_n_gc(tmp_path, tree):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.all_steps() == [3, 4]


def test_tmp_dirs_ignored_and_cleaned(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    # a crashed save: tmp dir without manifest
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert ck.all_steps() == []
    ck.save(10, tree, blocking=True)
    assert ck.latest_step() == 10
    assert not (tmp_path / "step_000000009.tmp").exists()


def test_restore_shape_mismatch_raises(tmp_path, tree):
    ck = Checkpointer(str(tmp_path))
    ck.save(0, tree, blocking=True)
    bad = dict(tree)
    bad["a"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        ck.restore(0, bad)


def test_elastic_restore_with_shardings(tmp_path, tree):
    """Restore onto the current (1-device) mesh with NamedShardings —
    the restart-on-different-mesh path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree, blocking=True)
    mesh = make_host_mesh()
    shardings = jax.tree.map(
        lambda l: NamedSharding(mesh, P()), tree)
    out = ck.restore(5, tree, shardings=shardings)
    _equal(tree, out)
    assert all(x.sharding.mesh.shape == mesh.shape
               for x in jax.tree.leaves(out)
               if hasattr(x, "sharding"))


def test_train_resume_continues_step_count(tmp_path):
    """Full driver-level resume: run 6 steps, kill, resume to 10."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    args = ["--arch", "minicpm-2b", "--smoke", "--batch", "2",
            "--seq", "16", "--ckpt", ck, "--ckpt-every", "3",
            "--log-every", "100"]
    assert main(args + ["--steps", "6"]) == 0
    assert main(args + ["--steps", "10"]) == 0
    steps = Checkpointer(ck).all_steps()
    assert 9 in steps
