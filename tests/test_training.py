"""Training substrate: optimizer math, schedules, microbatching
equivalence, gradient compression, loss-goes-down."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import get_model
from repro.training import (OptConfig, TrainConfig, adamw_init,
                            adamw_update, init_state,
                            make_jitted_train_step, schedule_lr)
from repro.training.train import make_train_step


def test_adamw_against_manual():
    oc = OptConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                   grad_clip=1e9, schedule="constant", warmup_steps=0)
    p = {"w": jnp.asarray([[1.0, 2.0]])}
    g = {"w": jnp.asarray([[0.5, -0.5]])}
    opt = adamw_init(p)
    new_p, new_opt, _ = adamw_update(oc, p, g, opt)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.99)
    want = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_opt["step"]) == 1


def test_grad_clipping():
    oc = OptConfig(lr=0.0, grad_clip=1.0, schedule="constant",
                   warmup_steps=0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([3.0, 4.0, 0.0])}   # norm 5 -> scaled by 1/5
    _, _, metrics = adamw_update(oc, p, g, adamw_init(p))
    assert abs(float(metrics["grad_norm"]) - 5.0) < 1e-5


@pytest.mark.parametrize("schedule,checks", [
    ("cosine", [(0, 0.0), (50, None), (10_000, 1e-4 * 0.1)]),
    ("wsd", [(0, 0.0), (5_000, 1e-4), (10_000, 1e-4 * 0.1)]),
])
def test_schedules(schedule, checks):
    oc = OptConfig(lr=1e-4, schedule=schedule, warmup_steps=100,
                   total_steps=10_000)
    for step, want in checks:
        got = float(schedule_lr(oc, jnp.int32(step)))
        if want is not None:
            assert abs(got - want) < 1e-6, (schedule, step, got)
    # WSD: flat in the stable phase
    if schedule == "wsd":
        a = float(schedule_lr(oc, jnp.int32(2000)))
        b = float(schedule_lr(oc, jnp.int32(7000)))
        assert abs(a - b) < 1e-9 and abs(a - 1e-4) < 1e-9


def test_microbatching_equivalent_to_single():
    import dataclasses
    # f32 activations: in bf16, near-zero grads flip sign across the
    # different reduction order and AdamW turns that into ±lr updates.
    cfg = dataclasses.replace(get_config("deepseek-7b", smoke=True),
                              dtype="float32")
    m = get_model(cfg)
    # eps=1e-6 (not the 1e-8 default): microbatch grads are accumulated
    # in f32, but mean-of-4-sums vs one 8-row mean still differ by
    # ~3e-8 in order-of-accumulation noise.  AdamW's ĝ/(√v̂+ε) treats
    # any |g| ≫ ε as a full ±1 direction, so at ε=1e-8 that noise on
    # near-zero gradients legitimately flips whole ±lr updates.  ε=1e-6
    # keeps every real gradient's update intact while not asserting on
    # the direction of pure float-associativity noise; the grad_norm
    # check below pins the accumulated gradients themselves tightly.
    opt = lambda: OptConfig(lr=1e-3, eps=1e-6, total_steps=10,
                            warmup_steps=0)
    tc1 = TrainConfig(opt=opt(), microbatches=1)
    tc4 = TrainConfig(opt=opt(), microbatches=4)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                     cfg.vocab),
    }
    s1 = init_state(m, jax.random.PRNGKey(0))
    s4 = init_state(m, jax.random.PRNGKey(0))
    s1, m1 = make_train_step(m, tc1)(s1, batch)
    s4, m4 = make_train_step(m, tc4)(s4, batch)
    # same data, same update (up to accumulation-order float noise)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    # the averaged accumulated gradient equals the full-batch gradient
    # (a /n scaling bug would 4x this norm)
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) < 1e-5
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_grad_compression_close_to_exact():
    cfg = get_config("deepseek-7b", smoke=True)
    m = get_model(cfg)
    mk = lambda comp: TrainConfig(
        opt=OptConfig(lr=1e-3, total_steps=10, warmup_steps=0),
        microbatches=4, compress_grads=comp)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                     cfg.vocab),
    }
    se = init_state(m, jax.random.PRNGKey(0))
    sc = init_state(m, jax.random.PRNGKey(0))
    se, _ = make_train_step(m, mk(False))(se, batch)
    sc, _ = make_train_step(m, mk(True))(sc, batch)
    deltas = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(se["params"]),
                              jax.tree.leaves(sc["params"]))]
    assert max(deltas) < 5e-3   # bf16 accumulator with error feedback


def test_loss_goes_down_100m_scale_proxy():
    """A few steps of the end-to-end jitted path on synthetic data."""
    cfg = get_config("minicpm-2b", smoke=True)
    m = get_model(cfg)
    tc = TrainConfig(opt=OptConfig(lr=3e-3, total_steps=40,
                                   warmup_steps=2))
    state = init_state(m, jax.random.PRNGKey(0))
    step = make_jitted_train_step(m, tc, mesh=None, donate=False)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, global_batch=4,
                                      seq_len=48))
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[-4:]) < losses[0] - 0.5
