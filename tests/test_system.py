"""End-to-end behaviour: the paper's complete flow (build → compile →
infer) plus save/load, the compile-time measurement, and property-based
checks on the compiled-vs-oracle invariant."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis: skip only the
    from _hypothesis_stub import given, settings, st  # property tests

import jax.numpy as jnp

from repro.core import (CompiledModel, ModelBuilder, SimpleNN, load_model,
                        save_model)


def ball_classifier(seed=0):
    """The shape of B-Human's ball classifier (paper Table 1, C-BH)."""
    mb = ModelBuilder().seed(seed)
    x = mb.input((32, 32, 1))
    h = mb.conv2d(x, 8, (3, 3), strides=(2, 2), activation="relu")
    h = mb.batchnorm(h)
    h = mb.conv2d(h, 16, (3, 3), strides=(2, 2), activation="relu")
    h = mb.conv2d(h, 32, (3, 3), strides=(2, 2), activation="relu")
    h = mb.flatten(h)
    h = mb.dense(h, 64, activation="relu")
    h = mb.dense(h, 2)
    h = mb.softmax(h)
    return mb.build([h]), h


def test_full_flow_compiled_equals_oracle(rng):
    g, out = ball_classifier()
    x = rng.standard_normal((4, 32, 32, 1)).astype(np.float32)
    want = np.asarray(SimpleNN(g)(input=x)[out])
    cm = CompiledModel(g)
    got = np.asarray(cm.apply(input=x)[out])
    np.testing.assert_allclose(want, got, rtol=2e-5, atol=1e-6)
    assert cm.compile_time is not None and cm.compile_time > 0


def test_save_load_roundtrip(tmp_path, rng):
    g, out = ball_classifier(seed=3)
    path = str(tmp_path / "model.npz")
    save_model(g, path)
    g2 = load_model(path)
    x = rng.standard_normal((2, 32, 32, 1)).astype(np.float32)
    a = np.asarray(SimpleNN(g)(input=x)[out])
    b = np.asarray(SimpleNN(g2)(input=x)[out])
    np.testing.assert_allclose(a, b, rtol=1e-6)
    assert g.structure_hash() == g2.structure_hash()


def test_compile_cache_reused():
    g, _ = ball_classifier(seed=4)
    cm = CompiledModel(g)
    f1 = cm.compile(batch_size=2)
    t1 = cm.compile_time
    f2 = cm.compile(batch_size=2)
    assert f1 is f2 and cm.compile_time == t1
    f3 = cm.compile(batch_size=3)          # new specialization
    assert f3 is not f1


def test_framework_mode_shares_program_across_weights(rng):
    g, out = ball_classifier(seed=5)
    x = rng.standard_normal((1, 32, 32, 1)).astype(np.float32)
    cm = CompiledModel(g, embed_weights=False)
    got = np.asarray(cm.apply(input=x)[out])
    want = np.asarray(SimpleNN(g)(input=x)[out])
    np.testing.assert_allclose(want, got, rtol=2e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       batch=st.integers(1, 3),
       act=st.sampled_from(["relu", "tanh", "sigmoid", "elu"]))
def test_property_compiled_equals_oracle(seed, batch, act):
    """Property: for random small CNNs, the optimized compiled program
    computes the same function as the unoptimized oracle."""
    rng = np.random.default_rng(seed)
    mb = ModelBuilder().seed(seed)
    x = mb.input((8, 8, 2))
    h = mb.conv2d(x, 4, (3, 3), activation=act)
    h = mb.batchnorm(h)
    if seed % 2:
        h = mb.zero_pad(h)
        h = mb.conv2d(h, 4, (3, 3), padding="valid")
        h = mb.activation(h, act)
    h = mb.global_avg_pool(h)
    h = mb.dense(h, 3)
    g = mb.build([h])
    inp = rng.standard_normal((batch, 8, 8, 2)).astype(np.float32)
    want = np.asarray(SimpleNN(g)(input=inp)[h])
    got = np.asarray(CompiledModel(g).apply(input=inp)[h])
    np.testing.assert_allclose(want, got, rtol=5e-5, atol=5e-6)
