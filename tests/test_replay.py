"""Capture bundles + python -m repro.replay (repro.api.capture,
repro.replay).

The contract under test: a compile with ``CompileOptions(capture=...)``
writes a self-contained bundle, and ``python -m repro.replay <bundle>``
in a *fresh process* reproduces the recorded selections and outputs
bit-identically (exit 0); any tampering fails the manifest check
(exit 2); a forced selection change is a divergence (exit 1).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro import CompileOptions
from repro.api.capture import MANIFEST, resolve_capture_dir, seeded_inputs
from repro.core import ModelBuilder
from repro.replay import (BundleError, load_manifest, replay_bundle,
                          verify_bundle)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mlp():
    mb = ModelBuilder().seed(0)
    x = mb.input((16,))
    h = mb.dense(x, 32, activation="relu")
    out = mb.dense(h, 8)
    return mb.build([out])


def _capture(tmp_path, *, autotune="full", batches=(1,)):
    bundle = os.path.join(str(tmp_path), "bundle")
    exe = repro.compile(_mlp(), CompileOptions(
        target="pallas", autotune=autotune, autotune_budget_ms=20_000,
        cache_dir=os.path.join(str(tmp_path), "cache"), capture=bundle))
    for b in batches:
        exe.ensure_compiled(b)
    return bundle, exe


def _run_replay(bundle, *extra):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.replay", bundle, *extra],
        capture_output=True, text=True, env=env)


# ---------------------------------------------------------------------------
# bundle contents
# ---------------------------------------------------------------------------
def test_bundle_is_self_contained(tmp_path):
    bundle, exe = _capture(tmp_path, batches=(1, 4))
    for rel in (MANIFEST, "graph.npz", "options.json", "report.json",
                "batches/1/selection.json", "batches/1/io.npz",
                "batches/4/selection.json", "batches/4/io.npz"):
        assert os.path.exists(os.path.join(bundle, rel)), rel
    assert os.listdir(os.path.join(bundle, "ir"))      # per-pass dumps
    assert os.listdir(os.path.join(bundle, "tactics"))  # harvested entries
    manifest = load_manifest(bundle)
    verify_bundle(bundle, manifest)
    assert sorted(manifest["batches"]) == [1, 4]
    with open(os.path.join(bundle, "report.json")) as f:
        report = json.load(f)
    assert report["graph_decisions"]["sites"]
    assert "entries" not in report["graph_decisions"]
    assert exe.capture_path == bundle


def test_capture_off_by_default(tmp_path):
    exe = repro.compile(_mlp(), CompileOptions(target="pallas"))
    exe.ensure_compiled(1)
    assert exe.capture_path is None


def test_capture_env_root_creates_subdir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CAPTURE_DIR", str(tmp_path))
    g = _mlp()
    path = resolve_capture_dir(None, g, "pallas")
    assert path == os.path.join(
        str(tmp_path), f"{g.structure_hash()[:12]}-pallas")
    exe = repro.compile(g, CompileOptions(target="pallas"))
    exe.ensure_compiled(1)
    assert os.path.exists(os.path.join(path, MANIFEST))


def test_seeded_inputs_are_deterministic():
    g = _mlp()
    a, b = seeded_inputs(g, 2), seeded_inputs(g, 2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


# ---------------------------------------------------------------------------
# replay: clean, tampered, diverged
# ---------------------------------------------------------------------------
def test_replay_clean_bundle_in_fresh_process(tmp_path):
    bundle, _ = _capture(tmp_path)
    r = _run_replay(bundle)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "replay OK" in r.stdout


def test_replay_reproduces_selections_bit_identically(tmp_path):
    bundle, exe = _capture(tmp_path)
    result = replay_bundle(bundle, verbose=False)
    assert result["divergences"] == []
    assert result["fingerprint_match"]


def test_replay_heuristic_bundle(tmp_path):
    """autotune="off" compiles capture and replay too — no tactics, all
    heuristic, still bit-exact."""
    bundle, _ = _capture(tmp_path, autotune="off")
    r = _run_replay(bundle)
    assert r.returncode == 0, r.stdout + r.stderr


def test_replay_tampered_file_exits_2(tmp_path):
    bundle, _ = _capture(tmp_path)
    sel = os.path.join(bundle, "batches", "1", "selection.json")
    with open(sel) as f:
        data = json.load(f)
    next(iter(data.values()))["kernel"] = "lax.dot"
    with open(sel, "w") as f:
        json.dump(data, f)
    r = _run_replay(bundle)
    assert r.returncode == 2
    assert "tampered" in r.stderr


def test_replay_missing_file_exits_2(tmp_path):
    bundle, _ = _capture(tmp_path)
    os.remove(os.path.join(bundle, "batches", "1", "io.npz"))
    r = _run_replay(bundle)
    assert r.returncode == 2
    assert "missing" in r.stderr


def test_replay_not_a_bundle_exits_2(tmp_path):
    r = _run_replay(str(tmp_path))
    assert r.returncode == 2


def test_replay_detects_selection_divergence(tmp_path):
    """A recorded selection that can't be reproduced (its tactic entries
    removed, so replay resolves to different winners) exits 1 — the
    manifest is resealed so this isn't a tamper, it's a divergence."""
    bundle, _ = _capture(tmp_path)
    tactics = os.path.join(bundle, "tactics")
    removed = 0
    for name in os.listdir(tactics):
        with open(os.path.join(tactics, name)) as f:
            entry = json.load(f)
        # flip measured winners to the loser so replay resolves
        # differently from the recorded report
        us = entry.get("measured_us") or {}
        if len(us) >= 2:
            loser = max(us, key=us.get)
            if entry.get("graph") or "kind" in entry:     # decision entry
                entry["winner"] = loser
            else:
                entry["winner_label"] = loser
                entry["winner"] = loser.split("[")[0]
                removed += 1
            with open(os.path.join(tactics, name), "w") as f:
                json.dump(entry, f)
    if not removed:
        pytest.skip("no multi-candidate kernel entries to flip")
    # reseal the manifest (simulating a stale-but-valid bundle)
    from repro.api.capture import _sha256
    mpath = os.path.join(bundle, MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    for rel in manifest["files"]:
        manifest["files"][rel] = _sha256(os.path.join(bundle, rel))
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    r = _run_replay(bundle)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "DIVERGENCE" in r.stdout


def test_replay_json_output(tmp_path):
    bundle, _ = _capture(tmp_path)
    r = _run_replay(bundle, "--json")
    assert r.returncode == 0
    result = json.loads(r.stdout)
    assert result["divergences"] == []
    assert result["batches"] == [1]
