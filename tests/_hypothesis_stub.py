"""Fallback when ``hypothesis`` is not installed: property-based tests
are skipped (everything else in the module still runs).  Mirrors just
enough of the decorator/strategy surface used in this suite."""

import pytest


def given(*_a, **_k):
    return lambda fn: pytest.mark.skip(
        reason="hypothesis not installed")(fn)


def settings(*_a, **_k):
    return lambda fn: fn


class _Strategies:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
