"""repro.serve — continuous-batching scheduler: admission order,
eviction, rebatch-vs-sequential equivalence, metrics accounting.

The deterministic step-loop tests drive the scheduler with a scripted
token sampler and a fake clock, so every admission, eviction and
timestamp is asserted exactly; the equivalence tests run the real
greedy sampler against the legacy sequential ``Engine``.
"""

import warnings

import numpy as np
import pytest

import jax

import repro
from repro.configs import get_config
from repro.models import get_model
from repro.serve import (QueueFullError, Request, Scheduler,
                         SchedulerOptions)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


class ScriptedSampler:
    """Returns ``script[uid][index]`` regardless of logits; falls back
    to greedy-0 (token 1) when a request runs off its script."""

    def __init__(self, script):
        self.script = script
        self.calls = []

    def __call__(self, logits, temperature, *, uid, index):
        self.calls.append((uid, index))
        seq = self.script.get(uid, ())
        return seq[index] if index < len(seq) else 1


class TickClock:
    """Monotone integer clock: one tick per call."""

    def __init__(self):
        self.t = 0

    def __call__(self):
        self.t += 1
        return float(self.t)


def _sched(m, params, *, sampler=None, clock=None, **opts) -> Scheduler:
    extra = {}
    if clock is not None:
        extra["clock"] = clock
    return Scheduler(m, params,
                     SchedulerOptions(fold=False, **opts),
                     sampler=sampler, **extra)


# ---------------------------------------------------------------- options
def test_options_validation():
    with pytest.raises(ValueError):
        SchedulerOptions(slots=0)
    with pytest.raises(ValueError):
        SchedulerOptions(admission="lifo")
    with pytest.raises(ValueError):
        SchedulerOptions(max_queue=0)


def test_serve_rejects_graph_executables():
    from repro.core import ModelBuilder
    mb = ModelBuilder().seed(0)
    out = mb.dense(mb.input((4,)), 2)
    exe = repro.compile(mb.build([out]),
                        repro.CompileOptions(target="jit"))
    with pytest.raises(TypeError, match="target='engine'"):
        repro.serve(exe)


def test_engine_shim_deprecation_warns_once(setup):
    cfg, m, params = setup
    import repro.inference.engine as legacy
    legacy._warned = False
    with pytest.warns(DeprecationWarning, match="repro.serve"):
        legacy.Engine(m, params, slots=1, max_len=32, fold=False)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy.Engine(m, params, slots=1, max_len=32, fold=False)
    assert not any(issubclass(w.category, DeprecationWarning)
                   for w in caught)


# -------------------------------------------------------------- admission
def test_fcfs_admission_order(setup):
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48,
                   sampler=ScriptedSampler({}), clock=TickClock())
    for uid in (3, 1, 2):                       # arrival order, not uid order
        sched.submit(Request(uid=uid, prompt=np.arange(4) % cfg.vocab,
                             max_new_tokens=2))
    sched.run()
    admitted = sorted(sched.request_metrics.values(),
                      key=lambda r: r.admitted_at)
    assert [r.uid for r in admitted] == [3, 1, 2]


def test_shortest_admission_order(setup):
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48, admission="shortest",
                   sampler=ScriptedSampler({}), clock=TickClock())
    for uid, plen in ((0, 10), (1, 3), (2, 6)):
        sched.submit(Request(uid=uid, prompt=np.arange(plen) % cfg.vocab,
                             max_new_tokens=2))
    sched.run()
    admitted = sorted(sched.request_metrics.values(),
                      key=lambda r: r.admitted_at)
    assert [r.uid for r in admitted] == [1, 2, 0]


def test_queue_admission_control(setup):
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48, max_queue=2)
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab))
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab))
    sched.submit(Request(uid=1, prompt=np.arange(4) % cfg.vocab))
    with pytest.raises(QueueFullError):
        sched.submit(Request(uid=2, prompt=np.arange(4) % cfg.vocab))
    assert sched.metrics.rejected == 1
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request(uid=3, prompt=np.arange(48) % cfg.vocab))


# --------------------------------------------------------------- eviction
def test_eos_evicts_slot_and_admits_next(setup):
    cfg, m, params = setup
    # uid 0 emits EOS (=7) as its second token; uid 1 runs to length
    sampler = ScriptedSampler({0: (5, 7), 1: (2, 3, 4)})
    sched = _sched(m, params, slots=1, max_len=48, sampler=sampler)
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=8, eos_id=7))
    sched.submit(Request(uid=1, prompt=np.arange(5) % cfg.vocab,
                         max_new_tokens=3, eos_id=7))
    done = sched.run()
    assert [c.uid for c in done] == [0, 1]      # finish order
    assert done[0].tokens == [5, 7]
    assert done[0].finish_reason == "eos"
    assert done[1].tokens == [2, 3, 4]
    assert done[1].finish_reason == "length"
    assert sched.request_metrics[0].finish_reason == "eos"


def test_eos_on_first_token_retires_at_admission(setup):
    cfg, m, params = setup
    sampler = ScriptedSampler({0: (7,)})
    sched = _sched(m, params, slots=2, max_len=48, sampler=sampler)
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=8, eos_id=7))
    done = sched.run()
    assert done[0].tokens == [7]
    assert done[0].finish_reason == "eos"
    assert sched.metrics.decode_steps == 0      # never needed a decode


def test_max_new_tokens_clamped_to_cache_budget(setup):
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=12)
    # prompt of 8 leaves a budget of 4 new tokens in a 12-wide cache
    sched.submit(Request(uid=0, prompt=np.arange(8) % cfg.vocab,
                         max_new_tokens=100))
    done = sched.run()
    assert len(done[0].tokens) == 4
    assert done[0].finish_reason == "length"


# ------------------------------------------------- rebatch vs sequential
def test_rebatched_matches_sequential_engine(setup):
    """Continuous batching with mid-flight arrivals must reproduce the
    sequential greedy decode token-for-token (acceptance criterion)."""
    cfg, m, params = setup
    prompts = {uid: (np.arange(3 + (uid % 4)) * (uid + 2)) % cfg.vocab
               for uid in range(10)}

    # sequential reference: the deprecated one-slot Engine
    from repro.inference import Engine
    want = {}
    for uid, prompt in prompts.items():
        eng = Engine(m, params, slots=1, max_len=48, fold=False)
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=6))
        want[uid] = eng.run()[0].tokens

    # concurrent: 8 slots, first 8 requests up front, 2 arrive mid-loop
    sched = _sched(m, params, slots=8, max_len=48)
    for uid in range(8):
        sched.submit(Request(uid=uid, prompt=prompts[uid],
                             max_new_tokens=6))
    sched.step()
    assert sched.num_active() == 8              # ≥8 concurrent requests
    sched.step()
    for uid in (8, 9):
        sched.submit(Request(uid=uid, prompt=prompts[uid],
                             max_new_tokens=6))
    done = {c.uid: c.tokens for c in sched.run()}
    assert done == want
    assert sched.summary()["completed"] == 10


# ---------------------------------------------------------------- metrics
def test_metrics_accounting(setup):
    cfg, m, params = setup
    clock = TickClock()
    sched = _sched(m, params, slots=2, max_len=48,
                   sampler=ScriptedSampler({}), clock=clock)
    for uid in range(2):
        sched.submit(Request(uid=uid, prompt=np.arange(4) % cfg.vocab,
                             max_new_tokens=3))
    done = sched.run()
    s = sched.summary()
    assert s["submitted"] == s["admitted"] == s["completed"] == 2
    assert s["total_new_tokens"] == sum(len(c.tokens) for c in done) == 6
    # both slots busy for both decode steps (1 prefill + 2 decode tokens)
    assert s["decode_steps"] == 2
    assert s["mean_batch_occupancy"] == 2.0
    assert s["peak_queue_depth"] == 2
    for uid in range(2):
        rm = sched.request_metrics[uid]
        assert rm.prompt_tokens == 4 and rm.new_tokens == 3
        assert (rm.submitted_at < rm.admitted_at < rm.first_token_at
                <= rm.finished_at)
        assert rm.ttft == rm.first_token_at - rm.submitted_at
        assert rm.queue_time == rm.admitted_at - rm.submitted_at
        assert rm.decode_tokens_per_s > 0
    assert sched.request_metrics[0].queue_depth_at_submit == 0
    assert sched.request_metrics[1].queue_depth_at_submit == 1


def test_pop_completions_streams(setup):
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48,
                   sampler=ScriptedSampler({}))
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2))
    sched.submit(Request(uid=1, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2))
    assert sched.pop_completions() == []
    while not sched.pop_completions():
        sched.step()
    # uid 0 drained exactly once; uid 1 still pending or drained later
    sched.run()
    rest = sched.pop_completions()
    assert [c.uid for c in rest] == [1]
    assert len(sched.done) == 2


# --------------------------------------------------- named multi-inputs
def test_request_named_multi_inputs():
    """A serve() request can carry the model signature's non-token
    inputs by name (audio frames here); they reach prefill verbatim
    and actually change the decode."""
    cfg = get_config("whisper-base", smoke=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    frames = np.random.default_rng(0).standard_normal(
        (cfg.n_frames, cfg.d_model)).astype(np.float32)   # batch-less

    sched = _sched(m, params, slots=1, max_len=32)
    batch = sched._prefill_batch(np.arange(4, dtype=np.int32)[None],
                                 {"frames": frames})
    assert sorted(batch) == ["frames", "tokens"]
    np.testing.assert_array_equal(np.asarray(batch["frames"][0]), frames)
    with pytest.raises(ValueError, match="expected"):
        sched._prefill_batch(np.arange(4, dtype=np.int32)[None],
                             {"frames": frames[: cfg.n_frames // 2]})

    # zeros vs real frames change the prefill logits...
    prompt = np.arange(4, dtype=np.int32)[None]
    logits_zero, _ = sched._prefill(
        params, sched._prefill_batch(prompt, None), m.init_cache(1, 32))
    logits_real, _ = sched._prefill(
        params, sched._prefill_batch(prompt, {"frames": frames}),
        m.init_cache(1, 32))
    assert not np.allclose(np.asarray(logits_zero), np.asarray(logits_real))

    # ...and a request carrying them runs end to end
    s = _sched(m, params, slots=1, max_len=32)
    s.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                     max_new_tokens=4, inputs={"frames": frames}))
    assert len(s.run()[0].tokens) == 4

    # names outside the model's signature — and wrong shapes — are
    # rejected at submit, before the request can enter the step loop
    sched2 = _sched(m, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="unknown inputs"):
        sched2.submit(Request(uid=1, prompt=np.arange(4) % cfg.vocab,
                              inputs={"patches": frames}))
    with pytest.raises(ValueError, match="expected"):
        sched2.submit(Request(uid=2, prompt=np.arange(4) % cfg.vocab,
                              inputs={"frames": frames[:3]}))
    assert sched2.queue_depth() == 0              # nothing got enqueued


def test_pop_completions_purge_frees_state_and_uids(setup):
    """A long-running server drains with purge=True: per-request state
    is released and finished uids become reusable."""
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48,
                   sampler=ScriptedSampler({}))
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2))
    sched.run()
    popped = sched.pop_completions(purge=True)
    assert [c.uid for c in popped] == [0]
    assert sched.done == [] and sched.generated == {}
    assert sched.request_metrics == {}
    # the uid is reusable now, and aggregate counters keep accumulating
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2))
    sched.run()
    assert sched.metrics.completed == 2
    assert [c.uid for c in sched.pop_completions(purge=True)] == [0]


# ------------------------------------------------- serve hot loop (PR 8)
def test_hot_loop_options_validation():
    with pytest.raises(ValueError, match="divide"):
        SchedulerOptions(max_len=48, prefill_chunk=10)
    with pytest.raises(ValueError, match="positive"):
        SchedulerOptions(prefill_chunk=0)
    with pytest.raises(ValueError, match="requires prefill_chunk"):
        SchedulerOptions(prefix_cache=4)
    with pytest.raises(ValueError, match="min_prefix"):
        SchedulerOptions(min_prefix=-1)
    # the new admission policy and the combined options are accepted
    o = SchedulerOptions(max_len=64, admission="deadline",
                         prefill_chunk=16, prefix_cache=4)
    assert o.to_dict()["prefill_chunk"] == 16


def test_chunked_prefill_model_bit_identity(setup):
    """Incremental prefill_chunk over an existing cache reproduces the
    full-sequence prefill EXACTLY: last-token logits and every written
    cache row are bitwise equal (online-softmax masking makes the pad
    positions contribute exact zeros)."""
    cfg, m, params = setup
    max_len, plen, chunk = 64, 37, 16
    prompt = (np.arange(plen, dtype=np.int32) * 3 + 1) % cfg.vocab

    logits_full, cache_full = jax.jit(
        lambda p, t, c: m.prefill(p, {"tokens": t}, c))(
        params, prompt[None], m.init_cache(1, max_len))

    cache = m.init_cache(1, max_len)
    step = jax.jit(lambda p, t, c, s, n: m.prefill_chunk(p, t, c, s, n))
    off = 0
    while off < plen:
        n = min(chunk, plen - off)
        padded = np.zeros((1, chunk), np.int32)
        padded[0, :n] = prompt[off:off + n]
        logits, cache = step(params, padded, cache,
                             np.int32(off), np.int32(n))
        off += n

    np.testing.assert_array_equal(np.asarray(logits_full[:, -1]),
                                  np.asarray(logits[:, 0]))
    for k in ("c1", "c2"):
        np.testing.assert_array_equal(
            np.asarray(cache_full[k])[:, :, :plen],
            np.asarray(cache[k])[:, :, :plen])
    assert int(cache["pos"][0]) == plen


def _mixed_requests(vocab, *, head=None, n=6, max_new=5):
    """Deterministic mixed stream; with ``head`` every odd request's
    prompt starts with it (the shared system prompt)."""
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, int(rng.integers(3, 12))).astype(
            np.int32)
        if head is not None and i % 2 == 1:
            prompt = np.concatenate([head, tail])
        else:
            prompt = tail
        reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _tokens(m, params, reqs, **opts):
    sched = _sched(m, params, **opts)
    for r in reqs:
        sched.submit(r)
    sched.run()
    out = {c.uid: tuple(c.tokens) for c in sched.done}
    summ = sched.summary()
    sched.shutdown()
    return out, summ


def test_chunked_prefill_scheduler_tokens_identical(setup):
    """Long prompts fed through chunked prefill (with and without batch
    buckets) produce bit-identical token streams to the fixed-shape
    whole-prompt scheduler."""
    cfg, m, params = setup
    head = (np.arange(20, dtype=np.int32) * 7 + 2) % cfg.vocab
    reqs = _mixed_requests(cfg.vocab, head=head)

    want, base_summ = _tokens(m, params, reqs, slots=3, max_len=64)
    assert "runtime" not in base_summ and "chunked_prefill" not in base_summ

    got, summ = _tokens(m, params, reqs, slots=3, max_len=64,
                        prefill_chunk=8)
    assert got == want
    assert summ["chunked_prefill"] == {"enabled": True, "chunk_len": 8}
    assert summ["prefill_chunks"] > len(reqs)   # long prompts = >1 chunk
    assert summ["runtime"]["chunk"]["compile_stalls"] == 0

    pol = repro.BucketPolicy.default(max_batch=3, max_len=64)
    got_b, summ_b = _tokens(m, params, reqs, slots=3, max_len=64,
                            prefill_chunk=8, buckets=pol)
    assert got_b == want
    # chunked prefill replaces the padded length-bucket prefill engine
    assert "prefill" not in summ_b["runtime"]
    assert "chunk" in summ_b["runtime"]


def test_prefix_sharing_bit_identity_and_head_prefilled_once(setup):
    """Requests sharing a prompt head: tokens stay bit-identical to the
    unshared scheduler, the head is prefilled exactly ONCE (one insert;
    every other sharer takes a snapshot copy), and the shared chunks
    are actually skipped (fewer chunk dispatches than without the
    cache)."""
    cfg, m, params = setup
    chunk = 8
    head = (np.arange(2 * chunk, dtype=np.int32) * 5 + 3) % cfg.vocab
    reqs = _mixed_requests(cfg.vocab, head=head)
    n_shared = sum(1 for r in reqs if len(r.prompt) > len(head))

    want, _ = _tokens(m, params, reqs, slots=3, max_len=64)
    plain, plain_summ = _tokens(m, params, reqs, slots=3, max_len=64,
                                prefill_chunk=chunk)
    shared, summ = _tokens(m, params, reqs, slots=3, max_len=64,
                           prefill_chunk=chunk, prefix_cache=4)
    assert plain == want and shared == want

    pc = summ["prefix_cache"]
    assert pc["inserts"] == 1                       # head prefilled once
    assert pc["hits"] == n_shared - 1               # every other sharer
    assert pc["shared_tokens"] == (n_shared - 1) * len(head)
    # the skipped head chunks are real dispatch savings
    saved = (n_shared - 1) * (len(head) // chunk)
    assert summ["prefill_chunks"] == plain_summ["prefill_chunks"] - saved


def test_prefix_cache_lru_and_proper_prefix():
    """Unit-level PrefixCache behavior: longest proper prefix wins,
    whole-prompt keys never match, LRU evicts beyond capacity."""
    from repro.serve import PrefixCache
    import jax.numpy as jnp
    pc = PrefixCache(2)
    mk = lambda v: {"c": jnp.full((2, 1, 4), v), "pos": jnp.array([0])}
    a = np.arange(8, dtype=np.int32)
    pc.insert(PrefixCache.key_for(a[:4]), 4, mk(1.0))
    pc.insert(PrefixCache.key_for(a[:6]), 6, mk(2.0))

    h, snap = pc.take(a)                  # longest proper prefix: 6
    assert h == 6 and float(snap["c"][0, 0, 0]) == 2.0
    assert pc.take(a[:4]) is None         # whole prompt == head: no hit
    assert pc.take(np.flip(a).copy()) is None
    # taken snapshots are copies: mutating one leaves the cache intact
    snap["c"] = snap["c"].at[0].set(9.0)
    _, snap2 = pc.take(a)
    assert float(snap2["c"][0, 0, 0]) == 2.0

    pc.insert(PrefixCache.key_for(a[:2]), 2, mk(3.0))   # evicts LRU (4)
    assert pc.evictions == 1 and len(pc) == 2
    h, _ = pc.take(a[:3])
    assert h == 2
    assert pc.stats()["hits"] == 3


def test_deadline_admission_order(setup):
    """EDF under a fake clock: earliest absolute deadline first, no-SLO
    requests last (FCFS among themselves)."""
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48, admission="deadline",
                   sampler=ScriptedSampler({}), clock=TickClock())
    # submit order: no-SLO, loose, tight -> admit order: tight, loose, no
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2))
    sched.submit(Request(uid=1, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2, slo_ms=9000.0))
    sched.submit(Request(uid=2, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=2, slo_ms=1000.0))
    assert sched.request_metrics[1].deadline == pytest.approx(2.0 + 9.0)
    assert sched.request_metrics[2].deadline == pytest.approx(3.0 + 1.0)
    sched.run()
    admitted = sorted(sched.request_metrics.values(),
                      key=lambda r: r.admitted_at)
    assert [r.uid for r in admitted] == [2, 1, 0]


def test_slo_violations_counted(setup):
    """First tokens landing after the deadline are counted and flagged;
    on-time requests are flagged False; no-SLO requests stay None."""
    cfg, m, params = setup
    sched = _sched(m, params, slots=1, max_len=48,
                   sampler=ScriptedSampler({}), clock=TickClock())
    sched.submit(Request(uid=0, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=3, slo_ms=60_000.0))
    sched.submit(Request(uid=1, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=3, slo_ms=4000.0))   # will queue
    sched.submit(Request(uid=2, prompt=np.arange(4) % cfg.vocab,
                         max_new_tokens=3))
    sched.run()
    s = sched.summary()
    assert s["slo_violations"] == 1
    assert sched.request_metrics[0].slo_violated is False
    assert sched.request_metrics[1].slo_violated is True
    assert sched.request_metrics[2].slo_violated is None
    assert s["ttft_p50"] is not None and s["ttft_p99"] is not None


def test_summary_percentiles_match_numpy():
    """The dependency-free percentile matches numpy's default (linear
    interpolation), and summary() reports the tail keys."""
    from repro.serve.metrics import percentile
    rng = np.random.default_rng(3)
    for n in (1, 2, 5, 100):
        vals = rng.standard_normal(n).tolist()
        for q in (0.0, 50.0, 90.0, 99.0, 100.0):
            assert percentile(vals, q) == pytest.approx(
                float(np.percentile(vals, q)))
    assert percentile([], 50.0) is None


def test_chunked_prefill_auto_disabled_for_ring_caches(setup):
    """All-sliding-window models allocate a ring cache whose absolute
    row indices alias; chunked prefill must switch itself off (surfaced
    in summary) and serving must still work via whole-prompt prefill."""
    import dataclasses
    cfg, _, _ = setup
    ring_cfg = dataclasses.replace(cfg, pattern="swa", window=8)
    m = get_model(ring_cfg)
    params = m.init(jax.random.PRNGKey(0))
    sched = _sched(m, params, slots=2, max_len=32, prefill_chunk=8)
    assert sched._chunk_engine is None
    sched.submit(Request(uid=0, prompt=np.arange(6) % ring_cfg.vocab,
                         max_new_tokens=3))
    done = sched.run()
    assert len(done[0].tokens) == 3
    assert sched.summary()["chunked_prefill"] == {
        "enabled": False, "chunk_len": 8}


def test_steady_state_decode_zero_allocations(setup):
    """The donated step loop: across steady-state decode steps every
    cache leaf keeps its device buffer (the donated program updates it
    in place) and the number of live device arrays does not grow — no
    per-step slice / write-back allocations, on both the fixed-shape
    and the bucketed path."""
    cfg, m, params = setup
    pol = repro.BucketPolicy.default(max_batch=4, max_len=48)
    for buckets in (None, pol):
        sched = Scheduler(m, params,
                          SchedulerOptions(slots=4, max_len=48,
                                           fold=False, buckets=buckets),
                          engine_worker="sync")
        for uid in range(4):
            sched.submit(Request(uid=uid,
                                 prompt=np.arange(6) % cfg.vocab,
                                 max_new_tokens=30))
        sched.step()                     # admissions + first decode
        sched.step()
        ptrs = sched.slot_manager.buffer_pointers()
        live = len(jax.live_arrays())
        for _ in range(6):
            sched.step()
            assert sched.slot_manager.buffer_pointers() == ptrs
        assert len(jax.live_arrays()) == live
        sched.shutdown()
