"""The unified ``repro.compile`` API: golden equivalence between the
"interpret" and "jit" targets for every op the builder emits, executable
serialization round-trips, the persistent on-disk executable cache, the
target registry, and the legacy ``CompiledModel`` deprecation shim."""

import warnings

import numpy as np
import pytest

import repro
from repro.api import CompileOptions, JitExecutable, register_target
from repro.core import ModelBuilder


# ---------------------------------------------------------------------------
# Golden per-op equivalence: interpret vs jit (satellite: includes the
# previously-broken flatten op and explicit-padding pooling).
# ---------------------------------------------------------------------------
OP_CASES = {
    "conv2d_same": lambda mb, x: mb.conv2d(x, 4, (3, 3)),
    "conv2d_valid_strided": lambda mb, x: mb.conv2d(
        x, 4, (3, 3), strides=(2, 2), padding="valid"),
    "conv2d_relu": lambda mb, x: mb.conv2d(x, 4, (3, 3), activation="relu"),
    "depthwise_conv2d": lambda mb, x: mb.depthwise_conv2d(x, (3, 3), mult=2),
    "dense": lambda mb, x: mb.dense(mb.global_avg_pool(x), 5),
    "dense_tanh": lambda mb, x: mb.dense(mb.global_avg_pool(x), 5,
                                         activation="tanh"),
    "batchnorm": lambda mb, x: mb.batchnorm(x),
    "act_relu6": lambda mb, x: mb.activation(x, "relu6"),
    "act_leaky_relu": lambda mb, x: mb.activation(x, "leaky_relu"),
    "act_sigmoid": lambda mb, x: mb.activation(x, "sigmoid"),
    "act_elu": lambda mb, x: mb.activation(x, "elu"),
    "act_hard_sigmoid": lambda mb, x: mb.activation(x, "hard_sigmoid"),
    "maxpool_valid": lambda mb, x: mb.maxpool(x),
    "maxpool_same": lambda mb, x: mb.maxpool(x, (3, 3), strides=(2, 2),
                                             padding="same"),
    "maxpool_explicit_pad": lambda mb, x: mb.maxpool(
        x, padding=((1, 0), (0, 1))),
    "avgpool_valid": lambda mb, x: mb.avgpool(x),
    "avgpool_explicit_pad": lambda mb, x: mb.avgpool(
        x, padding=((1, 1), (1, 1))),
    "global_avg_pool": lambda mb, x: mb.global_avg_pool(x),
    "upsample2d": lambda mb, x: mb.upsample(x),
    "zero_pad2d": lambda mb, x: mb.zero_pad(x, ((2, 0), (1, 1))),
    "add": lambda mb, x: mb.add(mb.conv2d(x, 4, (1, 1)),
                                mb.conv2d(x, 4, (1, 1))),
    "concat": lambda mb, x: mb.concat([mb.conv2d(x, 3, (1, 1)),
                                       mb.conv2d(x, 2, (1, 1))]),
    "flatten": lambda mb, x: mb.flatten(x),
    "flatten_dense": lambda mb, x: mb.dense(mb.flatten(x), 4),
    "softmax": lambda mb, x: mb.softmax(mb.dense(mb.global_avg_pool(x), 5)),
}


def _build(case):
    mb = ModelBuilder().seed(11)
    x = mb.input((6, 6, 3))
    out = OP_CASES[case](mb, x)
    return mb.build([out]), out


@pytest.mark.parametrize("embed", [True, False],
                         ids=["embed", "framework"])
@pytest.mark.parametrize("case", sorted(OP_CASES))
def test_interpret_jit_golden_equivalence(case, embed, rng):
    g, out = _build(case)
    x = rng.standard_normal((2, 6, 6, 3)).astype(np.float32)
    want = np.asarray(
        repro.compile(g, CompileOptions(target="interpret"))(input=x)[out])
    got = np.asarray(
        repro.compile(g, CompileOptions(target="jit",
                                        embed_weights=embed))(input=x)[out])
    np.testing.assert_allclose(want, got, rtol=1e-5, atol=1e-5)


def test_flatten_compiles_without_canonicalize(rng):
    """The 'jit' target must lower flatten directly (the legacy back end
    raised NotImplementedError unless canonicalize rewrote it away)."""
    g, out = _build("flatten")
    x = rng.standard_normal((3, 6, 6, 3)).astype(np.float32)
    exe = repro.compile(g, CompileOptions(passes=()))
    got = np.asarray(exe(input=x)[out])
    np.testing.assert_allclose(got, x.reshape(3, -1), rtol=1e-6)


# ---------------------------------------------------------------------------
# Executable protocol
# ---------------------------------------------------------------------------
def _cnn():
    mb = ModelBuilder().seed(3)
    x = mb.input((8, 8, 3))
    h = mb.conv2d(x, 8, (3, 3), activation="relu")
    h = mb.batchnorm(h)
    h = mb.maxpool(h)
    h = mb.global_avg_pool(h)
    h = mb.dense(h, 4)
    out = mb.softmax(h)
    return mb.build([out]), out


@pytest.mark.parametrize("target", ["interpret", "jit"])
def test_serialize_deserialize_roundtrip(target, rng):
    g, out = _cnn()
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    exe = repro.compile(g, CompileOptions(target=target))
    blob = exe.serialize()
    exe2 = repro.deserialize(blob)
    assert exe2.options == exe.options
    np.testing.assert_array_equal(np.asarray(exe(input=x)[out]),
                                  np.asarray(exe2(input=x)[out]))


def test_deserialize_rejects_garbage():
    with pytest.raises(ValueError):
        repro.deserialize(b"not an executable")


def test_deserialize_ignores_embedded_cache_dir(tmp_path):
    """A cache_dir carried inside serialized bytes must not be honored
    (the cache pickle-loads from that directory)."""
    g, _ = _cnn()
    exe = repro.compile(g, CompileOptions(cache_dir=str(tmp_path)))
    exe2 = repro.deserialize(exe.serialize())
    assert exe2.options.cache_dir is None


def test_executable_surface(rng):
    g, out = _cnn()
    x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
    exe = repro.compile(g, CompileOptions(target="jit"))
    exe(input=x)
    assert exe.compile_time is not None and exe.compile_time > 0
    cost = exe.cost_summary()
    assert cost["target"] == "jit"
    assert cost["memory_plan"]["arena_bytes"] > 0
    assert any(p["pass"] == "fold_batchnorm" for p in cost["passes"])
    with pytest.raises(ValueError, match="missing inputs"):
        exe(wrong_name=x)


def test_batch_buckets_pad_and_slice(rng):
    g, out = _cnn()
    exe = repro.compile(g, CompileOptions(batch_buckets=(4,)))
    ref = repro.compile(g, CompileOptions(target="interpret"))
    for batch in (1, 3, 4):
        x = rng.standard_normal((batch, 8, 8, 3)).astype(np.float32)
        got = np.asarray(exe(input=x)[out])
        assert got.shape[0] == batch
        np.testing.assert_allclose(
            got, np.asarray(ref(input=x)[out]), rtol=2e-5, atol=1e-6)
    # every call ran the single bucket-4 specialization
    assert list(exe._fns) == [4]
    x = rng.standard_normal((6, 8, 8, 3)).astype(np.float32)  # > bucket
    assert np.asarray(exe(input=x)[out]).shape[0] == 6


def test_options_validation():
    with pytest.raises(ValueError):
        CompileOptions(precision="approximate")
    with pytest.raises(ValueError):
        CompileOptions(batch_buckets=(0,))
    opts = CompileOptions(passes=["canonicalize"], batch_buckets=[4, 2])
    assert opts.passes == ("canonicalize",)
    assert opts.batch_buckets == (2, 4)
    # cache_dir and batch_buckets don't change generated code, so they
    # must not fragment the cross-process executable cache
    assert opts.cache_token() == opts.replace(cache_dir="/tmp/x").cache_token()
    assert opts.cache_token() == opts.replace(batch_buckets=()).cache_token()
    assert opts.cache_token() != opts.replace(precision="fast").cache_token()


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------
def test_unknown_target_raises():
    g, _ = _cnn()
    with pytest.raises(KeyError, match="unknown target"):
        repro.compile(g, CompileOptions(target="tpu-asm"))


def test_register_custom_target(rng):
    calls = []

    @register_target("test-echo")
    def build(graph, options):
        calls.append(options)
        return repro.api.get_target("jit")(graph, options.replace(target="jit"))

    try:
        g, out = _cnn()
        x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
        exe = repro.compile(g, CompileOptions(target="test-echo"))
        assert calls and calls[0].target == "test-echo"
        assert "test-echo" in repro.available_targets()
        assert np.asarray(exe(input=x)[out]).shape == (1, 4)
    finally:
        from repro.api import targets
        targets._TARGETS.pop("test-echo", None)


def test_graph_rejects_engine_target():
    g, _ = _cnn()
    with pytest.raises(TypeError):
        repro.compile(g, CompileOptions(target="engine"))


def test_config_requires_explicit_engine_target():
    """Non-graph models must name target='engine' — no silent rerouting
    of an explicitly requested graph target."""
    class FakeCfg:
        family = "dense"
        name = "fake"

    with pytest.raises(TypeError, match="engine"):
        repro.compile(FakeCfg(), CompileOptions(target="jit"))


def test_graph_targets_share_positional_surface(rng):
    """ensure_compiled/cache_info exist on every graph target, so
    benchmarks can time any backend uniformly."""
    g, out = _cnn()
    x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
    want = None
    for target in ("interpret", "jit"):
        exe = repro.compile(g, CompileOptions(target=target))
        fn = exe.ensure_compiled(batch_size=1)
        got = np.asarray(fn(x)[out])
        assert exe.cache_info()["hits"] == 0
        if want is None:
            want = got
        else:
            np.testing.assert_allclose(want, got, rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Persistent executable cache
# ---------------------------------------------------------------------------
def test_disk_cache_second_compile_hits(tmp_path, rng):
    g, out = _cnn()
    x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
    opts = CompileOptions(cache_dir=str(tmp_path))

    e1 = repro.compile(g, opts)
    want = np.asarray(e1(input=x)[out])
    assert e1.cache_info()["misses"] == 1 and e1.cache_info()["hits"] == 0

    e2 = repro.compile(g, opts)          # fresh executable, same process
    got = np.asarray(e2(input=x)[out])
    assert e2.cache_info()["hits"] == 1 and e2.cache_info()["misses"] == 0
    np.testing.assert_array_equal(want, got)


def test_disk_cache_key_sensitive_to_options_and_weights(tmp_path, rng):
    g, out = _cnn()
    x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
    e1 = repro.compile(g, CompileOptions(cache_dir=str(tmp_path)))
    e1(input=x)
    # different precision -> different key -> miss
    e2 = repro.compile(g, CompileOptions(cache_dir=str(tmp_path),
                                         precision="fast"))
    e2(input=x)
    assert e2.cache_info()["misses"] == 1
    # different weights (embedded) -> different key -> miss
    g2, _ = _cnn()
    k = sorted(g2.params)[0]
    g2.params[k] = g2.params[k] + 1.0
    e3 = repro.compile(g2, CompileOptions(cache_dir=str(tmp_path)))
    e3(input=x)
    assert e3.cache_info()["misses"] == 1


def test_corrupt_cache_entry_degrades_to_compile(tmp_path, rng):
    g, out = _cnn()
    x = rng.standard_normal((1, 8, 8, 3)).astype(np.float32)
    opts = CompileOptions(cache_dir=str(tmp_path))
    e1 = repro.compile(g, opts)
    want = np.asarray(e1(input=x)[out])
    for f in tmp_path.glob("*.xla"):
        f.write_bytes(b"corrupt")
    e2 = repro.compile(g, opts)
    got = np.asarray(e2(input=x)[out])
    assert e2.cache_info()["misses"] == 1
    np.testing.assert_array_equal(want, got)


# ---------------------------------------------------------------------------
# Legacy shim
# ---------------------------------------------------------------------------
def test_compiled_model_deprecation_warns_once(rng):
    import repro.core.compiler as legacy
    g, out = _cnn()
    legacy._warned = False
    with pytest.warns(DeprecationWarning, match="repro.compile"):
        cm = legacy.CompiledModel(g)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy.CompiledModel(g, precision="fast")
    assert not any(issubclass(w.category, DeprecationWarning)
                   for w in caught)
    # the shim still works end to end and exposes the old surface
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    want = np.asarray(
        repro.compile(g, CompileOptions(target="interpret"))(input=x)[out])
    np.testing.assert_allclose(np.asarray(cm.apply(input=x)[out]), want,
                               rtol=2e-5, atol=1e-6)
    assert cm.compile_time > 0
    assert cm.report["memory_plan"]["arena_bytes"] > 0
    assert isinstance(cm.executable, JitExecutable)
